"""Paper Fig 13: two-week production-trace replay — provisioning cost, GPU
usage, dependency bubbles. Paper: RollMux $510/h, 1.84x cheaper than Solo-D,
1.38x than veRL, 100% SLO."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import (ClusterSimulator, InterGroupScheduler, NodeAllocator,
                        SoloDisaggregation, replay_verl)
from repro.core.trace import production_replay_trace


def run(n_jobs: int = 200, seeds=(1, 2, 3)):
    ratios_solo, ratios_verl, slo, costs = [], [], [], []
    bub_r, bub_t, sb_r, sb_t = [], [], [], []
    peaks = []
    for seed in seeds:
        jobs = production_replay_trace(n_jobs=n_jobs, seed=seed)
        r = ClusterSimulator(InterGroupScheduler(NodeAllocator()),
                             seed=1).run(list(jobs))
        s = ClusterSimulator(SoloDisaggregation(NodeAllocator()),
                             seed=1).run(list(jobs))
        v = replay_verl(list(jobs), NodeAllocator())
        ratios_solo.append(s.avg_cost_per_hour / r.avg_cost_per_hour)
        ratios_verl.append(v.avg_cost_per_hour / r.avg_cost_per_hour)
        slo.append(r.slo_rate)
        costs.append(r.avg_cost_per_hour)
        bub_r.append(r.rollout_bubble)
        bub_t.append(r.train_bubble)
        sb_r.append(s.rollout_bubble)
        sb_t.append(s.train_bubble)
        peaks.append((r.peak_rollout_gpus, r.peak_train_gpus,
                      s.peak_train_gpus))
    emit("fig13_rollmux_cost_per_h", float(np.mean(costs)),
         "avg provisioning $/h (paper $510/h)")
    emit("fig13_cost_gain_vs_soloD", float(np.mean(ratios_solo)),
         "paper: 1.84x")
    emit("fig13_cost_gain_vs_verl", float(np.mean(ratios_verl)),
         "paper: 1.38x")
    emit("fig13_slo_attainment", float(np.mean(slo)), "paper: 100%")
    emit("fig13_train_bubble_reduction",
         float(1 - np.mean(bub_t) / np.mean(sb_t)),
         "relative reduction vs Solo-D (paper 43.1%)")
    emit("fig13_rollout_bubble_reduction",
         float(1 - np.mean(bub_r) / np.mean(sb_r)),
         "relative reduction vs Solo-D (paper 24.4%)")
    pr, pt, spt = np.mean([p[0] for p in peaks]), np.mean(
        [p[1] for p in peaks]), np.mean([p[2] for p in peaks])
    emit("fig13_peak_train_gpus", float(pt),
         f"vs Solo-D {spt:.0f} (paper: 152 vs 328)")
    emit("fig13_peak_rollout_gpus", float(pr), "paper: 216")


if __name__ == "__main__":
    run()
