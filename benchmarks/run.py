"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig13,...] [--fast]

Prints ``name,value,derived`` CSV rows (value is the paper-metric unit noted
in each row's `derived` column; latency rows are milliseconds).
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (at_scale, decision_latency, interference, longtail,
                        model_sync, mux_micro, scheduler_quality, sensitivity,
                        warm_start)

SUITES = {
    "fig10_mux_micro": mux_micro.run,
    "table4_interference": interference.run,
    "fig11_longtail": longtail.run,
    "fig12_model_sync": model_sync.run,
    "fig13_at_scale": at_scale.run,
    "fig14_sensitivity": sensitivity.run,
    "fig15_scheduler_quality": scheduler_quality.run,
    "table5_decision_latency": decision_latency.run,
    "fig4_warm_start": warm_start.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    ap.add_argument("--fast", action="store_true",
                    help="shrink trace sizes for CI-speed runs")
    args = ap.parse_args()
    picked = {k.strip() for k in args.only.split(",") if k.strip()}
    print("name,value,derived")
    for name, fn in SUITES.items():
        if picked and not any(p in name for p in picked):
            continue
        t0 = time.time()
        kwargs = {}
        if args.fast:
            if name == "fig13_at_scale":
                kwargs = {"n_jobs": 60, "seeds": (1,)}
            elif name == "fig14_sensitivity":
                kwargs = {"n_jobs": 50}
            elif name == "fig15_scheduler_quality":
                kwargs = {"n_instances": 3, "jobs_per_instance": 6}
            elif name == "table5_decision_latency":
                kwargs = {"targets": (5, 13, 100, 500)}
        fn(**kwargs)
        print(f"# {name} done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
