"""Paper Fig 11: long-tail rollouts + request-migration gains (1.06-1.28x)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, paper_job
from repro.core import (CoExecutionGroup, Node, Placement, SwitchCosts,
                        H20, H800)
from repro.core.distributions import straggler_stats


def run():
    rng = np.random.default_rng(0)
    # left panel: generation-length distribution statistics
    for sigma, label in ((0.7, "7B-4k"), (0.9, "7B-8k"), (1.1, "14B-8k")):
        st = straggler_stats(rng, n=512, sigma=sigma)
        emit(f"fig11_dist_{label}_p80_over_max", st["p80"] / st["max"],
             "80th-pct completion fraction of straggler time")
        emit(f"fig11_dist_{label}_bubble", st["bubble_frac"],
             "mean GPU idleness waiting for stragglers")

    # right panel: migration throughput gain when two same-type jobs share a
    # rollout node (tail of job A pipelines with head of job B)
    # rollout-bound pairs (the paper tests 7B/14B generation workloads where
    # the rollout pool is the binding resource)
    for t80, label in ((0.75, "7B-4k"), (0.62, "7B-8k"), (0.5, "14B-8k"),
                       (0.68, "mixed-7B8B")):
        a = paper_job("Type-D", "a")
        b = paper_job("Type-D" if label != "mixed-7B8B" else "Type-E", "b")
        a.t80_frac = b.t80_frac = t80
        nodes_r = [Node("r0", H20)]
        nodes_t = [Node("t0", H800)]
        G = CoExecutionGroup("g", nodes_r, nodes_t)
        G.add_job(a, Placement(("r0",)))
        G.add_job(b, Placement(("r0",)))
        base = G.simulate(migration=False, switch=SwitchCosts(),
                          work_conserving=True)
        mig = G.simulate(migration=True, switch=SwitchCosts(),
                         work_conserving=True)
        def thr(r):
            return sum(1.0 / t for t in r.iter_time.values())
        emit(f"fig11_migration_gain_{label}", thr(mig) / thr(base),
             "throughput gain from long-tail migration (paper 1.06-1.28x)")


if __name__ == "__main__":
    run()
