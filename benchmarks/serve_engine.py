"""Trace-driven rollout-serving benchmark: continuous batching vs static,
contiguous vs paged KV.

Replays a Poisson-arrival trace with heavy-tailed per-request decode
budgets (the paper's long-tail response-length model, ``core.distributions``)
through three servers sharing one model + weights:

  * **engine** — ``repro.serve.Engine``: FIFO queue over a fixed slot pool
    of contiguous ``max_seq_len`` KV stripes, prefill-into-free-slot
    admission, slot recycle on EOS/budget, decode batched across live
    slots (``--block-size`` fused steps per tick);
  * **paged** — the same engine on the block-pool KV layout at **equal KV
    memory**: the pool holds exactly as many ``--kv-block-size``-token
    blocks as ``--slots`` contiguous stripes, but requests reserve only
    their own budget's worth of blocks, so the long-tail trace packs more
    live requests into the same bytes (``--paged-slots-factor`` × more
    decode slots are offered; blocks are the binding constraint);
  * **static** — the legacy ``serve_batch`` path: requests are grouped
    FIFO into fixed batches of ``--slots``; each batch waits for its last
    member to arrive, then runs prefill + a fixed ``--max-new``-step decode
    scan end-to-end (no early exit, no refill).

Two scheduler-path scenarios ride along (the SLO-admission / prefix-
sharing tentpole's tracked numbers): **mixed-priority** replays a
two-class trace (interactive: short + tight self-calibrated deadlines;
batch: long-tail bulk) under FIFO vs deadline admission and reports
per-class p95 latency and deadline-attainment %; **prefix sharing**
replays a GRPO-group trace (each prompt submitted ``group`` times) through
the paged engine at one fixed pool size with and without radix sharing and
reports peak concurrency at equal KV memory plus blocks saved.

The **chat trace** scenario exercises the content-addressed radix tree
beyond the GRPO shape: a multi-tenant conversation workload (shared
system prompt, per-tenant preambles, growing multi-turn histories,
fan-out retries) runs unshared, tree-shared, and tree-shared through the
KV-aware disagg router (two prefill engines), and reports the
blocks-saved ratio against both the unshared run and the best a flat
exact-match index could do (``radix.saved_over_flat`` — the tree's
cross-request partial-prefix margin), TTFT speedup, requests KV-routed,
and a greedy token-equality bit (``radix.tokens_match``).

Both timelines start at the first arrival; useful tokens are counted
identically (per-request budget).  Response lengths are modeled entirely
by the budgets — the EOS channel is disabled in both servers (random
weights emit EOS at random, which would make the two servers decode
different useful-token totals and add noise to the comparison; EOS-driven
slot recycling is covered by tests/test_serve_engine.py).  Reports token
throughput, request latency (mean / p95), time-to-first-token, slot/block
utilization and peak concurrency, and writes the whole report to
``BENCH_serve.json`` at the repo root so the trajectory is tracked per PR.

    PYTHONPATH=src python benchmarks/serve_engine.py
    PYTHONPATH=src python benchmarks/serve_engine.py --arch rwkv6-7b
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.distributions import sample_response_fractions
from repro.data import tokenizer as tok
from repro.models import build_model
from repro.rl import SamplerConfig, generate
from repro.serve import (DisaggConfig, DisaggRouter, ElasticConfig,
                         ElasticController, Engine, EngineConfig, Request,
                         blocks_for, run_trace)

PROMPT_BUCKETS = (8, 16)
NO_EOS = -1           # lengths come from budgets; see module docstring


def make_trace(rng: np.random.Generator, n: int, rate: float, cap: int):
    """Poisson arrivals + lognormal (long-tail) decode budgets + bucketed
    prompts. Returns a list of Requests (prompts are PAD-left-padded to a
    bucket so both servers compile O(#buckets) prefill variants)."""
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n))
    arrivals -= arrivals[0]                       # timeline starts at t=0
    budgets = np.maximum(
        1, (sample_response_fractions(rng, n) * cap).astype(int))
    reqs = []
    for i in range(n):
        # operand width 2..6 digits so both prompt buckets really occur
        hi = 10 ** int(rng.integers(2, 7))
        text = f"{int(rng.integers(10, hi))}+{int(rng.integers(10, hi))}="
        ids = tok.encode(text, bos=True)
        bucket = next(b for b in PROMPT_BUCKETS if b >= len(ids))
        prompt = tok.pad_batch([ids], bucket)[0]
        reqs.append(Request(rid=i, prompt=prompt,
                            max_new_tokens=int(budgets[i]),
                            arrival_time=float(arrivals[i])))
    return reqs


def run_static(model, params, reqs, batch_size: int, max_new: int,
               seed: int = 0):
    """Static-batch timeline: FIFO batches of ``batch_size``; batch i starts
    at max(prev batch end, its last member's arrival) and costs one
    measured ``generate`` wall time (fixed ``max_new`` decode steps)."""
    key = jax.random.PRNGKey(seed)
    sampler = SamplerConfig(max_new_tokens=max_new, temperature=0.0,
                            eos_id=NO_EOS)
    t_free = 0.0
    latencies, ttfts, useful = [], [], 0
    for i in range(0, len(reqs), batch_size):
        batch = reqs[i:i + batch_size]
        plen = max(r.prompt_len for r in batch)
        prompts = jnp.asarray(np.stack([
            tok.pad_batch([r.prompt.tolist()], plen)[0] for r in batch]))
        t0 = time.perf_counter()
        out = generate(model, params, prompts, key, sampler)
        jax.block_until_ready(out["completions"])
        wall = time.perf_counter() - t0
        start = max(t_free, max(r.arrival_time for r in batch))
        end = start + wall
        t_free = end
        mask = np.asarray(out["mask"])
        for j, r in enumerate(batch):
            n_eos = int(mask[j].sum())
            useful += min(n_eos, r.max_new_tokens)
            latencies.append(end - r.arrival_time)
            # the one-shot generate only materialises tokens at batch end
            ttfts.append(end - r.arrival_time)
    lat = np.array(latencies)
    return {
        "makespan_s": t_free,
        "tokens": useful,
        "tok_per_s": useful / max(t_free, 1e-9),
        "latency_mean_s": float(lat.mean()),
        "latency_p95_s": float(np.quantile(lat, 0.95)),
        "ttft_mean_s": float(np.mean(ttfts)),
    }


def _strip_outputs(report: dict) -> dict:
    return {k: v for k, v in report.items() if k != "outputs"}


# ---------------------------------------------------------------------------
# Scenario: mixed-priority traffic under deadline-aware admission
# ---------------------------------------------------------------------------
def _class_stats(outputs, cls):
    outs = [o for o in outputs if o.priority == cls]
    lat = np.array([o.finish_time - o.arrival_time for o in outs])
    met = sum(o.finish_time <= o.deadline for o in outs
              if o.deadline is not None)
    n_dl = sum(o.deadline is not None for o in outs)
    return {
        "n": len(outs),
        "latency_p95_s": float(np.quantile(lat, 0.95)) if len(lat) else 0.0,
        "latency_mean_s": float(lat.mean()) if len(lat) else 0.0,
        "deadline_attainment": met / n_dl if n_dl else 1.0,
    }


def run_priority_scenario(model, params, rng, *, n: int, rate: float,
                          cap: int, slots: int, block_size: int):
    """Two traffic classes through one engine, FIFO vs deadline admission.

    *Interactive* requests (priority 1, ~1/3 of traffic) have short decode
    budgets and tight deadlines; *batch* requests (priority 0) are the
    long-tail bulk with loose deadlines.  Deadlines are self-calibrated
    from a FIFO dry run (per-token service latency measured on this
    machine, so attainment is meaningful on any runner), then the same
    deadline-tagged trace replays under ``--sched fifo`` and ``--sched
    deadline``.  The deadline policy's head skipping should buy the
    interactive class p95/attainment at bounded cost to batch traffic —
    the per-class numbers below are the tracked evidence.
    """
    reqs = make_trace(rng, n, rate, cap)
    interactive = rng.random(n) < (1 / 3)
    for r, it in zip(reqs, interactive):
        if it:                              # short, urgent
            r.priority = 1
            r.max_new_tokens = max(1, r.max_new_tokens // 4)
        r.job_id = "interactive" if it else "batch"
    max_len = max(PROMPT_BUCKETS) + cap

    def fresh(sched):
        return Engine(model, params, EngineConfig(
            num_slots=slots, max_seq_len=max_len, temperature=0.0,
            eos_id=NO_EOS, block_size=block_size, sched=sched))

    # calibration: measure this machine's per-token service latency, then
    # rescale arrivals AND deadlines by it — offered load and slack are
    # expressed in service-time units, so queueing depth (and hence the
    # fifo-vs-deadline contrast) is comparable across runner speeds
    calib = run_trace(fresh("fifo"), reqs)
    # per-request per-token wall latency when the pool is busy: one decode
    # step serves all slots at once, so a single request sees roughly
    # slots / aggregate-throughput per token.  (Per-request timestamps are
    # too coarse here: fused decode blocks deliver a short request's whole
    # budget in one host-visible step.)
    per_tok = slots / max(calib["tok_per_s"], 1e-9)
    mean_budget = float(np.mean([r.max_new_tokens for r in reqs]))
    overload = 1.3                          # offered load vs service capacity
    gap = mean_budget * per_tok / (slots * overload)
    arrivals = np.cumsum(rng.exponential(gap, size=n))
    arrivals -= arrivals[0]
    for r, t in zip(reqs, arrivals):
        r.arrival_time = float(t)
        slack = 4.0 if r.priority else 10.0
        r.deadline = (r.arrival_time
                      + slack * per_tok * (r.max_new_tokens + r.prompt_len))

    out = {"config": {"n": n, "interactive_frac": float(interactive.mean()),
                      "overload": overload},
           "per_token_calib_s": per_tok}
    for sched in ("fifo", "deadline"):
        res = run_trace(fresh(sched), reqs)
        out[sched] = {
            "tok_per_s": res["tok_per_s"],
            "deadline_attainment": res.get("deadline_attainment", 1.0),
            "interactive": _class_stats(res["outputs"], 1),
            "batch": _class_stats(res["outputs"], 0),
        }
    out["attainment_gain_interactive"] = (
        out["deadline"]["interactive"]["deadline_attainment"]
        - out["fifo"]["interactive"]["deadline_attainment"])
    return out


# ---------------------------------------------------------------------------
# Scenario: GRPO-group traffic with radix prefix sharing at equal KV memory
# ---------------------------------------------------------------------------
def run_prefix_scenario(model, params, rng, *, n_groups: int, group: int,
                        rate: float, block_size: int):
    """GRPO-shaped trace (every prompt submitted ``group`` times, members
    arriving together) through the paged engine at one fixed KV pool size,
    with and without radix prefix sharing.

    Sharing turns each group's ``group`` prompt copies into one prefill
    plus pinned blocks, so paged admission — which gates on *net new*
    blocks — packs strictly more live requests into the same KV bytes.
    Tracked: peak concurrency both ways (the admitted-at-equal-memory
    claim), blocks saved and the saved fraction of all prompt-block
    traffic, prefill hit counts, and throughput.
    """
    bs = block_size
    prompt_bucket = 16                      # 2 full KV blocks per prompt
    cap = 16
    max_len = prompt_bucket + cap
    stripes = 3                             # pool = 3 contiguous stripes
    num_blocks = stripes * blocks_for(max_len, bs)
    slots = 2 * stripes + 2                 # slots non-binding; blocks bind
    arrivals = np.cumsum(rng.exponential(group / rate, size=n_groups))
    arrivals -= arrivals[0]
    reqs, rid = [], 0
    prompt_blocks_total = 0
    for gi in range(n_groups):
        hi = 10 ** int(rng.integers(4, 7))  # wide operands: bucket-16 prompt
        text = f"{int(rng.integers(1000, hi))}+{int(rng.integers(1000, hi))}="
        ids = tok.encode(text, bos=True)
        prompt = tok.pad_batch([ids], prompt_bucket)[0]
        budgets = np.maximum(1, (sample_response_fractions(rng, group)
                                 * cap).astype(int))
        for m in range(group):
            reqs.append(Request(
                rid=rid, prompt=prompt, max_new_tokens=int(budgets[m]),
                arrival_time=float(arrivals[gi]), prefix_key=("g", gi)))
            prompt_blocks_total += prompt_bucket // bs
            rid += 1

    def fresh(share: bool):
        return Engine(model, params, EngineConfig(
            num_slots=slots, max_seq_len=max_len, temperature=0.0,
            eos_id=NO_EOS, block_size=1, kv_layout="paged",
            kv_block_size=bs, num_kv_blocks=num_blocks,
            prefix_share=share))

    for share in (False, True):             # warmup: compile both paths
        warm = fresh(share)
        for j in range(2):
            warm.submit(Request(rid=-1 - j,
                                prompt=np.full(prompt_bucket, tok.PAD,
                                               np.int32),
                                max_new_tokens=1, prefix_key=("w", 0)))
        warm.run()

    runs = {}
    for name, share in (("unshared", False), ("shared", True)):
        res = run_trace(fresh(share), reqs)
        runs[name] = {
            "tok_per_s": res["tok_per_s"],
            "latency_p95_s": res["latency_p95_s"],
            "peak_active": res["peak_active"],
            "peak_kv_blocks": res["peak_kv_blocks"],
        }
        if "prefix" in res:
            runs[name]["prefix"] = res["prefix"]
    saved = runs["shared"]["prefix"]["blocks_saved"]
    return {
        "config": {"n_groups": n_groups, "group": group,
                   "kv_block_size": bs, "num_kv_blocks": num_blocks,
                   "slots": slots, "prompt_bucket": prompt_bucket,
                   "cap": cap},
        "unshared": runs["unshared"],
        "shared": runs["shared"],
        "blocks_saved": saved,
        "blocks_saved_ratio": saved / max(prompt_blocks_total, 1),
        "extra_concurrency_at_equal_memory": (
            runs["shared"]["peak_active"] - runs["unshared"]["peak_active"]),
    }


# ---------------------------------------------------------------------------
# Scenario: multi-tenant chat trace through the content-addressed radix tree
# ---------------------------------------------------------------------------
def run_chat_scenario(model, params, rng, *, n_tenants: int = 3,
                      turns: int = 3, fanout: int = 3, block_size: int = 4,
                      max_new: int = 6, turn_gap_s: float = 0.25,
                      repeats: int = 2):
    """Multi-tenant multi-turn chat replay: the radix tree's cross-request /
    cross-tenant / cross-turn sharing against two baselines.

    The trace is built from block-aligned content chunks: a **system**
    preamble (2 blocks) shared by every tenant, one **tenant** chunk, and
    per turn a **user** chunk plus an **assistant** chunk appended to the
    history — so turn ``k``'s prompt extends turn ``k-1``'s registered
    path, and each turn is submitted ``fanout`` times (parallel
    candidates over the same history, the chat analogue of a GRPO
    group).  Nothing carries a ``prefix_key``: all sharing is by content.

    Three arms at identical pool sizes: **unshared** paged engine,
    **shared** (radix tree), and **disagg** — two prefill engines behind
    KV-aware routing, each with its own tree, so repeats steer to their
    prefix holder (``kv_routed``).  Tracked (CI-floored as ``radix.*``):

    * ``blocks_saved_ratio`` — shared prompt blocks / all prompt-block
      traffic.  Must beat ``flat_index_ceiling``, the analytic best a
      flat per-group exact-duplicate index (the pre-radix design) could
      reach on this trace — only the ``fanout`` copies of one prompt can
      share there, never cross-turn or cross-tenant prefixes.
    * ``ttft_speedup`` — unshared/shared mean TTFT: exact repeats admit
      with zero prefill compute, extensions prefill only their new
      blocks.
    * ``tokens_match`` — greedy outputs bit-identical across all arms.
    """
    bs = block_size

    def chunk(n_blocks):
        # byte-range ids only: no PAD/BOS/EOS in synthetic chat content
        return rng.integers(1, 256, size=n_blocks * bs).astype(np.int32)

    # heavy system preamble + multi-block chat turns: prefill is the
    # dominant per-request cost, which is exactly what exact hits skip
    sys_c = chunk(12)
    hist = [np.concatenate([sys_c, chunk(2)]) for _ in range(n_tenants)]
    reqs, rid, total_blocks, flat_dup, unique = [], 0, 0, 0, set()
    for k in range(turns):
        for t in range(n_tenants):
            prompt = np.concatenate([hist[t], chunk(2)])
            n_blocks = len(prompt) // bs
            for _ in range(fanout):
                # turns arrive in waves: turn k routes (and matches)
                # against the trees turn k-1 registered
                reqs.append(Request(rid=rid, prompt=prompt.copy(),
                                    max_new_tokens=max_new,
                                    arrival_time=k * turn_gap_s))
                total_blocks += n_blocks
                rid += 1
            # a flat exact-duplicate index shares only the non-donor copies
            flat_dup += (fanout - 1) * n_blocks
            for d in range(n_blocks):
                unique.add(prompt[d * bs:(d + 1) * bs].tobytes())
            hist[t] = np.concatenate([prompt, chunk(2)])
    max_len = max(r.total_budget for r in reqs)
    slots = n_tenants * fanout
    # generous pool: tree pins + every wave live, no eviction noise
    num_blocks = slots * blocks_for(max_len, bs) + 2 * len(unique)

    def mono(share: bool):
        return Engine(model, params, EngineConfig(
            num_slots=slots, max_seq_len=max_len, temperature=0.0,
            eos_id=NO_EOS, block_size=1, kv_layout="paged",
            kv_block_size=bs, num_kv_blocks=num_blocks,
            prefix_share=share))

    def disagg():
        return DisaggRouter(model, params, DisaggConfig(
            prefill_slots=2, decode_slots=slots, max_seq_len=max_len,
            temperature=0.0, eos_id=NO_EOS, kv_layout="paged",
            kv_block_size=bs, decode_kv_blocks=num_blocks,
            prefix_share=True, prefill_engines=2, kv_routing="kv_aware"))

    arms, toks, kv_routed, shared_stats = {}, {}, 0, None
    for name, fresh in (("unshared", lambda: mono(False)),
                        ("shared", lambda: mono(True)),
                        ("disagg_kv_aware", disagg)):
        runs = []
        for i in range(repeats + 1):        # first run is compile warmup
            srv = fresh()
            res = run_trace(srv, reqs)
            if i:
                runs.append(res)
        best = min(runs, key=lambda r: r["makespan_s"])
        arms[name] = {"tok_per_s": best["tok_per_s"],
                      "ttft_mean_s": best["ttft_mean_s"],
                      "latency_p95_s": best["latency_p95_s"]}
        toks[name] = {o.rid: list(map(int, o.tokens))
                      for o in best["outputs"]}
        if name == "shared":
            shared_stats = {"hits": srv.radix.hits,
                            "partial_hits": srv.radix.partial_hits,
                            "misses": srv.radix.misses,
                            "blocks_saved": srv.metrics().blocks_saved}
            arms[name]["prefix"] = shared_stats
        elif name == "disagg_kv_aware":
            snap = srv.metrics()
            kv_routed = snap.kv_routed
            arms[name]["prefix"] = {
                "hits": snap.prefix_hits,
                "partial_hits": snap.prefix_partial_hits,
                "blocks_saved": snap.blocks_saved}
            arms[name]["kv_routed"] = kv_routed

    saved = shared_stats["blocks_saved"]
    return {
        "config": {"n_tenants": n_tenants, "turns": turns, "fanout": fanout,
                   "kv_block_size": bs, "num_kv_blocks": num_blocks,
                   "slots": slots, "requests": len(reqs),
                   "prompt_blocks_total": total_blocks,
                   "unique_content_blocks": len(unique)},
        "unshared": arms["unshared"],
        "shared": arms["shared"],
        "disagg_kv_aware": arms["disagg_kv_aware"],
        "blocks_saved": saved,
        "blocks_saved_ratio": saved / max(total_blocks, 1),
        # analytic ceilings on this trace: a flat per-group index can only
        # dedupe exact prompt copies; the tree's own bound is every block
        # re-prefilled at most never (unique content prefills once)
        "flat_index_ceiling": flat_dup / max(total_blocks, 1),
        "radix_ideal_ratio": (total_blocks - len(unique))
        / max(total_blocks, 1),
        "saved_over_flat": (saved - flat_dup) / max(total_blocks, 1),
        "ttft_speedup": (arms["unshared"]["ttft_mean_s"]
                         / max(arms["shared"]["ttft_mean_s"], 1e-9)),
        "kv_routed": kv_routed,
        "tokens_match": int(toks["unshared"] == toks["shared"]
                            == toks["disagg_kv_aware"]),
    }


# ---------------------------------------------------------------------------
# Scenario: disaggregated prefill/decode router, pool-ratio sweep
# ---------------------------------------------------------------------------
def run_disagg_scenario(model, params, rng, *, n: int, rate: float,
                        cap: int, slots: int, block_size: int,
                        kv_block_size: int):
    """The same trace through a monolithic paged engine and through the
    prefill/decode router at *equal total pools*: every split keeps
    ``prefill_slots + decode_slots == slots`` and splits the block pool in
    the same proportion, so any throughput difference is pure routing +
    KV-handle transfer cost, and the ratio sweep shows the independent
    pool-sizing knob doing its job (decode-heavy splits win this decode-
    dominated trace).  Deadlines are self-calibrated from the monolithic
    run so attainment is comparable across runners.  Tracked:
    ``tok_per_s_ratio_vs_monolithic`` (the CI floor: disaggregation must
    keep >= 0.9x monolithic throughput at equal resources) and
    ``transfer_efficiency`` (1 - transfer-time share of serving time).
    """
    reqs = make_trace(rng, n, rate, cap)
    max_len = max(PROMPT_BUCKETS) + cap
    total_blocks = slots * blocks_for(max_len, kv_block_size)
    prompt_blocks = blocks_for(max(PROMPT_BUCKETS), kv_block_size)

    def mono():
        return Engine(model, params, EngineConfig(
            num_slots=slots, max_seq_len=max_len, temperature=0.0,
            eos_id=NO_EOS, block_size=block_size, kv_layout="paged",
            kv_block_size=kv_block_size, num_kv_blocks=total_blocks))

    def router(pf_slots: int):
        # split the block pool in slot proportion, but keep each side
        # large enough to make progress: prefill holds a whole prompt
        # (plus one pinned handle), decode a whole worst-case request
        pf_blocks = max(round(total_blocks * pf_slots / slots),
                        2 * prompt_blocks)
        pf_blocks = min(pf_blocks,
                        total_blocks - blocks_for(max_len, kv_block_size))
        return DisaggRouter(model, params, DisaggConfig(
            prefill_slots=pf_slots, decode_slots=slots - pf_slots,
            max_seq_len=max_len, temperature=0.0, eos_id=NO_EOS,
            block_size=block_size, kv_layout="paged",
            kv_block_size=kv_block_size, prefill_kv_blocks=pf_blocks,
            decode_kv_blocks=total_blocks - pf_blocks))

    ratios = sorted({1, slots // 2, slots - 1})
    # calibrate deadlines off the monolithic engine (also its warmup)
    calib = run_trace(mono(), [Request(rid=r.rid, prompt=r.prompt,
                                       max_new_tokens=r.max_new_tokens,
                                       arrival_time=r.arrival_time)
                               for r in reqs])
    per_tok = slots / max(calib["tok_per_s"], 1e-9)
    for r in reqs:
        r.deadline = (r.arrival_time
                      + 6.0 * per_tok * (r.max_new_tokens + r.prompt_len))
    for pf in ratios:                      # warmup: each decode-pool shape
        warm = router(pf)
        for b in PROMPT_BUCKETS:
            warm.submit(Request(rid=-b, prompt=np.full(b, tok.PAD, np.int32),
                                max_new_tokens=1))
        warm.run()

    mono_res = run_trace(mono(), reqs)
    out = {"config": {"n": n, "slots": slots, "total_kv_blocks": total_blocks,
                      "kv_block_size": kv_block_size, "ratios": ratios},
           "monolithic": {
               "tok_per_s": mono_res["tok_per_s"],
               "ttft_mean_s": mono_res["ttft_mean_s"],
               "latency_p95_s": mono_res["latency_p95_s"],
               "deadline_attainment": mono_res.get("deadline_attainment",
                                                   1.0)},
           "splits": []}
    best = None
    for pf in ratios:
        rt = router(pf)
        res = run_trace(rt, reqs)
        split = {
            "ratio": f"{pf}:{slots - pf}",
            "prefill_slots": pf, "decode_slots": slots - pf,
            "prefill_kv_blocks": rt.prefill.slots.alloc.num_blocks,
            "decode_kv_blocks": rt.decode.slots.alloc.num_blocks,
            "tok_per_s": res["tok_per_s"],
            "ttft_mean_s": res["ttft_mean_s"],
            "latency_p95_s": res["latency_p95_s"],
            "deadline_attainment": res.get("deadline_attainment", 1.0),
            "transfers": rt.metrics().transfers,
            "transfer_time_s": rt.metrics().transfer_time_s,
            "transfer_overhead_frac": rt.metrics().transfer_overhead_frac,
            "peak_kv_blocks_decode": res["peak_kv_blocks"],
        }
        out["splits"].append(split)
        if best is None or split["tok_per_s"] > best["tok_per_s"]:
            best = split
    out["best_ratio"] = best["ratio"]
    out["tok_per_s_ratio_vs_monolithic"] = (
        best["tok_per_s"] / max(mono_res["tok_per_s"], 1e-9))
    out["transfer_efficiency"] = 1.0 - best["transfer_overhead_frac"]
    return out


# ---------------------------------------------------------------------------
# Scenario: kernel decode path (pallas backend) + int8 KV at equal bytes
# ---------------------------------------------------------------------------
def _paged_block_bytes(model, max_len: int, bs: int, kv_dtype):
    """Bytes one KV block costs in the pool (scales included for int8),
    from the abstract cache shapes — no allocation."""
    cache = jax.eval_shape(lambda: model.init_paged_cache(
        1, max_len, block_size=bs, num_blocks=1, kv_dtype=kv_dtype))
    paged = set(model.paged_cache_names())
    scales = set(model.scale_cache_names()) if kv_dtype == "int8" else set()
    total = 0
    for name, leaf in cache.items():
        if name in paged or name in scales:
            # (L, NB+1, bs, *rest): per-block cost excludes the null block
            per_block = int(np.prod(leaf.shape)) // leaf.shape[1]
            total += per_block * jnp.dtype(leaf.dtype).itemsize
    return total


def run_kernel_scenario(model, params, rng, *, n: int, rate: float,
                        cap: int, slots: int, block_size: int,
                        kv_block_size: int):
    """Kernel-path scenarios for the live Pallas decode path.

    **fp32 vs int8 at equal KV bytes** (the tracked floor): the int8 pool
    holds as many blocks as the fp32 pool's byte budget buys once blocks
    are quantized (~1/4 the bytes incl. per-position scales), so paged
    admission — which gates on blocks — packs strictly more live requests
    into the same memory.  ``int8_admit_ratio`` = peak concurrent int8 /
    fp32 requests on the same long-tail trace; the CI floor demands
    >= 1.5x.  Both runs use the jnp backend (the admission math is
    backend-blind), and slot counts scale with the block budget so blocks
    stay the binding resource.

    **jnp vs pallas** (informational): the same short paged trace through
    both decode backends.  On CPU the pallas kernels run in interpret
    mode — a correctness path, not a speed path — so the tok/s ratio is
    recorded but not guarded; ``tokens_match`` is the hard claim (greedy
    bit-exactness under serving conditions, budget-truncated trace).
    """
    max_len = max(PROMPT_BUCKETS) + cap
    bs = kv_block_size
    f32_blocks = slots * blocks_for(max_len, bs)
    bytes_f32 = _paged_block_bytes(model, max_len, bs, None)
    bytes_i8 = _paged_block_bytes(model, max_len, bs, "int8")
    i8_blocks = (f32_blocks * bytes_f32) // bytes_i8
    byte_budget = f32_blocks * bytes_f32

    reqs = make_trace(rng, n, rate, cap)

    def fresh(kv_dtype, num_blocks, num_slots, backend="jnp"):
        return Engine(model, params, EngineConfig(
            num_slots=num_slots, max_seq_len=max_len, temperature=0.0,
            eos_id=NO_EOS, block_size=block_size, kv_layout="paged",
            kv_block_size=bs, num_kv_blocks=int(num_blocks),
            kv_dtype=kv_dtype, kernel_backend=backend))

    # slots scale with the block budget (extra paged slots are nearly
    # free — no contiguous stripe), so blocks bind admission on both sides
    f32_slots, i8_slots = 2 * slots, 4 * slots
    admit = {}
    for name, kv_dtype, nb, ns in (("fp32", None, f32_blocks, f32_slots),
                                   ("int8", "int8", i8_blocks, i8_slots)):
        runs = [run_trace(fresh(kv_dtype, nb, ns), reqs) for _ in range(2)]
        best = min(runs, key=lambda r: r["makespan_s"])
        admit[name] = {
            "num_kv_blocks": int(nb), "num_slots": ns,
            "pool_bytes": int(nb * (bytes_i8 if kv_dtype else bytes_f32)),
            "tok_per_s": best["tok_per_s"],
            "peak_active": max(r["peak_active"] for r in runs),
            "peak_kv_blocks": max(r["peak_kv_blocks"] for r in runs),
        }
    ratio = admit["int8"]["peak_active"] / max(admit["fp32"]["peak_active"],
                                               1)

    # jnp vs pallas on a short trace (interpret mode is slow on CPU)
    short = [Request(rid=r.rid, prompt=r.prompt,
                     max_new_tokens=min(r.max_new_tokens, 16),
                     arrival_time=r.arrival_time)
             for r in reqs[:max(8, n // 6)]]
    backends = {}
    toks = {}
    for backend in ("jnp", "pallas"):
        res = run_trace(fresh(None, f32_blocks, slots, backend), short)
        backends[backend] = {"tok_per_s": res["tok_per_s"],
                             "ttft_mean_s": res["ttft_mean_s"]}
        toks[backend] = {o.rid: list(map(int, o.tokens))
                         for o in res["outputs"]}
    return {
        "config": {"n": n, "slots": slots, "kv_block_size": bs,
                   "byte_budget": int(byte_budget),
                   "block_bytes_fp32": bytes_f32,
                   "block_bytes_int8": bytes_i8,
                   "pallas_trace_n": len(short)},
        "fp32": admit["fp32"],
        "int8": admit["int8"],
        "int8_blocks_per_fp32_block": bytes_f32 / bytes_i8,
        "int8_admit_ratio": ratio,
        "jnp": backends["jnp"],
        "pallas": backends["pallas"],
        "pallas_vs_jnp_tok_per_s_ratio": (
            backends["pallas"]["tok_per_s"]
            / max(backends["jnp"]["tok_per_s"], 1e-9)),
        "tokens_match": toks["jnp"] == toks["pallas"],
    }


# ---------------------------------------------------------------------------
# Scenario: elastic capacity under diurnal / bursty load
# ---------------------------------------------------------------------------
def run_elastic_scenario(model, params, rng, *, n: int, cap: int,
                         slots: int, block_size: int):
    """Closed-loop autoscaling (``serve.elastic``) vs a statically
    peak-provisioned engine on a diurnal trace, plus the two admission-
    control guarantees.

    The trace alternates **burst waves** (a wave's worth of requests
    arriving together — the diurnal peak) with **trickle valleys**
    (near-serial arrivals at roughly one request per solo service time).
    Gaps and deadlines are expressed in service-time units measured by a
    calibration run at peak capacity, so the diurnal shape — and hence
    the controller's grow/shrink behaviour — survives runner-speed
    differences.  The same deadline-stamped trace replays through a
    static engine pinned at the peak rung and through the elastic
    controller starting at the peak rung; shrinking through the valleys
    is where the capacity-seconds saving comes from.  (A full diurnal
    replay — the paper's million-request day — is this same code at
    higher ``n``; the CI trace keeps the wave structure at bench scale.)

    Tracked (CI-guarded as ``elastic.*``):

    * ``capacity_seconds_ratio`` — elastic capacity-seconds over the
      peak-provisioned static baseline (CI ceiling: <= 0.9 — elasticity
      must actually return capacity);
    * ``attainment_delta`` — elastic minus static deadline attainment on
      the identical trace (floor: >= 0 — returned capacity must not cost
      attainment);
    * ``subsat_shed_free`` — with admission control *armed*, a
      sub-saturation trace sheds exactly nothing (the predictor is
      conservative by construction);
    * ``tokens_match`` — greedy token equality: elastic output is
      bit-identical to static per request; in the overload leg, admitted
      non-degraded requests are bit-identical and degraded requests are
      an exact prefix of their unclamped static tokens;
    * ``overload_accounted`` — under genuine overload with tight
      deadlines every arrival is finished or recorded-shed (sheds are
      never silent).
    """
    max_len = max(PROMPT_BUCKETS) + cap
    ladder = tuple(sorted({max(1, slots // 4), max(1, slots // 2), slots}))

    def fresh(ns):
        return Engine(model, params, EngineConfig(
            num_slots=ns, max_seq_len=max_len, temperature=0.0,
            eos_id=NO_EOS, block_size=block_size))

    for rung in ladder:                 # compile every rung off-trace
        warm = fresh(rung)
        for b in PROMPT_BUCKETS:
            warm.submit(Request(rid=-b, prompt=np.full(b, tok.PAD, np.int32),
                                max_new_tokens=1))
        warm.run()

    prompts = []
    for _ in range(n):
        hi = 10 ** int(rng.integers(2, 7))
        text = f"{int(rng.integers(10, hi))}+{int(rng.integers(10, hi))}="
        ids = tok.encode(text, bos=True)
        bucket = next(b for b in PROMPT_BUCKETS if b >= len(ids))
        prompts.append(tok.pad_batch([ids], bucket)[0])
    budgets = np.maximum(
        1, (sample_response_fractions(rng, n) * cap).astype(int))

    calib = run_trace(fresh(slots),
                      [Request(rid=i, prompt=prompts[i],
                               max_new_tokens=int(budgets[i]))
                       for i in range(n)], realtime=False)
    per_tok = slots / max(calib["tok_per_s"], 1e-9)  # solo per-token service
    mean_budget = float(budgets.mean())

    # diurnal arrivals: two "days" of burst -> valley
    segs = ("burst", "valley", "burst", "valley")
    counts = [round(n * f) for f in (0.3, 0.2, 0.3, 0.0)]
    counts[3] = n - sum(counts[:3])
    serial_gap = 1.3 * cap * per_tok    # one request per solo service time
    arr, t = [], 0.0
    for kind, count in zip(segs, counts):
        if kind == "burst":
            arr.extend([t] * count)
            t += 1.2 * count * mean_budget * per_tok / slots
        else:
            for _ in range(count):
                arr.append(t)
                t += serial_gap

    def mk(slack):
        return [Request(rid=i, prompt=prompts[i],
                        max_new_tokens=int(budgets[i]), arrival_time=arr[i],
                        deadline=arr[i] + slack * per_tok
                        * (int(budgets[i]) + len(prompts[i])))
                for i in range(n)]

    def ctrl(**over):
        kw = dict(ladder=ladder, interval_s=0.05, cooldown_s=0.15)
        kw.update(over)
        return ElasticController(ElasticConfig(**kw))

    static_res = run_trace(fresh(slots), mk(12.0), realtime=False)
    c_main = ctrl()
    ela_res = run_trace(fresh(slots), mk(12.0), realtime=False,
                        controller=c_main)
    e = ela_res["elastic"]
    ref = {o.rid: list(map(int, o.tokens)) for o in static_res["outputs"]}
    got = {o.rid: list(map(int, o.tokens)) for o in ela_res["outputs"]}
    main_exact = got == ref
    att_static = static_res.get("deadline_attainment", 1.0)
    att_elastic = ela_res.get("deadline_attainment", 1.0)

    # sub-saturation, admission control ARMED: sheds must be exactly zero
    n_sub = min(max(n // 3, 8), 16)
    sub_reqs = [Request(rid=i, prompt=prompts[i],
                        max_new_tokens=int(budgets[i]),
                        arrival_time=i * serial_gap,
                        deadline=i * serial_gap + 12.0 * per_tok
                        * (int(budgets[i]) + len(prompts[i])))
                for i in range(n_sub)]
    sub_res = run_trace(fresh(slots), sub_reqs, realtime=False,
                        controller=ctrl(shed=True))
    subsat_shed_free = int(sub_res["elastic"]["sheds"] == 0)

    # overload: the whole trace as one dense wave with tight deadlines —
    # admission degrades (budget clamps) before it sheds, sheds are
    # recorded, and nothing silently vanishes.  Arrivals are staggered by
    # one service step so the predictor has a measured time-per-token
    # before the queue gets deep (a cold engine admits everything).
    over_gap = per_tok / 3.0
    over_reqs = [Request(rid=i, prompt=prompts[i],
                         max_new_tokens=int(budgets[i]),
                         arrival_time=i * over_gap,
                         deadline=i * over_gap + 1.25 * per_tok
                         * (int(budgets[i]) + len(prompts[i])))
                 for i in range(n)]
    c_over = ctrl(shed=True, min_degrade_tokens=4)
    over_res = run_trace(fresh(slots), over_reqs, realtime=False,
                         controller=c_over)
    oe = over_res["elastic"]
    degraded_to = {d["rid"]: d["to"] for d in oe["degrade_records"]}
    shed_rids = {s["rid"] for s in oe["shed_records"]}
    prefix_ok, exact_ok = True, True
    for o in over_res["outputs"]:
        if o.rid in degraded_to:
            want = ref[o.rid][:degraded_to[o.rid]]
            prefix_ok &= list(map(int, o.tokens)) == want
        else:
            exact_ok &= list(map(int, o.tokens)) == ref[o.rid]
    overload_accounted = int(
        len(over_res["outputs"]) + oe["sheds"] == n
        and oe["sheds"] == len(oe["shed_records"])
        and not shed_rids & {o.rid for o in over_res["outputs"]})
    tokens_match = int(main_exact and prefix_ok and exact_ok)

    return {
        "config": {"n": n, "slots": slots, "ladder": list(ladder),
                   "cap": cap, "per_token_calib_s": per_tok,
                   "segments": list(zip(segs, counts)),
                   "n_subsat": n_sub},
        "static": {"tok_per_s": static_res["tok_per_s"],
                   "latency_p95_s": static_res["latency_p95_s"],
                   "deadline_attainment": att_static},
        "elastic": {"tok_per_s": ela_res["tok_per_s"],
                    "latency_p95_s": ela_res["latency_p95_s"],
                    "deadline_attainment": att_elastic,
                    "resizes": e["resizes"],
                    "resize_log": e["resize_log"],
                    "capacity_log": e["capacity_log"]},
        "capacity_seconds": e["capacity_seconds"],
        "static_capacity_seconds": e["static_capacity_seconds"],
        "capacity_seconds_ratio": e["capacity_seconds_ratio"],
        "attainment_delta": att_elastic - att_static,
        "tokens_match": tokens_match,
        "subsat_sheds": sub_res["elastic"]["sheds"],
        "subsat_shed_free": subsat_shed_free,
        "overload": {"sheds": oe["sheds"], "degrades": oe["degrades"],
                     "shed_frac": oe["sheds"] / n,
                     "class_counts": oe["class_counts"],
                     "degraded_prefix_ok": int(prefix_ok),
                     "admitted_exact_ok": int(exact_ok)},
        "overload_accounted": overload_accounted,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--n-requests", type=int, default=64)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--rate", type=float, default=100.0,
                    help="Poisson arrival rate (req/s); high rate = the "
                         "compute-bound heavy-traffic regime (low rates are "
                         "arrival-limited: the engine then wins on latency/"
                         "TTFT rather than throughput)")
    ap.add_argument("--max-new", type=int, default=48,
                    help="static decode budget / engine per-request cap")
    ap.add_argument("--block-size", type=int, default=8)
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV block for the paged server")
    ap.add_argument("--paged-slots-factor", type=int, default=2,
                    help="paged server offers factor * --slots decode slots "
                         "over the SAME KV memory (blocks bind admission)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="run each server this many times and keep its best "
                         "(min-makespan) run — wall-clock noise rejection on "
                         "shared/throttled CPUs")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--quick", action="store_true",
                    help="small CI workload (fewer requests; best-of-N "
                         "repeats kept for noise rejection); writes "
                         "BENCH_serve_quick.json — the same-config baseline "
                         "the CI bench guard diffs against")
    ap.add_argument("--json", default=None,
                    help="report path ('' disables; default "
                         "BENCH_serve[_quick].json at the repo root)")
    args = ap.parse_args()
    if args.quick:
        # the CI bench guard diffs this report's speedup ratios at 15%
        # tolerance, so the quick trace stays large enough (and best-of-5)
        # to keep run-to-run ratio noise well inside that band
        args.n_requests = 48
        args.repeats = max(args.repeats, 5)
    if args.json is None:
        name = "BENCH_serve_quick.json" if args.quick else "BENCH_serve.json"
        args.json = os.path.join(os.path.dirname(__file__), "..", name)

    model = build_model(args.arch, reduced=True)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    reqs = make_trace(rng, args.n_requests, args.rate, args.max_new)
    max_len = max(PROMPT_BUCKETS) + args.max_new

    # equal KV memory: the paged pool holds exactly --slots contiguous
    # stripes' worth of block capacity; extra decode slots are nearly free
    # (no per-slot stripe), so blocks are the binding admission resource.
    # Architectures with no cache_seq leaves (rwkv6: pure recurrent state)
    # have nothing to page — extra slots there would just be extra state
    # memory, so the equal-memory comparison is skipped.
    num_kv_blocks = args.slots * blocks_for(max_len, args.kv_block_size)
    paged_slots = args.paged_slots_factor * args.slots
    has_paged_kv = bool(model.paged_cache_names())

    def fresh_engine(kv: str):
        if kv == "paged":
            return Engine(model, params, EngineConfig(
                num_slots=paged_slots, max_seq_len=max_len, temperature=0.0,
                eos_id=NO_EOS, block_size=args.block_size, kv_layout="paged",
                kv_block_size=args.kv_block_size,
                num_kv_blocks=num_kv_blocks))
        return Engine(model, params, EngineConfig(
            num_slots=args.slots, max_seq_len=max_len, temperature=0.0,
            eos_id=NO_EOS, block_size=args.block_size))

    # ---- warmup: compile both prompt buckets for engine prefill (both KV
    # layouts) AND the static generate path, plus the decode blocks
    layouts = ("contiguous", "paged") if has_paged_kv else ("contiguous",)
    for kv in layouts:
        warm = fresh_engine(kv)
        for b in PROMPT_BUCKETS:
            warm.submit(Request(rid=-b, prompt=np.full(b, tok.PAD, np.int32),
                                max_new_tokens=1))
        warm.run()
    for b in PROMPT_BUCKETS:
        fake = [Request(rid=-100 - b - j, prompt=np.full(b, tok.PAD, np.int32),
                        max_new_tokens=1, arrival_time=0.0)
                for j in range(args.slots)]
        run_static(model, params, fake, args.slots, args.max_new)

    # ---- timed runs (best-of-N per server; interleaved for fairness)
    eng_runs, pag_runs, sta_runs = [], [], []
    for _ in range(max(args.repeats, 1)):
        eng_runs.append(run_trace(fresh_engine("contiguous"), reqs))
        if has_paged_kv:
            pag_runs.append(run_trace(fresh_engine("paged"), reqs))
        sta_runs.append(run_static(model, params, reqs, args.slots,
                                   args.max_new, seed=args.seed))
    eng_res = min(eng_runs, key=lambda r: r["makespan_s"])
    sta_res = min(sta_runs, key=lambda r: r["makespan_s"])
    # capacity numbers are properties of the trace, not of timing: report
    # the max across repeats so a lucky fast run can't under-state them
    eng_res["peak_active"] = max(r["peak_active"] for r in eng_runs)
    pag_res = None
    if has_paged_kv:
        pag_res = min(pag_runs, key=lambda r: r["makespan_s"])
        pag_res["peak_active"] = max(r["peak_active"] for r in pag_runs)
        pag_res["peak_kv_blocks"] = max(r["peak_kv_blocks"]
                                        for r in pag_runs)
        pag_res["kv_block_utilization"] = (
            pag_res["peak_kv_blocks"] / max(pag_res["kv_blocks_total"], 1))

    # ---- scheduler-path scenarios (tentpole metrics) ----------------------
    pri_res = run_priority_scenario(
        model, params, np.random.default_rng(args.seed + 1),
        n=args.n_requests, rate=args.rate, cap=args.max_new,
        slots=args.slots, block_size=args.block_size)
    pfx_res = None
    if has_paged_kv:
        pfx_res = run_prefix_scenario(
            model, params, np.random.default_rng(args.seed + 2),
            n_groups=max(args.n_requests // 4, 4), group=4, rate=args.rate,
            block_size=max(args.kv_block_size // 2, 4))
    dis_res = None
    if has_paged_kv:
        dis_res = run_disagg_scenario(
            model, params, np.random.default_rng(args.seed + 3),
            n=args.n_requests, rate=args.rate, cap=args.max_new,
            slots=args.slots, block_size=args.block_size,
            kv_block_size=args.kv_block_size)
    ker_res = None
    if has_paged_kv and model.kernel_supported():
        ker_res = run_kernel_scenario(
            model, params, np.random.default_rng(args.seed + 4),
            n=args.n_requests, rate=args.rate, cap=args.max_new,
            slots=args.slots, block_size=args.block_size,
            kv_block_size=args.kv_block_size)
    chat_res = None
    if has_paged_kv:
        chat_res = run_chat_scenario(
            model, params, np.random.default_rng(args.seed + 5))
    ela_res = run_elastic_scenario(
        model, params, np.random.default_rng(args.seed + 6),
        n=args.n_requests, cap=args.max_new,
        slots=args.slots, block_size=args.block_size)

    speedup = eng_res["tok_per_s"] / max(sta_res["tok_per_s"], 1e-9)
    print(f"# {args.arch}: {args.n_requests} reqs, {args.slots} slots, "
          f"rate {args.rate}/s, cap {args.max_new}, block {args.block_size}, "
          f"kv-block {args.kv_block_size} ({num_kv_blocks} blocks = equal "
          f"memory, paged offers {paged_slots} slots)")
    servers = [("engine", eng_res), ("static", sta_res)]
    if pag_res is not None:
        servers.insert(1, ("paged ", pag_res))
    for name, r in servers:
        print(f"{name}: {r['tokens']} tokens in {r['makespan_s']:.2f}s = "
              f"{r['tok_per_s']:.1f} tok/s | latency mean "
              f"{r['latency_mean_s']:.2f}s p95 {r['latency_p95_s']:.2f}s | "
              f"ttft {r['ttft_mean_s']:.2f}s")
    print(f"engine slot utilization: {eng_res['slot_utilization']:.1%}")
    if pag_res is not None:
        print(f"concurrency at equal KV memory: contiguous peaks at "
              f"{eng_res['peak_active']} live requests (slot-capped at "
              f"{args.slots}), paged at {pag_res['peak_active']} "
              f"(block util {pag_res['kv_block_utilization']:.0%})")
    else:
        print(f"{args.arch} has no cache_seq leaves — nothing to page, "
              f"equal-memory paged comparison skipped")
    print(f"throughput speedup (engine/static): {speedup:.2f}x")

    f_i = pri_res["fifo"]["interactive"]
    d_i = pri_res["deadline"]["interactive"]
    print(f"mixed-priority: interactive p95 fifo {f_i['latency_p95_s']:.2f}s"
          f" -> deadline {d_i['latency_p95_s']:.2f}s | attainment "
          f"{f_i['deadline_attainment']:.0%} -> "
          f"{d_i['deadline_attainment']:.0%} (batch "
          f"{pri_res['deadline']['batch']['deadline_attainment']:.0%})")
    if pfx_res is not None:
        print(f"prefix sharing at equal KV memory: peak live "
              f"{pfx_res['unshared']['peak_active']} -> "
              f"{pfx_res['shared']['peak_active']} requests, "
              f"{pfx_res['blocks_saved']} blocks saved "
              f"({pfx_res['blocks_saved_ratio']:.0%} of prompt-block "
              f"traffic), {pfx_res['shared']['prefix']['hits']} prefills "
              f"skipped")
    if dis_res is not None:
        print(f"disagg at equal total pools: best split "
              f"{dis_res['best_ratio']} = "
              f"{dis_res['tok_per_s_ratio_vs_monolithic']:.2f}x monolithic "
              f"tok/s, transfer efficiency "
              f"{dis_res['transfer_efficiency']:.0%} | per-ratio tok/s: "
              + ", ".join(f"{s['ratio']}={s['tok_per_s']:.0f}"
                          for s in dis_res["splits"]))
    if ker_res is not None:
        match = ("tokens identical" if ker_res["tokens_match"]
                 else "TOKEN MISMATCH")
        print(f"kernel path: int8 KV admits {ker_res['int8']['peak_active']} "
              f"vs fp32 {ker_res['fp32']['peak_active']} live requests at "
              f"equal KV bytes ({ker_res['int8_admit_ratio']:.2f}x admit, "
              f"{ker_res['int8_blocks_per_fp32_block']:.1f} blocks per fp32 "
              f"block) | pallas decode "
              f"{ker_res['pallas_vs_jnp_tok_per_s_ratio']:.2f}x jnp tok/s "
              f"({match}; interpret mode off-TPU)")
    if chat_res is not None:
        match = ("tokens identical" if chat_res["tokens_match"]
                 else "TOKEN MISMATCH")
        print(f"chat trace (radix): {chat_res['blocks_saved_ratio']:.0%} of "
              f"prompt blocks shared (flat-index ceiling "
              f"{chat_res['flat_index_ceiling']:.0%}, tree ideal "
              f"{chat_res['radix_ideal_ratio']:.0%}) | ttft "
              f"{chat_res['ttft_speedup']:.2f}x unshared | "
              f"{chat_res['kv_routed']} requests KV-routed across 2 prefill "
              f"engines ({match})")
    match = ("tokens identical" if ela_res["tokens_match"]
             else "TOKEN MISMATCH")
    print(f"elastic (diurnal trace): {ela_res['capacity_seconds_ratio']:.0%} "
          f"capacity-seconds vs peak-provisioned static at attainment delta "
          f"{ela_res['attainment_delta']:+.0%} "
          f"({ela_res['elastic']['resizes']} resizes over ladder "
          f"{ela_res['config']['ladder']}) | sub-saturation sheds "
          f"{ela_res['subsat_sheds']} | overload: "
          f"{ela_res['overload']['degrades']} degraded, "
          f"{ela_res['overload']['sheds']} shed "
          f"({ela_res['overload']['shed_frac']:.0%}), accounted="
          f"{ela_res['overload_accounted']} ({match})")

    if args.json:
        report = {
            "arch": args.arch,
            "config": {
                "n_requests": args.n_requests, "slots": args.slots,
                "rate": args.rate, "max_new": args.max_new,
                "block_size": args.block_size,
                "kv_block_size": args.kv_block_size,
                "num_kv_blocks": num_kv_blocks, "paged_slots": paged_slots,
                "repeats": args.repeats, "seed": args.seed,
                "quick": args.quick,
            },
            "engine": _strip_outputs(eng_res),
            "static": _strip_outputs(sta_res),
            "speedup_engine_vs_static": speedup,
        }
        if pag_res is not None:
            report["paged"] = _strip_outputs(pag_res)
            report["speedup_paged_vs_static"] = (
                pag_res["tok_per_s"] / max(sta_res["tok_per_s"], 1e-9))
            report["paged_extra_concurrency_at_equal_memory"] = (
                pag_res["peak_active"] - eng_res["peak_active"])
        report["priority"] = pri_res
        if pfx_res is not None:
            report["prefix"] = pfx_res
        if dis_res is not None:
            report["disagg"] = dis_res
        if ker_res is not None:
            report["kernel"] = ker_res
        if chat_res is not None:
            report["radix"] = chat_res
        report["elastic"] = ela_res
        path = os.path.abspath(args.json)
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {path}")
    return speedup


if __name__ == "__main__":
    main()
