"""Benchmark regression guard: diff a freshly measured BENCH_*.json against
the committed baseline and fail (exit 1) on a >``--tolerance`` drop.

    python benchmarks/check_regression.py BASELINE CANDIDATE \
        --metrics engine.tok_per_s,speedup_engine_vs_static [--tolerance 0.15]
    python benchmarks/check_regression.py BASELINE CANDIDATE \
        --floors prefix.extra_concurrency_at_equal_memory=1

Metrics are dotted paths into the report JSON.  A metric regresses when
``candidate < baseline * (1 - tolerance)``; higher must be better for every
guarded metric (throughputs, speedup ratios, reclaimed-bubble fractions —
never latencies).  Ratio metrics (mode-vs-mode speedups, bubble fractions)
are machine-independent; absolute tok/s is only comparable when baseline
and candidate ran on the same runner class, which is why CI diffs the
``--quick`` reports whose baselines are refreshed from CI artifacts.

``--floors path=value,...`` adds *absolute* assertions on the candidate
alone — ``candidate >= value`` regardless of the baseline.  This is how
the scheduler-path contracts are guarded: the prefix-sharing engine must
keep admitting at least one extra concurrent request at equal KV memory,
and deadline scheduling must keep its attainment floor — logical
properties of the trace, not timings, so a hard floor is the right guard.

``--ceilings path=value,...`` is the mirror image: ``candidate <= value``.
Used for metrics where *lower* proves the property — the elastic
controller's capacity-seconds ratio vs a peak-provisioned static baseline
must stay at or below 0.9, or autoscaling stopped returning capacity.

The candidate's ``config`` block must match the baseline's (same workload,
seed and sizes) — comparing different workloads is a config error, not a
regression, and exits 2.
"""
from __future__ import annotations

import argparse
import json
import sys


def lookup(report: dict, path: str):
    cur = report
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_*.json")
    ap.add_argument("candidate", help="freshly measured BENCH_*.json")
    ap.add_argument("--metrics", default="",
                    help="comma-separated dotted paths; higher is better")
    ap.add_argument("--tolerance", type=float, default=0.15,
                    help="allowed fractional drop before failing")
    ap.add_argument("--floors", default="",
                    help="comma-separated path=value absolute floors the "
                         "candidate must meet regardless of the baseline")
    ap.add_argument("--ceilings", default="",
                    help="comma-separated path=value absolute ceilings the "
                         "candidate must stay at or below")
    ap.add_argument("--skip-config-check", action="store_true")
    args = ap.parse_args()
    if not args.metrics and not args.floors and not args.ceilings:
        ap.error("nothing to check: pass --metrics, --floors and/or "
                 "--ceilings")

    with open(args.baseline) as f:
        base = json.load(f)
    with open(args.candidate) as f:
        cand = json.load(f)

    if not args.skip_config_check and base.get("config") != cand.get("config"):
        print(f"config mismatch:\n  baseline : {base.get('config')}\n"
              f"  candidate: {cand.get('config')}")
        return 2

    failed = []
    for path in [m.strip() for m in args.metrics.split(",") if m.strip()]:
        b, c = lookup(base, path), lookup(cand, path)
        if b is None or c is None:
            print(f"MISSING  {path}: baseline={b} candidate={c}")
            failed.append(path)
            continue
        floor = b * (1.0 - args.tolerance)
        status = "FAIL" if c < floor else "ok"
        print(f"{status:7s}  {path}: baseline={b:.4g} candidate={c:.4g} "
              f"(floor {floor:.4g}, {(c / b - 1) * 100:+.1f}%)")
        if c < floor:
            failed.append(path)
    for spec in [f.strip() for f in args.floors.split(",") if f.strip()]:
        path, _, floor_s = spec.partition("=")
        floor = float(floor_s)
        c = lookup(cand, path)
        if c is None:
            print(f"MISSING  {path}: candidate={c} (floor {floor:.4g})")
            failed.append(path)
            continue
        status = "FAIL" if c < floor else "ok"
        print(f"{status:7s}  {path}: candidate={c:.4g} "
              f"(absolute floor {floor:.4g})")
        if c < floor:
            failed.append(path)
    for spec in [c.strip() for c in args.ceilings.split(",") if c.strip()]:
        path, _, ceil_s = spec.partition("=")
        ceil = float(ceil_s)
        c = lookup(cand, path)
        if c is None:
            print(f"MISSING  {path}: candidate={c} (ceiling {ceil:.4g})")
            failed.append(path)
            continue
        status = "FAIL" if c > ceil else "ok"
        print(f"{status:7s}  {path}: candidate={c:.4g} "
              f"(absolute ceiling {ceil:.4g})")
        if c > ceil:
            failed.append(path)
    if failed:
        print(f"\nregression in: {', '.join(failed)}")
        return 1
    print("\nno regression beyond tolerance")
    return 0


if __name__ == "__main__":
    sys.exit(main())
