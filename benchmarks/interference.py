"""Paper Table 4: co-execution interference — normalized per-job training
throughput vs solo execution (paper: <=10% overhead)."""
from __future__ import annotations

from benchmarks.common import emit, paper_job
from repro.core import InterGroupScheduler, NodeAllocator, SwitchCosts


SCENARIOS = {
    "temporal": ["Type-A", "Type-A"],
    "trainmux": ["Type-D", "Type-D", "Type-E"],
    "spatial": ["Type-C", "Type-D", "Type-D"],
}


def run():
    for name, types in SCENARIOS.items():
        jobs = [paper_job(t, f"{name}{i}") for i, t in enumerate(types)]
        sched = InterGroupScheduler(NodeAllocator())
        for j in jobs:
            d = sched.schedule(j)
        G = d.group
        res = G.simulate(migration=True, switch=SwitchCosts(),
                         stochastic=False, work_conserving=True)
        # normalized throughput = solo iter time / co-exec iter time,
        # averaged over jobs (1.0 = no interference)
        norm = sum(j.t_solo / res.iter_time[j.job_id] for j in jobs) / len(jobs)
        emit(f"table4_{name}_norm_throughput", norm,
             "vs solo=1.0 (paper: 0.91-0.98)")


if __name__ == "__main__":
    run()
