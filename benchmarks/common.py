"""Shared helpers for the paper-artifact benchmarks."""
from __future__ import annotations

from repro.configs.paper_jobs import PAPER_JOB_TYPES
from repro.core import (CoExecutionGroup, RLJob, SwitchCosts, from_profile,
                        H20, H800)

ROWS: list[tuple[str, float, str]] = []


def emit(name: str, value: float, derived: str = "") -> None:
    ROWS.append((name, value, derived))
    print(f"{name},{value:.6g},{derived}")


def paper_job(type_name: str, jid: str, slo: float = 2.0) -> RLJob:
    return from_profile(PAPER_JOB_TYPES[type_name], jid, slo=slo,
                        duration=10 * 3600.0)


def solo_cost_eff(job: RLJob) -> float:
    """Iterations per $ for dedicated disaggregated pools."""
    cost_h = (job.n_roll_gpus * H20.price_per_gpu_hour
              + job.n_train_gpus * H800.price_per_gpu_hour)
    iters_per_h = 3600.0 / job.t_solo
    return iters_per_h / cost_h


def group_cost_eff(G: CoExecutionGroup, migration=True) -> float:
    res = G.simulate(migration=migration, switch=SwitchCosts(),
                     work_conserving=True)
    iters_per_h = sum(3600.0 / t for t in res.iter_time.values())
    return iters_per_h / G.cost_per_hour()


def verl_cost_eff(job: RLJob) -> float:
    """Colocated: all phases on H800; rollout pays the bandwidth mismatch."""
    slow = H20.hbm_tbps / H800.hbm_tbps
    iter_t = job.t_roll * slow + job.t_train
    cost_h = job.n_train_gpus * H800.price_per_gpu_hour
    return (3600.0 / iter_t) / cost_h


def gavel_cost_eff(G: CoExecutionGroup) -> float:
    res = G.simulate(job_atomic=True, switch=SwitchCosts(),
                     work_conserving=True)
    iters_per_h = sum(3600.0 / t for t in res.iter_time.values())
    return iters_per_h / G.cost_per_hour()
