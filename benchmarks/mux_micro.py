"""Paper Fig 10: micro-benchmarks — temporal / train / spatial multiplexing.

Cost-efficiency (iterations per dollar) of RollMux co-execution groups vs
Solo-D, Gavel+ (job-atomic), and colocated veRL, using the paper's Table 3
job types. Paper result: 1.82-2.11x over Solo-D.
"""
from __future__ import annotations

from benchmarks.common import (emit, gavel_cost_eff, group_cost_eff,
                               paper_job, solo_cost_eff)
from repro.core import InterGroupScheduler, NodeAllocator, H20, H800


def _scheduled_group(jobs):
    sched = InterGroupScheduler(NodeAllocator())
    for j in jobs:
        d = sched.schedule(j)
    assert len(sched.groups) == 1, "scenario jobs should co-execute"
    return d.group


def _scenario(name: str, jobs, paper_gain: str):
    G = _scheduled_group(jobs)
    ours = group_cost_eff(G)
    solo = sum(solo_cost_eff(j) for j in jobs) / len(jobs)
    solo_total = (sum(3600.0 / j.t_solo for j in jobs)
                  / sum(j.n_roll_gpus * H20.price_per_gpu_hour
                        + j.n_train_gpus * H800.price_per_gpu_hour
                        for j in jobs))
    verl = (sum(3600.0 / (j.t_roll * H20.hbm_tbps / H800.hbm_tbps
                          + j.t_train) for j in jobs)
            / sum(j.n_train_gpus * H800.price_per_gpu_hour for j in jobs))
    gavel = gavel_cost_eff(G)
    emit(f"fig10_{name}_vs_soloD", ours / solo_total,
         f"cost-efficiency gain over Solo-D (paper {paper_gain})")
    emit(f"fig10_{name}_vs_verl", ours / verl, "gain over colocated veRL")
    emit(f"fig10_{name}_vs_gavel", ours / gavel, "gain over Gavel+")


def run():
    # (a) temporal multiplexing: two Type-A jobs
    _scenario("temporal", [paper_job("Type-A", "a1"),
                           paper_job("Type-A", "a2")], "1.82x")
    # (b) train mux (rollout-heavy): Type-D x2 + Type-E share one train pool
    _scenario("trainmux", [paper_job("Type-D", "d1"),
                           paper_job("Type-D", "d2"),
                           paper_job("Type-E", "e1")], "2.04x")
    # (c) spatial multiplexing: large Type-C + two Type-D packed in its bubbles
    _scenario("spatial", [paper_job("Type-C", "c1"),
                          paper_job("Type-D", "d1"),
                          paper_job("Type-D", "d2")], "2.11x")


if __name__ == "__main__":
    run()
