"""Phase-multiplexed GRPO benchmark: back-to-back vs pipelined vs co-executed.

Runs the *same* GRPO workload (engine-served rollouts, real train steps)
through the three ``rl.coexec`` executors and measures what the paper's
phase multiplexing is for — the dependency bubble between rollout and
training, and how much of it each schedule reclaims:

  * **off** — rollout and training back-to-back (the standard-
    disaggregation baseline RollMux beats); by construction overlap = 0.
  * **pipeline** — rollout of iteration ``k+1`` overlaps training on
    iteration ``k`` behind the ``--staleness`` on-policy guard.
  * **coexec** — ``--jobs`` independent jobs round-robin the shared
    rollout/train permit pools with warm-start context switches (this is
    the two-job co-execution of paper Fig 1-bottom, running for real).
  * **stream** — group-level pipelining inside the job (``rl.stream``):
    finished GRPO prompt groups flow to the reward permit pool and to
    train micro-batches while the engine still decodes stragglers.  Run
    twice: with instant rewards (comparable to pipeline) and in a
    slow-verifier pair — ``off_slow_reward`` verifies each group inline
    through an external-verifier stub whose per-group latency is
    calibrated to ``--reward-latency-frac`` of the measured rollout
    phase, ``stream_slow_reward`` hides the same verification work on
    ``--reward-workers`` reward-pool workers.

A seventh scenario benchmarks the *multi-turn agentic* bubble: episodes
that alternate generation with tool calls (``rl.agentic.run_episodes``)
run once with the engine's suspend/resume lifecycle (a tool-waiting
episode's slot is reclaimed the moment the boundary token is sampled)
and once with the hold-the-slot baseline (what an engine without suspend
support does).  Tokens are identical by construction; the cost is
measured in deterministic virtual scheduler ticks, so the reclaimed
fraction of the tool-latency bubble is machine-independent and CI holds
it to an absolute floor.

Reported per mode: wall time, per-step time, useful completion tokens/s,
measured rollout/train busy time, rollout×train overlap, and the fraction
of the back-to-back bubble (``min(Σroll, Σtrain)``) reclaimed.  The
engine-measured :class:`PhaseProfile` records are also pushed through the
co-execution simulator (``core.simulate_profiles``) so modeled-vs-served
iteration times appear side by side.  Writes ``BENCH_train_mux.json``
(``--quick`` shrinks the workload and writes ``BENCH_train_mux_quick.json``
— the same-config baseline the CI bench guard diffs against).

    PYTHONPATH=src python benchmarks/train_mux.py
    PYTHONPATH=src python benchmarks/train_mux.py --quick
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.simulator import simulate_profiles
from repro.data import tokenizer as tok
from repro.models import build_model
from repro.rl.agentic import CountdownToolEnv, run_episodes
from repro.rl.coexec import (GRPOJob, run_coexec, run_pipelined,
                             run_sequential)
from repro.rl.rewards import ExternalVerifier, arithmetic_reward
from repro.rl.stream import run_streaming
from repro.serve import Engine, EngineConfig, Request


def serial_group_verifier(fn, group: int):
    """Inline-baseline shape of external verification: the driver submits
    one verification call per GRPO group, serially, on the critical path —
    the same per-group work the streaming executor hides on the reward
    pool."""
    def wrapped(completions, mask, answers):
        outs = [fn(completions[i:i + group], mask[i:i + group],
                   answers[i:i + group])
                for i in range(0, len(answers), group)]
        return np.concatenate(outs)
    return wrapped


def run_agentic_scenario(model, *, episodes: int, max_new: int,
                         slots: int, tool_latency_ticks: int, turns: int,
                         tool_len: int, seed: int) -> dict:
    """Multi-turn episodes, suspend vs hold-the-slot, in virtual ticks.

    Three deterministic runs of the *same* token work: ``suspend`` (slot
    reclaimed at every tool boundary), ``hold`` (tool-waiting episodes
    keep their slot — admission stalls behind the tool latency) and
    ``ideal`` (zero-latency tools: the floor no schedule can beat).  The
    reclaimed-bubble fraction is ``(hold - suspend) / (hold - ideal)``;
    because ticks count engine scheduler steps, not seconds, the number
    is identical on every runner and is guarded by an absolute CI floor.
    """
    import jax

    params = model.init(jax.random.PRNGKey(seed))
    max_seq = 8 + max_new + turns * tool_len

    def engine():
        return Engine(model, params, EngineConfig(
            num_slots=slots, max_seq_len=max_seq, temperature=0.0))

    # probe the greedy path for a boundary token that fires early — same
    # trick the engine tests use, deterministic for a given seed
    probe = engine()
    probe.submit(Request(
        rid=0, prompt=np.asarray(tok.encode("1+2=", bos=True), np.int32),
        max_new_tokens=max_new))
    [ref] = probe.run()
    env = CountdownToolEnv((ref.tokens[2],), vocab=model.cfg.vocab_size,
                           turns=turns, tool_len=tool_len)
    # long-tail prompt mix: most episodes hit the tool boundary, the rest
    # decode straight through and keep the pool busy
    texts = ["1+2=", "0+1=", "1+2=", "3+4=", "1+2=", "2+3="]
    prompts = [np.asarray(tok.encode(texts[i % len(texts)], bos=True),
                          np.int32) for i in range(episodes)]

    runs = {}
    for name, latency, hold in (("suspend", tool_latency_ticks, False),
                                ("hold", tool_latency_ticks, True),
                                ("ideal", 0, False)):
        eps, stats = run_episodes(engine(), env, prompts,
                                  max_new_tokens=max_new,
                                  tool_latency_ticks=latency,
                                  hold_slots=hold)
        runs[name] = (eps, stats)
    sus, hol, ide = (runs[k][1]["ticks"] for k in ("suspend", "hold",
                                                   "ideal"))
    # identical tokens across schedules — the bench only re-times them
    for a, b in zip(runs["suspend"][0], runs["hold"][0]):
        assert a.full_completion == b.full_completion
    gen_tokens = sum(len(e.gen_tokens) for e in runs["suspend"][0])
    bubble = max(hol - ide, 1)
    return {
        "episodes": episodes,
        "turns": runs["suspend"][1]["turns"],
        "tool_calls": runs["suspend"][1]["tool_calls"],
        "gen_tokens": gen_tokens,
        "ticks_suspend": sus,
        "ticks_hold": hol,
        "ticks_ideal": ide,
        "speedup_suspend_vs_hold": hol / max(sus, 1),
        "reclaimed_bubble_frac": (hol - sus) / bubble,
    }


def _mode_summary(histories, report) -> dict:
    """Collapse one executor run into the tracked numbers."""
    if isinstance(histories, dict):                 # coexec: per-job
        steps = sum(len(h) for h in histories.values())
        tokens = sum(r["tokens"] for h in histories.values() for r in h)
    else:
        steps = len(histories)
        tokens = sum(r["tokens"] for r in histories)
    s = report.summary()
    return {
        "steps": steps,
        "tokens": tokens,
        "wall_s": s["wall_s"],
        "step_time_s": s["wall_s"] / max(steps, 1),
        "tok_per_s": tokens / max(s["wall_s"], 1e-9),
        "total_rollout_s": s["total_rollout_s"],
        "total_train_s": s["total_train_s"],
        "total_reward_s": s["total_reward_s"],
        "overlap_s": s["overlap_s"],
        "bubble_back_to_back_s": s["bubble_back_to_back_s"],
        "reclaimed_bubble_frac": s["reclaimed_bubble_frac"],
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--steps", type=int, default=12)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--group", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--slots", type=int, default=None,
                    help="engine KV slots (default batch*group)")
    ap.add_argument("--block-size", type=int, default=4,
                    help="engine decode steps fused per scheduler tick")
    ap.add_argument("--kv", choices=("contiguous", "paged"),
                    default="contiguous")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--staleness", type=int, default=1,
                    help="pipeline/stream on-policy staleness guard")
    ap.add_argument("--jobs", type=int, default=2,
                    help="co-executing jobs in coexec mode")
    ap.add_argument("--reward-workers", type=int, default=2,
                    help="stream mode: reward permit-pool capacity")
    ap.add_argument("--reward-latency-frac", type=float, default=0.25,
                    help="slow-verifier scenario: per-group verification "
                         "latency as a fraction of the measured rollout "
                         "phase (calibrated from the warmup run)")
    ap.add_argument("--tool-latency-ticks", type=int, default=16,
                    help="agentic scenario: engine ticks each tool call "
                         "takes (the bubble suspend/resume reclaims)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--repeats", type=int, default=1,
                    help="run each mode this many times, keep its best "
                         "(min-wall) run and the max reclaimed-bubble "
                         "fraction — wall-clock noise rejection on shared "
                         "CI runners")
    ap.add_argument("--quick", action="store_true",
                    help="small CI workload (best-of-2 repeats); writes the "
                         "*_quick.json the bench guard diffs (same config "
                         "every run)")
    ap.add_argument("--json", default=None,
                    help="report path ('' disables; default "
                         "BENCH_train_mux[_quick].json at the repo root)")
    args = ap.parse_args()
    if args.quick:
        # batch 4 = four GRPO groups per iteration: enough sub-phase
        # granularity for the streaming scenarios to show their overlap
        args.steps, args.batch, args.group, args.max_new = 6, 4, 2, 8
        args.repeats = max(args.repeats, 2)
        args.tool_latency_ticks = 10
    # agentic scenario shape: more episodes than slots (so reclaimed slots
    # actually admit waiting work) and 2 tool turns per episode
    agentic_cfg = dict(
        episodes=6 if args.quick else 8,
        max_new=10 if args.quick else 16,
        slots=2, turns=2, tool_len=3,
        tool_latency_ticks=args.tool_latency_ticks)
    # micro-batched trainer size for the slow-verifier streaming scenario:
    # half the groups per iteration, so the trainer overlaps the decode and
    # verification of the other half (derived from config => deterministic)
    stream_micro = max(1, args.batch // 2)
    if args.json is None:
        name = "BENCH_train_mux_quick.json" if args.quick \
            else "BENCH_train_mux.json"
        args.json = os.path.join(os.path.dirname(__file__), "..", name)

    model = build_model(args.arch, reduced=True)

    def make_job(jid: str, seed: int, reward_fn=None) -> GRPOJob:
        return GRPOJob(jid, model=model, seed=seed, steps=args.steps,
                       batch=args.batch, group=args.group,
                       max_new=args.max_new, temperature=args.temperature,
                       rollout="engine", num_slots=args.slots,
                       engine_block_size=args.block_size, kv=args.kv,
                       reward_fn=reward_fn)

    # warmup: compile prefill/decode/train for this shape once, off the clock
    # (the jitted train step and engine fns are shared across jobs); the
    # post-compile rollout duration also calibrates the slow-verifier
    # latency below
    _, _, r_warm = run_sequential(make_job("warmup", args.seed), steps=2,
                                  log_every=0)
    t_roll = r_warm.profiles["warmup"].rollout_s[-1]
    reward_latency = args.reward_latency_frac * t_roll
    # ... and the micro-batch train shape the streaming scenario uses
    wj = make_job("warmup", args.seed)
    wj.steps = 1
    run_streaming(wj, max_staleness=1, micro_groups=stream_micro)

    print(f"# {args.arch}: {args.steps} steps x batch {args.batch} x group "
          f"{args.group}, {args.max_new} new tokens, engine rollout "
          f"(block {args.block_size}, kv {args.kv}), best of "
          f"{args.repeats} repeat(s)")

    def best_of(run_mode):
        """Best (min-wall) summary across repeats; the reclaimed-bubble
        fraction is a property of the schedule, not of timing noise, so
        report the max across repeats (like serve's capacity numbers)."""
        runs = [run_mode() for _ in range(max(args.repeats, 1))]
        best = min(runs, key=lambda m: m["wall_s"])
        best["reclaimed_bubble_frac"] = max(r["reclaimed_bubble_frac"]
                                            for r in runs)
        return best

    modes: dict[str, dict] = {}

    def run_off():
        _, h, r = run_sequential(make_job("job0", args.seed))
        return _mode_summary(h, r)

    def run_pipe():
        _, h, r = run_pipelined(make_job("job0", args.seed),
                                max_staleness=args.staleness)
        m = _mode_summary(h, r)
        m["staleness"] = max((rec["rollout_staleness"] for rec in h),
                             default=0)
        return m

    co_reports = []

    def run_co():
        jobs = [make_job(f"job{i}", args.seed + i) for i in range(args.jobs)]
        _, h, r = run_coexec(jobs)
        co_reports.append(r)
        return _mode_summary(h, r)

    def run_stream():
        _, h, r = run_streaming(make_job("job0", args.seed),
                                max_staleness=args.staleness,
                                reward_workers=args.reward_workers)
        m = _mode_summary(h, r)
        m["staleness"] = max((rec["rollout_staleness"] for rec in h),
                             default=0)
        return m

    def run_off_slow():
        # inline baseline: the driver verifies each group through the slow
        # external verifier serially, on the critical path (run_sequential
        # calls the reward inside its train permit)
        job = make_job("job0", args.seed, reward_fn=serial_group_verifier(
            ExternalVerifier(arithmetic_reward, latency_s=reward_latency,
                             jitter=0.1, seed=args.seed), args.group))
        _, h, r = run_sequential(job)
        return _mode_summary(h, r)

    def run_stream_slow():
        # same per-group verification work, hidden on the reward pool
        # while the engine decodes stragglers and the micro-batched
        # trainer steps on already-verified groups
        job = make_job("job0", args.seed, reward_fn=ExternalVerifier(
            arithmetic_reward, latency_s=reward_latency, jitter=0.1,
            seed=args.seed))
        _, h, r = run_streaming(job, max_staleness=args.staleness,
                                reward_workers=args.reward_workers,
                                micro_groups=stream_micro)
        return _mode_summary(h, r)

    modes["off"] = best_of(run_off)
    modes["pipeline"] = best_of(run_pipe)
    modes["coexec"] = best_of(run_co)
    modes["stream"] = best_of(run_stream)
    modes["off_slow_reward"] = best_of(run_off_slow)
    modes["stream_slow_reward"] = best_of(run_stream_slow)
    r_co = co_reports[-1]

    # multi-turn agentic bubble: suspend/resume vs hold-the-slot (virtual
    # ticks — deterministic, no repeats needed)
    agentic = run_agentic_scenario(model, seed=args.seed, **agentic_cfg)
    print(f"agentic multi-turn ({agentic_cfg['episodes']} episodes x "
          f"{agentic_cfg['turns']} tool turns, "
          f"{agentic_cfg['tool_latency_ticks']}-tick tools, "
          f"{agentic_cfg['slots']} slots): "
          f"hold {agentic['ticks_hold']} ticks -> suspend "
          f"{agentic['ticks_suspend']} ticks (ideal "
          f"{agentic['ticks_ideal']}), "
          f"{agentic['speedup_suspend_vs_hold']:.2f}x, "
          f"{agentic['reclaimed_bubble_frac']:.0%} of the tool bubble "
          f"reclaimed")

    for name, m in modes.items():
        print(f"{name:18s}: {m['wall_s']:6.2f}s wall "
              f"({m['step_time_s']*1e3:6.1f} ms/step), "
              f"{m['tok_per_s']:7.1f} tok/s | roll {m['total_rollout_s']:.2f}s "
              f"train {m['total_train_s']:.2f}s "
              f"reward {m['total_reward_s']:.2f}s "
              f"overlap {m['overlap_s']:.2f}s "
              f"-> {m['reclaimed_bubble_frac']:.0%} of bubble reclaimed")

    # feed the engine-measured phase profiles back into the co-execution
    # simulator: served durations in, predicted group iteration times out
    profiles = [p for jid, p in sorted(r_co.profiles.items())]
    sim = simulate_profiles(profiles)
    measured_iter = modes["coexec"]["wall_s"] / max(args.steps, 1)
    print(f"simulator on measured profiles: iter_time "
          f"{ {j: round(t, 3) for j, t in sim.iter_time.items()} } "
          f"(measured coexec {measured_iter:.3f}s/iter), "
          f"rollout bubble {sim.rollout_bubble:.0%}, "
          f"train bubble {sim.train_bubble:.0%}")

    speed_pipe = modes["off"]["wall_s"] / max(modes["pipeline"]["wall_s"], 1e-9)
    reclaimed = modes["pipeline"]["reclaimed_bubble_frac"]
    print(f"pipeline vs back-to-back: {speed_pipe:.2f}x wall, "
          f"{reclaimed:.0%} of the dependency bubble reclaimed")
    speed_stream_slow = (modes["off_slow_reward"]["wall_s"]
                         / max(modes["stream_slow_reward"]["wall_s"], 1e-9))
    print(f"stream vs inline under slow rewards "
          f"({reward_latency * 1e3:.0f} ms/group = "
          f"{args.reward_latency_frac:.0%} of rollout): "
          f"{speed_stream_slow:.2f}x wall, "
          f"{modes['stream_slow_reward']['reclaimed_bubble_frac']:.0%} of "
          f"the three-pool bubble reclaimed")

    if args.json:
        report = {
            "arch": args.arch,
            "config": {
                "steps": args.steps, "batch": args.batch,
                "group": args.group, "max_new": args.max_new,
                "slots": args.slots, "block_size": args.block_size,
                "kv": args.kv, "temperature": args.temperature,
                "staleness": args.staleness, "jobs": args.jobs,
                "reward_workers": args.reward_workers,
                "stream_micro_groups": stream_micro,
                # the *rule*, not the machine-calibrated seconds — the
                # config block must stay runner-independent for the CI
                # baseline equality check
                "reward_latency_frac": args.reward_latency_frac,
                "seed": args.seed, "repeats": args.repeats,
                "quick": args.quick,
                "agentic": agentic_cfg,
            },
            "calibration": {"rollout_phase_s": t_roll,
                            "reward_latency_s": reward_latency},
            "modes": modes,
            "speedup_pipeline_vs_off": speed_pipe,
            "speedup_coexec_vs_off": (
                # per-step time ratio: coexec runs --jobs x the work
                modes["off"]["step_time_s"]
                / max(modes["coexec"]["step_time_s"], 1e-9)),
            "speedup_stream_vs_off_slow_reward": speed_stream_slow,
            "reclaimed_bubble_frac_pipeline": reclaimed,
            "reclaimed_bubble_frac_coexec":
                modes["coexec"]["reclaimed_bubble_frac"],
            "reclaimed_bubble_frac_stream":
                modes["stream"]["reclaimed_bubble_frac"],
            "reclaimed_bubble_frac_stream_slow":
                modes["stream_slow_reward"]["reclaimed_bubble_frac"],
            "agentic": agentic,
            "simulator_on_measured_profiles": {
                "iter_time_s": dict(sim.iter_time),
                "rollout_bubble": sim.rollout_bubble,
                "train_bubble": sim.train_bubble,
            },
        }
        path = os.path.abspath(args.json)
        with open(path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {path}")
    return modes


if __name__ == "__main__":
    main()
