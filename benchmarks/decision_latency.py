"""Paper Table 5: scheduling decision latency vs number of concurrent jobs
(paper: 5.6 ms @ 5 jobs ... 591 ms @ 2000 jobs, near-linear)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit
from repro.core import InterGroupScheduler, NodeAllocator
from repro.core.trace import make_sim_job


def run(targets=(5, 9, 13, 100, 500, 1000, 2000)):
    rng = np.random.default_rng(0)
    sched = InterGroupScheduler(NodeAllocator())
    n = 0
    for target in targets:
        while n < target:
            sched.schedule(make_sim_job(rng, f"j{n}", duration=1e9))
            n += 1
        # median of 3 probe decisions
        lats = []
        for k in range(3):
            probe = make_sim_job(rng, f"probe{k}", duration=1e9)
            t0 = time.perf_counter()
            sched.schedule(probe)
            lats.append((time.perf_counter() - t0) * 1e3)
            sched.release(probe.job_id)
        emit(f"table5_decision_ms_{target}_jobs", float(np.median(lats)),
             "paper: sub-second at 2000 jobs")


if __name__ == "__main__":
    run()
