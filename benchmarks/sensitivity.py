"""Paper Fig 14: sensitivity of the inter-group scheduler to workload type,
SLO tightness, and max group residency; RollMux vs Random/Greedy."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import (ClusterSimulator, GreedyMostIdle, InterGroupScheduler,
                        NodeAllocator, RandomScheduler)
from repro.core.trace import philly_like_trace


def _run(jobs, mk):
    return ClusterSimulator(mk(), seed=1).run(list(jobs))


def run(n_jobs: int = 120):
    # (a) workload characteristics
    for wl in ("BL", "RH", "TH", "Mixed"):
        jobs = philly_like_trace(n_jobs=n_jobs, workload=wl, seed=0)
        r = _run(jobs, lambda: InterGroupScheduler(NodeAllocator()))
        rd = _run(jobs, lambda: RandomScheduler(NodeAllocator()))
        gd = _run(jobs, lambda: GreedyMostIdle(NodeAllocator()))
        emit(f"fig14a_{wl}_rollmux_slo", r.slo_rate, "paper: 100%")
        emit(f"fig14a_{wl}_random_slo", rd.slo_rate, "paper: 37-58%")
        emit(f"fig14a_{wl}_greedy_slo", gd.slo_rate, "paper: 42-61%")
        emit(f"fig14a_{wl}_random_cost_x", rd.total_cost / r.total_cost,
             "cost vs RollMux")
        emit(f"fig14a_{wl}_greedy_cost_x", gd.total_cost / r.total_cost,
             "cost vs RollMux")

    # (b) SLO tightness
    for slo in (1.2, 1.5, 2.0, None):
        label = f"slo{slo}" if slo else "sloU12"
        jobs = philly_like_trace(n_jobs=n_jobs, slo=slo, seed=1)
        r = _run(jobs, lambda: InterGroupScheduler(NodeAllocator()))
        rd = _run(jobs, lambda: RandomScheduler(NodeAllocator()))
        emit(f"fig14b_{label}_rollmux_slo", r.slo_rate, "paper: 100%")
        emit(f"fig14b_{label}_random_slo", rd.slo_rate, "paper: 38-71%")

    # (c) max group residency (host-memory bound)
    for gs in (2, 3, 4, 5):
        jobs = philly_like_trace(n_jobs=n_jobs, seed=2)
        r = _run(jobs, lambda: InterGroupScheduler(NodeAllocator(),
                                                   max_group_size=gs))
        emit(f"fig14c_gs{gs}_rollmux_slo", r.slo_rate, "paper: 100% at all")
        emit(f"fig14c_gs{gs}_rollmux_cost", r.total_cost,
             "small groups already suffice (paper)")


if __name__ == "__main__":
    run()
