"""Paper §7.5 / Fig 15: RollMux vs brute-force Offline Optimal on small
instances (paper: within 6% of optimal)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.core import (InterGroupScheduler, NodeAllocator,
                        offline_optimal_cost)
from repro.core.trace import make_sim_job


def run(n_instances: int = 6, jobs_per_instance: int = 7):
    ratios = []
    for seed in range(n_instances):
        rng = np.random.default_rng(seed)
        jobs = [make_sim_job(rng, f"j{i}", duration=1e9)
                for i in range(jobs_per_instance)]
        sched = InterGroupScheduler(NodeAllocator())
        for j in jobs:
            sched.schedule(j)
        ours = sched.total_cost_per_hour()
        opt = offline_optimal_cost(jobs, NodeAllocator())
        ratios.append(ours / opt)
        emit(f"fig15_instance{seed}_cost_ratio", ours / opt,
             f"RollMux $/h over offline-opt ({jobs_per_instance} jobs)")
    emit("fig15_mean_cost_ratio", float(np.mean(ratios)),
         "paper: <=1.06x of optimal")


if __name__ == "__main__":
    run()
