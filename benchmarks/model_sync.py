"""Paper Fig 12: topology-aware model sync vs flat collectives.

Analytic bandwidth model (20 Gbps cross / 400 Gbps intra, paper §7.1) plus —
when enough host devices are available — HLO collective-byte attribution of
the real shard_map lowerings (ppermute bytes = slow link, all-gather = fast
fabric)."""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit
from repro.sync import ClusterTopology

MODEL_BYTES = {"7B": 15.4e9, "14B": 29.6e9, "32B": 65.5e9}  # bf16 weights


def run():
    topo = ClusterTopology()
    for name, b in MODEL_BYTES.items():
        flat = topo.flat_fetch_time_s(b, 8)
        hier = topo.hierarchical_time_s(b, 8, 8)
        emit(f"fig12_single_{name}_flat_s", flat, "veRL 8xH800->8xH20")
        emit(f"fig12_single_{name}_rollmux_s", hier, "hierarchical 2-stage")
        emit(f"fig12_single_{name}_speedup", flat / hier,
             "paper: 7.87-8.33x")
        ring = topo.ring_allgather_time_s(b, 32)
        hier16 = topo.hierarchical_time_s(b, 16, 16)
        emit(f"fig12_multi_{name}_speedup", ring / hier16,
             "paper: 2.62-2.75x (our ring baseline is conservative)")

    # real collective structure, via a 16-device subprocess
    code = r"""
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=16'
import json, re, sys
sys.path.insert(0, 'src')
from repro.sync import lower_sync
from repro.launch.hlo_cost import analyze_hlo
out = {}
for mode in ('hierarchical','flat'):
    txt = lower_sync(8, 2*8*1000, mode=mode).compile().as_text()
    c = analyze_hlo(txt)
    out[mode] = {k: v for k, v in c.coll.items()}
print(json.dumps(out))
"""
    try:
        res = subprocess.run([sys.executable, "-c", code], cwd=os.getcwd(),
                             capture_output=True, text=True, timeout=600)
        data = json.loads(res.stdout.strip().splitlines()[-1])
        hier_slow = data["hierarchical"]["collective-permute"]
        flat_slow = data["flat"]["all-gather"]
        emit("fig12_hlo_slowlink_bytes_hier", hier_slow,
             "ppermute bytes crossing the cluster axis (one copy)")
        emit("fig12_hlo_alllink_bytes_flat", flat_slow,
             "flat all-gather bytes spanning both pools")
    except Exception as e:  # pragma: no cover
        emit("fig12_hlo_collectives", -1, f"subprocess failed: {e}")


if __name__ == "__main__":
    run()
