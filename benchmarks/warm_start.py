"""Paper Fig 4: cold vs warm start latency across model sizes.

Analytic (bandwidth-model) latencies for the paper's cluster constants plus a
REAL measured host->device reload (the warm-start mechanism) on this host,
scaled per GB."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.sync import ClusterTopology
from repro.train.checkpoints import HostStateCache


# transferable actor state (bf16 weights; train adds fp32 master+moments).
# The rest of Table 2's footprint (KV buffers, cuda graphs, activations) is
# re-creatable and never crosses the wire.
WEIGHT_GB = {"3B": 6.0, "7B": 15.4, "8B": 17.0, "14B": 29.6, "32B": 65.5}


def run():
    topo = ClusterTopology()
    for size, gb in WEIGHT_GB.items():
        for phase, mult in (("rollout", 1.0), ("train", 3.0)):
            b = gb * mult * 1e9
            cold = topo.cold_start_s(b)
            warm = topo.warm_start_s(b)
            emit(f"fig4_{size}_{phase}_cold_s", cold, "paper: up to ~80 s")
            emit(f"fig4_{size}_{phase}_warm_s", warm, "")
            emit(f"fig4_{size}_{phase}_ratio", cold / warm,
                 "paper: up to 48x")

    # real measured warm start on this host (per-GB device_put throughput)
    cache = HostStateCache(4 << 30)
    state = {"w": np.random.randn(64 << 20 >> 3).astype(np.float64)}  # 64 MB
    cache.offload("probe/train", jax.device_put(state))
    t0 = time.perf_counter()
    tree, dt = cache.restore("probe/train")
    jax.block_until_ready(tree)
    per_gb = (time.perf_counter() - t0) / (64 / 1024)
    emit("fig4_measured_warm_s_per_gb", per_gb,
         "host-cache restore throughput on this container")


if __name__ == "__main__":
    run()
