"""Co-execution demo (paper Fig 1-bottom): two RL jobs time-multiplex the
rollout and training pools under the RollMux phase-centric runtime, with
warm-start context switching. Prints the per-pool execution timeline and the
bubble reclamation vs running the jobs back-to-back.

    PYTHONPATH=src python examples/co_execution.py [--iters 4]
"""
import argparse
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.phase_control import RollMuxRuntime
from repro.data import ArithmeticTask
from repro.launch.train import build_train_batch
from repro.models import build_model
from repro.rl import (SamplerConfig, arithmetic_reward, generate,
                      group_advantages, init_train_state, make_train_step)
from repro.sync import sync_params_between_jobs


def make_job(rt, jid, seed, iters):
    model = build_model("internlm2-1.8b", reduced=True)
    key = jax.random.PRNGKey(seed)
    task = ArithmeticTask(seed=seed)
    sampler = SamplerConfig(max_new_tokens=4)
    train_step = jax.jit(make_train_step(model, remat=False))

    @rt.phase("rollout", name="roll",
              init_fn=lambda: {"params": init_train_state(model, key)["params"]})
    def roll(state, prompts, k):
        out = generate(model, state["params"], prompts, k, sampler)
        jax.block_until_ready(out["completions"])
        return state, out

    @rt.phase("train", name="train",
              init_fn=lambda: init_train_state(model, key))
    def train(state, batch):
        state, metrics = train_step(state, batch)
        jax.block_until_ready(metrics["loss"])
        return state, state["params"]

    def loop():
        k = key
        for _ in range(iters):
            b = task.sample_batch(4)
            prompts = jnp.asarray(np.repeat(b.prompts, 2, axis=0))
            k, k1 = jax.random.split(k)
            out = roll(jid, prompts, k1)
            r = arithmetic_reward(out["completions"], out["mask"],
                                  [a for a in b.answers for _ in range(2)])
            tb = build_train_batch(out, group_advantages(r, 2),
                                   b.prompts.shape[1])
            new_params = train(jid, tb)
            # sync phase: updated weights -> rollout actor (host cache)
            rstate, _ = rt.cache.restore(f"{jid}/rollout")
            rstate["params"] = sync_params_between_jobs(new_params,
                                                        rstate["params"])
            rt.cache.offload(f"{jid}/rollout", rstate)
    return loop


def render_timeline(pool, width=78):
    """ASCII gantt of a pool's busy segments."""
    if not pool.timeline:
        return ""
    t_end = max(t1 for _, _, t1 in pool.timeline)
    line = ["."] * width
    for who, t0, t1 in pool.timeline:
        c = who[3]  # job index digit
        for i in range(int(t0 / t_end * (width - 1)),
                       max(int(t1 / t_end * (width - 1)), 1)):
            line[i] = c
    return "".join(line)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=4)
    args = ap.parse_args()

    # --- co-executed ---
    rt = RollMuxRuntime(host_cache_gb=4.0)
    rt.pool("rollout", 1)
    rt.pool("train", 1)
    loops = [make_job(rt, f"job{i}", i, args.iters) for i in range(2)]
    t0 = time.perf_counter()
    ts = [threading.Thread(target=fn) for fn in loops]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    co_wall = time.perf_counter() - t0

    # --- sequential (solo) ---
    rt2 = RollMuxRuntime(host_cache_gb=4.0)
    rt2.pool("rollout", 1)
    rt2.pool("train", 1)
    t0 = time.perf_counter()
    for fn in [make_job(rt2, f"job{i}", i, args.iters)
               for i in range(2)]:
        fn()
    seq_wall = time.perf_counter() - t0

    print("\nco-execution timeline (0/1 = job id, . = dependency bubble):")
    print(f"  rollout pool: {render_timeline(rt.pools['rollout'])}")
    print(f"  train pool:   {render_timeline(rt.pools['train'])}")
    for name, p in rt.pools.items():
        busy = p.busy_time
        total = max(t1 for _, _, t1 in p.timeline)
        print(f"  {name:8s} utilization: {busy/total:6.1%}")
    stats = rt.stats
    warm = sum(s.warm_starts for s in stats.values())
    cold = sum(s.cold_starts for s in stats.values())
    print(f"  context switches: {cold} cold (init), {warm} warm "
          f"(host-DRAM cache)")
    print(f"\nwall time: co-executed {co_wall:.2f}s vs sequential "
          f"{seq_wall:.2f}s "
          f"(note: single-core container — real gains need two pools)")


if __name__ == "__main__":
    main()
