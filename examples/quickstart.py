"""Quickstart: end-to-end synchronous on-policy RL post-training of a small
model on a verifiable arithmetic task (RLVR), on CPU.

    PYTHONPATH=src python examples/quickstart.py [--steps 150] [--arch ID]

This is the exact workload RollMux schedules: rollout -> verify/reward ->
GRPO advantages -> train -> weight sync, strictly on-policy. Reward should
climb visibly within ~100 steps.
"""
import argparse
import sys
import os

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import run_training


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    help="any assigned arch id (reduced variant is used)")
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--group", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--lr", type=float, default=5e-4)
    args = ap.parse_args()

    _, hist = run_training(args.arch, reduced=True, steps=args.steps,
                           batch=args.batch, group=args.group,
                           max_new=args.max_new, lr=args.lr, log_every=10)
    first = sum(h["reward"] for h in hist[:10]) / 10
    last = sum(h["reward"] for h in hist[-10:]) / 10
    print(f"\nreward: first-10 avg {first:.3f} -> last-10 avg {last:.3f}")


if __name__ == "__main__":
    main()
