"""At-scale trace replay (paper §7.4): 200 production-like RL jobs through
RollMux vs Solo-D vs colocated veRL.

    PYTHONPATH=src python examples/trace_replay.py [--jobs 200] [--seed 1]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (ClusterSimulator, InterGroupScheduler, NodeAllocator,
                        SoloDisaggregation, replay_verl)
from repro.core.trace import production_replay_trace


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--jobs", type=int, default=200)
    ap.add_argument("--seed", type=int, default=1)
    args = ap.parse_args()

    jobs = production_replay_trace(n_jobs=args.jobs, seed=args.seed)
    print(f"replaying {len(jobs)} jobs "
          f"({sum(j.turns == 'multi' for j in jobs)} multi-turn)...")

    r = ClusterSimulator(InterGroupScheduler(NodeAllocator()), seed=1)\
        .run(list(jobs))
    s = ClusterSimulator(SoloDisaggregation(NodeAllocator()), seed=1)\
        .run(list(jobs))
    v = replay_verl(list(jobs), NodeAllocator())

    def row(name, rep, extra=""):
        print(f"{name:10s} ${rep.avg_cost_per_hour:7.1f}/h  "
              f"SLO {rep.slo_rate:6.1%}  peak GPUs R={rep.peak_rollout_gpus:3d} "
              f"T={rep.peak_train_gpus:3d}  bubbles R={rep.rollout_bubble:.2f} "
              f"T={rep.train_bubble:.2f} {extra}")

    row("RollMux", r)
    row("Solo-D", s, f"({s.avg_cost_per_hour/r.avg_cost_per_hour:.2f}x cost)")
    row("veRL", v, f"({v.avg_cost_per_hour/r.avg_cost_per_hour:.2f}x cost)")
    print(f"\npaper reference: RollMux 1.84x cheaper than Solo-D, "
          f"1.38x than veRL, 100% SLO")


if __name__ == "__main__":
    main()
