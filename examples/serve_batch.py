"""Batched serving example: prefill + KV-cache decode on any assigned arch.

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-4b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve_batch


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()
    prompts = [f"{10+i}+{20+i}=" for i in range(args.batch)]
    res = serve_batch(args.arch, prompts, max_new=args.max_new)
    print(f"{args.arch}: {res['tokens']} tokens in {res['wall_s']:.2f}s "
          f"({res['tok_per_s']:.1f} tok/s, random weights)")
    for p, t in zip(prompts, res["texts"]):
        print(f"  {p!r} -> {t[:40]!r}")


if __name__ == "__main__":
    main()
