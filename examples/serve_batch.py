"""Serving example: continuous-batching engine vs the static-batch path.

Requests stream through ``repro.serve.Engine`` — FIFO admission into a
fixed pool of KV-cache slots (here fewer slots than requests, so the
engine queues, recycles slots on EOS/budget, and keeps the decode batch
full).  Pass ``--engine static`` for the legacy one-shot batch.

    PYTHONPATH=src python examples/serve_batch.py --arch gemma3-4b
    PYTHONPATH=src python examples/serve_batch.py --batch 8 --slots 2
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve_batch, serve_continuous


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=2,
                    help="KV-cache slot pool size (continuous engine)")
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()
    prompts = [f"{10+i}+{20+i}=" for i in range(args.batch)]
    if args.engine == "continuous":
        res = serve_continuous(args.arch, prompts, max_new=args.max_new,
                               num_slots=args.slots)
        print(f"{args.arch} [continuous, {args.slots} slots]: "
              f"{res['tokens']} tokens in {res['wall_s']:.2f}s "
              f"({res['tok_per_s']:.1f} tok/s, slot util "
              f"{res['slot_utilization']:.0%}, random weights)")
    else:
        res = serve_batch(args.arch, prompts, max_new=args.max_new)
        print(f"{args.arch} [static]: {res['tokens']} tokens in "
              f"{res['wall_s']:.2f}s ({res['tok_per_s']:.1f} tok/s, "
              f"random weights)")
    for p, t in zip(prompts, res["texts"]):
        print(f"  {p!r} -> {t[:40]!r}")


if __name__ == "__main__":
    main()
