"""Per-arch smoke tests (spec deliverable f): reduced variant of each family,
one forward + one train step on CPU, asserting shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import list_archs
from repro.models import build_model
from repro.rl import init_train_state, make_train_step


def _frontend(m, key, B):
    if m.cfg.frontend == "vision":
        return jax.random.normal(key, (B, m.cfg.num_frontend_tokens,
                                       m.cfg.d_model))
    if m.cfg.frontend == "audio":
        return jax.random.normal(key, (B, m.cfg.max_source_len, m.cfg.d_model))
    return None


@pytest.mark.parametrize("arch", list_archs())
def test_forward_shapes_and_finite(arch, rng_key):
    m = build_model(arch, reduced=True)
    assert m.cfg.num_layers == 2 and m.cfg.d_model <= 512
    assert m.cfg.num_experts <= 4
    B, S = 2, 32
    tokens = jax.random.randint(rng_key, (B, S), 0, m.cfg.vocab_size)
    params = m.init(rng_key)
    logits, aux = m.forward(params, tokens,
                            frontend=_frontend(m, rng_key, B))
    assert logits.shape == (B, S, m.cfg.vocab_size)
    assert logits.dtype == jnp.float32
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", list_archs())
def test_train_step(arch, rng_key):
    m = build_model(arch, reduced=True)
    B, S = 2, 16
    state = init_train_state(m, rng_key)
    batch = {
        "tokens": jax.random.randint(rng_key, (B, S), 0, m.cfg.vocab_size),
        "labels": jax.random.randint(rng_key, (B, S), 0, m.cfg.vocab_size),
        "loss_mask": jnp.ones((B, S), jnp.float32),
        "advantages": jax.random.normal(rng_key, (B, S)),
    }
    fr = _frontend(m, rng_key, B)
    if fr is not None:
        batch["frontend"] = fr
    step = jax.jit(make_train_step(m, remat=False))
    new_state, metrics = step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    # params actually changed
    leaves0 = jax.tree.leaves(state["params"])
    leaves1 = jax.tree.leaves(new_state["params"])
    assert any(not np.allclose(a, b) for a, b in zip(leaves0, leaves1))
    for leaf in jax.tree.leaves(new_state["params"]):
        assert bool(jnp.isfinite(leaf).all())
