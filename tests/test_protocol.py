"""EngineProtocol conformance + RolloutSpec consolidation.

``repro.serve.protocol.EngineProtocol`` is the explicit contract the rl
layer programs against: anything admitted there must expose the full
submit/step/harvest lifecycle *plus* the suspend/resume and
checkpoint surface, and the data attributes (``ENGINE_ATTRS``) the
drivers read.  Both implementations — the monolithic ``Engine`` and the
``DisaggRouter`` — are checked structurally (``isinstance`` against the
runtime-checkable protocol) and attribute-by-attribute, so adding a
method to the protocol without implementing it on both fails here, not
in a driver at 2am.

``RolloutSpec`` is the consolidated engine-shape surface: one frozen
dataclass feeding ``launch.serve`` and ``launch.train`` identically,
with the old loose-kwargs call shape kept working behind a warn-once
deprecation shim.
"""
import warnings

import jax
import pytest
from test_serve_engine import MAX_LEN, get_model

from repro.serve import (ENGINE_ATTRS, DisaggConfig, DisaggRouter, Engine,
                         EngineConfig, EngineProtocol, RolloutSpec)


def _make(kind):
    m, params = get_model("internlm2-1.8b")
    if kind == "disagg":
        return DisaggRouter(m, params, DisaggConfig(
            prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
            temperature=0.0))
    return Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                          temperature=0.0))


@pytest.mark.parametrize("kind", ["mono", "disagg"])
def test_engine_protocol_conformance(kind):
    eng = _make(kind)
    # method surface: runtime_checkable verifies every protocol callable
    assert isinstance(eng, EngineProtocol)
    # data surface: checked one attribute at a time (runtime_checkable
    # only inspects callables)
    for attr in ENGINE_ATTRS:
        assert hasattr(eng, attr), f"{kind} missing {attr}"


def test_protocol_rejects_non_engines():
    class Almost:
        def submit(self, req):
            return True

    assert not isinstance(Almost(), EngineProtocol)


# ---------------------------------------------------------------------------
# RolloutSpec: one source of engine shape for serve and train
# ---------------------------------------------------------------------------
def test_spec_builds_both_topologies():
    m, params = get_model("internlm2-1.8b")
    mono = RolloutSpec(num_slots=2).build_engine(
        m, params, batch=2, max_seq_len=MAX_LEN, eos_id=-1, temperature=0.0)
    assert isinstance(mono, Engine) and isinstance(mono, EngineProtocol)
    dis = RolloutSpec(num_slots=4, disagg=True).build_engine(
        m, params, batch=4, max_seq_len=MAX_LEN, eos_id=-1, temperature=0.0)
    assert isinstance(dis, DisaggRouter)
    assert dis.config.prefill_slots == 1      # 1:3 default split
    assert dis.config.decode_slots == 3


def test_spec_from_args_maps_serve_and_train_namespaces():
    import argparse
    serve_ns = argparse.Namespace(
        slots=4, block_size=2, kv="paged", kv_block_size=8,
        num_kv_blocks=32, sched="slo", prefix_share=True, disagg=True,
        prefill_slots=1, decode_slots=3, prefill_kv_blocks=None,
        decode_kv_blocks=None, kernel_backend="jnp", kv_dtype="int8",
        group=4)
    spec = RolloutSpec.from_args(serve_ns)
    assert (spec.num_slots, spec.kv_layout, spec.kv_block_size) == \
        (4, "paged", 8)
    assert spec.disagg == {"prefill_slots": 1, "decode_slots": 3}
    assert spec.kv_dtype == "int8" and spec.sched == "slo"
    train_ns = argparse.Namespace(
        num_slots=8, engine_block_size=1, kv="contiguous", carry=True)
    spec = RolloutSpec.from_args(train_ns)
    assert spec.num_slots == 8 and spec.carry


def test_legacy_kwargs_warn_once_and_conflict_raises():
    import numpy as np

    from repro.data import tokenizer as tok
    from repro.rl import SamplerConfig, generate_continuous

    m, params = get_model("internlm2-1.8b")
    prompts = jax.numpy.asarray(np.stack(
        [np.asarray(tok.encode(p, bos=True), np.int32)
         for p in ["1+2=", "7+8="]]))
    sampler = SamplerConfig(max_new_tokens=4, temperature=0.0)
    key = jax.random.PRNGKey(0)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        import repro.rl.rollout as ro
        ro._warned_legacy[0] = False        # fresh process view
        generate_continuous(m, params, prompts, key, sampler, num_slots=2,
                            kv_layout="paged", kv_block_size=4)
        generate_continuous(m, params, prompts, key, sampler, num_slots=2,
                            kv_layout="paged", kv_block_size=4)
    deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert len(deps) == 1                   # warn once per process, not call
    assert "RolloutSpec" in str(deps[0].message)
    with pytest.raises(ValueError, match="legacy engine kwargs"):
        generate_continuous(m, params, prompts, key, sampler,
                            spec=RolloutSpec(num_slots=2), num_slots=4)
    # spec path: silent
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        out = generate_continuous(m, params, prompts, key, sampler,
                                  spec=RolloutSpec(num_slots=2))
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]
    assert "token_versions" in out
