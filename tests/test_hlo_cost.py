"""Trip-count-aware HLO cost walker: scan == unroll, collective factors."""
import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze_hlo, _shape_bytes


def _body(x, w):
    return jnp.tanh(x @ w), None


def test_scan_equals_unroll_flops():
    x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 256, 256), jnp.float32)

    def f_scan(x, ws):
        return jax.lax.scan(_body, x, ws)[0]

    def f_unroll(x, ws):
        for i in range(10):
            x, _ = _body(x, ws[i])
        return x

    cs = analyze_hlo(jax.jit(f_scan).lower(x, ws).compile().as_text())
    cu = analyze_hlo(jax.jit(f_unroll).lower(x, ws).compile().as_text())
    expected = 2 * 128 * 256 * 256 * 10
    assert cs.flops == pytest.approx(expected, rel=1e-6)
    assert cu.flops == pytest.approx(expected, rel=1e-6)
    # bytes agree to ~25% (scan pays loop-carry traffic; slicing-aware model
    # charges 2x slice bytes for the unrolled static slices)
    assert cs.bytes == pytest.approx(cu.bytes, rel=0.25)


def test_nested_scan_multiplier():
    def inner(x, w):
        return jnp.tanh(x @ w), None

    def outer(x, ws):
        def step(x, _):
            x, _ = jax.lax.scan(inner, x, ws)
            return x, None
        return jax.lax.scan(step, x, None, length=3)[0]

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 64, 64), jnp.float32)
    c = analyze_hlo(jax.jit(outer).lower(x, ws).compile().as_text())
    assert c.flops == pytest.approx(2 * 64 * 64 * 64 * 5 * 3, rel=1e-6)


def test_shape_bytes():
    assert _shape_bytes("f32[10,10]") == 400
    assert _shape_bytes("bf16[4]{0}") == 8
    assert _shape_bytes("(f32[2,2], s32[3])") == 28
    assert _shape_bytes("pred[]") == 1
