"""Test config. NOTE: no XLA_FLAGS device-count forcing here — smoke tests
and benches must see the single real CPU device (the 512-device view is
exclusively the dry-run's, per spec)."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
