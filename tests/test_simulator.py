"""Cluster-level trace replay invariants (paper §7.4/§7.5 semantics)."""
import pytest

from repro.core import (ClusterSimulator, GreedyMostIdle, InterGroupScheduler,
                        NodeAllocator, SoloDisaggregation, replay_verl)
from repro.core.trace import philly_like_trace, production_replay_trace


@pytest.fixture(scope="module")
def trace():
    return production_replay_trace(n_jobs=40, seed=3)


def test_rollmux_full_slo_and_cheaper_than_solo(trace):
    r = ClusterSimulator(InterGroupScheduler(NodeAllocator()), seed=1)\
        .run(list(trace))
    s = ClusterSimulator(SoloDisaggregation(NodeAllocator()), seed=1)\
        .run(list(trace))
    assert r.slo_rate == 1.0                      # paper: 100 % attainment
    assert s.slo_rate == 1.0                      # solo trivially meets SLO
    assert r.total_cost < s.total_cost            # bubbles reclaimed
    assert r.peak_train_gpus <= s.peak_train_gpus


def test_baselines_violate_slo(trace):
    g = ClusterSimulator(GreedyMostIdle(NodeAllocator()), seed=1)\
        .run(list(trace))
    assert g.slo_rate < 1.0                       # no SLO guarantee


def test_verl_replay_sane(trace):
    v = replay_verl(list(trace), NodeAllocator())
    assert v.peak_rollout_gpus == 0               # colocated: no rollout pool
    assert v.total_cost > 0
    # colocated rollout pays the HBM-bandwidth mismatch -> some SLO misses
    assert 0.0 <= v.slo_rate <= 1.0


def test_report_accounting(trace):
    r = ClusterSimulator(InterGroupScheduler(NodeAllocator()), seed=1)\
        .run(list(trace))
    assert r.n_jobs == len(trace)
    assert len(r.per_job_slowdown) == len(trace)
    assert all(s > 0 for s in r.per_job_slowdown.values())
    assert 0.0 <= r.rollout_bubble <= 1.0
    assert 0.0 <= r.train_bubble <= 1.0


def test_philly_trace_shape():
    jobs = philly_like_trace(n_jobs=50, seed=0)
    assert len(jobs) == 50
    arr = [j.arrival for j in jobs]
    assert arr == sorted(arr)
    assert all(1.0 <= j.slo <= 2.0 for j in jobs)
