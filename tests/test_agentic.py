"""Multi-turn agentic episode driver (``repro.rl.agentic``).

The driver's contract has two halves.  *Content*: episodes are
deterministic — same engine weights, same environment, same prompts give
byte-identical turn structure, tokens and masks, on monolithic and
disaggregated engines alike, and regardless of the scheduling mode.
*Schedule*: with non-zero tool latency, suspend mode (the engine
reclaims a tool-waiting episode's slot) finishes the batch in strictly
fewer virtual ticks than the hold-the-slot baseline — the bubble the
ROADMAP's multi-turn item is about, and the quantity the train_mux
agentic bench floors in CI.
"""
import numpy as np
import pytest
from test_serve_engine import MAX_LEN, get_model, reference

from repro.data import tokenizer as tok
from repro.rl import CountdownToolEnv, run_episodes
from repro.serve import (DisaggConfig, DisaggRouter, Engine, EngineConfig,
                         Request)

MAX_NEW = 14


def _prompts():
    # three prompts that hit the tool boundary, one long-tail straggler
    return [np.asarray(tok.encode(t, bos=True), np.int32)
            for t in ["1+2=", "0+1=", "1+2=", "2+3="]]


def _env(m, params, turns=2):
    ref_t, _ = reference(
        m, params,
        Request(rid=0, prompt=_prompts()[0], max_new_tokens=MAX_NEW),
        max_new=MAX_NEW)
    return CountdownToolEnv((ref_t[2],), vocab=m.cfg.vocab_size,
                            turns=turns, tool_len=3)


def _engine(m, params, kind):
    if kind == "disagg":
        return DisaggRouter(m, params, DisaggConfig(
            prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
            temperature=0.0))
    return Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                          temperature=0.0))


@pytest.mark.parametrize("kind", ["mono", "disagg"])
def test_suspend_and_hold_are_token_identical(kind):
    m, params = get_model("internlm2-1.8b")
    env = _env(m, params)
    runs = {}
    for hold in (False, True):
        eps, stats = run_episodes(_engine(m, params, kind), env, _prompts(),
                                  max_new_tokens=MAX_NEW,
                                  tool_latency_ticks=6, hold_slots=hold)
        runs[hold] = (eps, stats)
    sus, hol = runs[False][0], runs[True][0]
    for a, b in zip(sus, hol):
        assert a.gen_tokens == b.gen_tokens, a.index
        assert a.full_completion == b.full_completion
        assert a.action_mask == b.action_mask
        assert a.finish_reason == b.finish_reason
        np.testing.assert_allclose(a.logprobs, b.logprobs, atol=1e-5)
    assert any(len(e.turns) >= 2 for e in sus)   # multi-turn really happened
    # schedule half: suspend reclaims the tool bubble
    assert runs[False][1]["ticks"] < runs[True][1]["ticks"]
    assert runs[False][1]["tool_calls"] == runs[True][1]["tool_calls"] > 0


def test_episode_structure_and_masks():
    m, params = get_model("internlm2-1.8b")
    env = _env(m, params)
    eng = _engine(m, params, "mono")
    eps, stats = run_episodes(eng, env, _prompts(), max_new_tokens=MAX_NEW,
                              tool_latency_ticks=0)
    multi = [e for e in eps if len(e.turns) >= 2]
    assert multi
    for e in eps:
        assert len(e.gen_tokens) <= MAX_NEW        # budget spans turns
        assert sum(e.action_mask) == len(e.gen_tokens)
        assert len(e.action_mask) == len(e.full_completion)
        assert len(e.logprobs) == len(e.gen_tokens)
        assert len(e.token_versions) == len(e.gen_tokens)
        assert e.finish_reason in ("eos", "length", "env_done")
        # every non-final turn's boundary token is the env's stop token
        for turn in e.turns[:-1]:
            assert turn.tokens[-1] in env.stop_tokens
            assert len(turn.tool_tokens) == env.tool_len
    # first turn of a multi-turn episode matches the uninterrupted
    # reference prefix — suspension never rewrites history
    e = multi[0]
    ref_t, _ = reference(
        m, params,
        Request(rid=0, prompt=e.prompt, max_new_tokens=MAX_NEW),
        max_new=MAX_NEW)
    n0 = len(e.turns[0].tokens)
    assert e.turns[0].tokens == ref_t[:n0]
    assert stats["turns"] == sum(len(e.turns) for e in eps)


def test_driver_is_deterministic_across_runs():
    m, params = get_model("internlm2-1.8b")
    env = _env(m, params)
    a, _ = run_episodes(_engine(m, params, "mono"), env, _prompts(),
                        max_new_tokens=MAX_NEW, tool_latency_ticks=3)
    b, _ = run_episodes(_engine(m, params, "mono"), env, _prompts(),
                        max_new_tokens=MAX_NEW, tool_latency_ticks=3)
    for x, y in zip(a, b):
        assert x.full_completion == y.full_completion
        assert x.finish_reason == y.finish_reason
        np.testing.assert_array_equal(x.logprobs, y.logprobs)


def test_env_can_terminate_episode_at_boundary():
    class OneShotEnv(CountdownToolEnv):
        def react(self, episode, turn_tokens):
            return None, True               # done at the first boundary

    m, params = get_model("internlm2-1.8b")
    base = _env(m, params)
    env = OneShotEnv(base.stop_tokens, vocab=m.cfg.vocab_size)
    eng = _engine(m, params, "mono")
    eps, _ = run_episodes(eng, env, _prompts(), max_new_tokens=MAX_NEW)
    assert any(e.finish_reason == "env_done" and len(e.turns) == 1
               for e in eps)
    # dropped handles released cleanly: the engine resets without leaks
    eng.reset(params)


def test_job_tags_flow_to_requests():
    m, params = get_model("internlm2-1.8b")
    env = _env(m, params)
    eps, _ = run_episodes(_engine(m, params, "mono"), env, _prompts(),
                          max_new_tokens=MAX_NEW,
                          job_ids=["a", "a", "b", "b"],
                          priorities=[1, 0, 0, 2])
    assert [e.job_id for e in eps] == ["a", "a", "b", "b"]
    assert [e.priority for e in eps] == [1, 0, 0, 2]
