#!/usr/bin/env python
"""Deterministically partition the test files across CI matrix shards.

    python tests/shard_files.py --shards 2 --index 1

Prints a space-separated list of test files for the given (1-based) shard.
Partitioning is greedy size-balanced over the checked-in file sizes, so
every shard gets a comparable amount of work, the split is stable across
runs of the same commit, and no external plugin (pytest-xdist) is needed —
the runner image only has the pinned requirements.  Every test file lands
in exactly one shard; a file added tomorrow is picked up automatically.
"""
from __future__ import annotations

import argparse
import pathlib


def shard(files: list[pathlib.Path], n_shards: int) -> list[list[pathlib.Path]]:
    buckets: list[list[pathlib.Path]] = [[] for _ in range(n_shards)]
    loads = [0] * n_shards
    # largest-first greedy into the lightest bucket; ties broken by name
    # (sort is total, so the partition is deterministic)
    for size, f in sorted(((f.stat().st_size, f) for f in files),
                          key=lambda t: (-t[0], t[1].name)):
        i = loads.index(min(loads))
        buckets[i].append(f)
        loads[i] += size
    return buckets


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--shards", type=int, required=True)
    ap.add_argument("--index", type=int, required=True,
                    help="1-based shard index")
    args = ap.parse_args()
    if args.shards < 1 or not 1 <= args.index <= args.shards:
        raise SystemExit("need 1 <= index <= shards")
    here = pathlib.Path(__file__).resolve().parent
    files = sorted(here.glob("test_*.py"))
    mine = shard(files, args.shards)[args.index - 1]
    print(" ".join(str(f.relative_to(here.parent)) for f in sorted(mine)))


if __name__ == "__main__":
    main()
