"""Suspend/resume lifecycle (``repro.serve.engine`` stop-token boundaries):
bit-exactness of an interrupted-and-resumed generation against the
uninterrupted one across every layout × dtype × topology combination,
tool-token injection equivalence against a prompt-continuation reference,
KV refcount conservation when handles are dropped instead of resumed,
partial-rollout continuation across a weight sync
(``Engine.reset(carry_live=True)``) with per-token version provenance,
checkpoint round-trips that carry suspended handles — including int8
scale leaves and radix prefix pins — and the recurrent-family guard
(``stop_tokens`` needs ``block_size == 1`` for rollback-free boundaries).

The core contract: suspension changes *when* a sequence's tokens are
computed, never *what* is computed.  fp32 resumes are bit-identical
(tokens and logprobs); int8 KV resumes are token-identical with logprobs
inside the same 1e-5 envelope the int8 layout is held to elsewhere
(requantizing the partial tail block costs ~1 ulp on the scales).
"""
import numpy as np
import pytest
from test_serve_engine import MAX_LEN, get_model, reference

from repro.data import tokenizer as tok
from repro.serve import (DisaggConfig, DisaggRouter, Engine, EngineConfig,
                         Request)

MAX_NEW = 10
# greedy step-3 token of "1+2=" on the shared fixture — probed per test so
# the suspension actually fires mid-sequence
PROMPT = "1+2="


def _req(rid=0, stop_tokens=(), max_new=MAX_NEW, prompt=PROMPT):
    return Request(rid=rid,
                   prompt=np.asarray(tok.encode(prompt, bos=True), np.int32),
                   max_new_tokens=max_new, stop_tokens=stop_tokens)


def _build(m, params, kind, kv, kv_dtype, **kw):
    if kind == "disagg":
        return DisaggRouter(m, params, DisaggConfig(
            prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
            temperature=0.0, kv_layout=kv, kv_block_size=4,
            kv_dtype=kv_dtype, **kw))
    return Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0, kv_layout=kv,
        kv_block_size=4, kv_dtype=kv_dtype, **kw))


def _pick_stop(m, params):
    """A token the greedy path emits early and again later — suspending on
    it exercises a genuine mid-sequence boundary."""
    ref_t, _ = reference(m, params, _req(), max_new=MAX_NEW)
    return ref_t[2]


# ---------------------------------------------------------------------------
# Bit-exactness: suspended-and-resumed == uninterrupted, full matrix
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["mono", "disagg"])
@pytest.mark.parametrize("kv,kv_dtype", [
    ("contiguous", None), ("paged", None), ("paged", "int8")])
def test_resume_matches_uninterrupted(kind, kv, kv_dtype):
    m, params = get_model("internlm2-1.8b")
    stop = _pick_stop(m, params)
    gen_t, gen_l = reference(m, params, _req(), max_new=MAX_NEW)
    ref_eng = _build(m, params, kind, kv, kv_dtype)
    ref_eng.submit(_req())
    [ref_out] = ref_eng.run()
    ref_t, ref_l = ref_out.tokens, np.asarray(ref_out.logprobs)
    assert ref_t == gen_t                   # engine == generate, as ever
    if kv_dtype is None:                    # int8 KV drifts ~1e-2 from fp32
        np.testing.assert_allclose(ref_l, gen_l, atol=1e-5)

    eng = _build(m, params, kind, kv, kv_dtype)
    eng.submit(_req(stop_tokens=(stop,)))
    eng.run()
    [sreq] = eng.harvest_suspended()
    assert sreq.out.finish_reason == "stop"
    assert sreq.out.tokens[-1] == stop
    n0 = len(sreq.out.tokens)
    assert 0 < n0 < MAX_NEW                 # genuinely mid-sequence
    # no tool tokens + no stop tokens -> must replay the uninterrupted tail
    eng.resume(sreq, (), max_new_tokens=MAX_NEW - n0, rid=1,
               stop_tokens=())
    [out] = eng.run()
    tokens = sreq.out.tokens + out.tokens
    logp = list(sreq.out.logprobs) + list(out.logprobs)
    assert tokens == ref_t, (kind, kv, kv_dtype)
    if kv_dtype is None:
        # fp32 boundary logits are carried, not recomputed: the resumed
        # tail is bit-identical to the uninterrupted engine run
        np.testing.assert_array_equal(np.asarray(logp, np.float32), ref_l)
    else:
        # int8: requantizing the dequantized tail costs ~1 ulp on scales
        np.testing.assert_allclose(logp, ref_l, atol=1e-5)


def test_resume_with_tool_tokens_matches_prompt_continuation():
    """Resuming with injected tool tokens must equal a fresh request whose
    prompt is (original prompt + generated turn + tool tokens) — the
    synthetic-prompt adoption path is semantically a prefill."""
    m, params = get_model("internlm2-1.8b")
    stop = _pick_stop(m, params)
    tool = np.asarray([7, 11, 13], np.int32)

    eng = _build(m, params, "mono", "paged", None)
    eng.submit(_req(stop_tokens=(stop,)))
    eng.run()
    [sreq] = eng.harvest_suspended()
    eng.resume(sreq, tool, max_new_tokens=6, rid=1, stop_tokens=())
    [out] = eng.run()

    cont_prompt = np.concatenate([sreq.req.prompt,
                                  np.asarray(sreq.out.tokens, np.int32),
                                  tool])
    ref = _build(m, params, "mono", "paged", None)
    ref.submit(Request(rid=0, prompt=cont_prompt, max_new_tokens=6))
    [ref_out] = ref.run()
    assert out.tokens == ref_out.tokens
    np.testing.assert_allclose(out.logprobs, ref_out.logprobs, atol=1e-5)


# ---------------------------------------------------------------------------
# Refcount conservation: dropped handles must not leak KV blocks
# ---------------------------------------------------------------------------
def test_dropped_handle_restores_block_conservation():
    m, params = get_model("internlm2-1.8b")
    stop = _pick_stop(m, params)
    eng = _build(m, params, "mono", "paged", None)
    eng.submit(_req(rid=0, stop_tokens=(stop,)))
    eng.submit(_req(rid=1, stop_tokens=(stop,), prompt="10+20="))
    eng.run()
    handles = eng.harvest_suspended()
    assert handles                          # at least rid 0 suspended
    alloc = eng.slots.alloc
    live_before = alloc.num_live
    for h in handles:
        h.release()
        h.release()                         # idempotent
    assert alloc.num_live < live_before
    assert alloc.num_free + alloc.num_live == alloc.num_blocks
    eng.run()                               # any non-suspended stragglers
    eng.harvest()
    alloc.assert_clean(context="dropped suspended handles")
    eng.reset(params)                       # clean reset: nothing pinned


def test_disagg_dropped_handle_conservation():
    m, params = get_model("internlm2-1.8b")
    stop = _pick_stop(m, params)
    router = _build(m, params, "disagg", "paged", None)
    router.submit(_req(rid=0, stop_tokens=(stop,)))
    router.run()
    [sreq] = router.harvest_suspended()
    sreq.release()
    router.prefill.slots.alloc.assert_clean()
    router.decode.slots.alloc.assert_clean()


# ---------------------------------------------------------------------------
# Partial-rollout continuation: carry across a weight sync with provenance
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kind", ["mono", "disagg"])
def test_carry_live_across_weight_sync(kind):
    """reset(carry_live=True) suspends live generations, swaps weights and
    resumes them: tokens before the sync match the old-weights reference,
    token_versions records exactly where the behaviour policy changed."""
    import jax
    m, params = get_model("internlm2-1.8b")
    params2 = m.init(jax.random.PRNGKey(7))   # a genuinely different policy
    ref_t, ref_l = reference(m, params, _req(), max_new=MAX_NEW)

    eng = _build(m, params, kind, "paged", None)
    eng.submit(_req())
    for _ in range(4):                      # prefill + a few decode steps
        eng.step()
    eng.reset(params2, carry_live=True)
    [out] = eng.run()
    assert len(out.tokens) == MAX_NEW
    vers = list(out.token_versions)
    assert set(vers) == {0, 1}
    n_old = vers.count(0)
    assert 0 < n_old < MAX_NEW
    # pre-sync tokens and logprobs are the old policy's, bit-for-bit
    assert out.tokens[:n_old] == ref_t[:n_old]
    np.testing.assert_allclose(out.logprobs[:n_old], ref_l[:n_old],
                               atol=1e-5)
    # provenance is monotone: once the sync happens, no token is ever
    # attributed to the old policy again
    assert vers == sorted(vers)
    # post-sync decode really uses params2: the tail diverges from the
    # old policy's continuation (KV stays the old rollout's, by design —
    # a carried generation is NOT a re-prefill under the new weights)
    assert out.tokens[n_old:] != ref_t[n_old:]
    # and the whole carry procedure is deterministic
    eng2 = _build(m, params, kind, "paged", None)
    eng2.submit(_req())
    for _ in range(4):
        eng2.step()
    eng2.reset(params2, carry_live=True)
    [rep] = eng2.run()
    assert rep.tokens == out.tokens
    np.testing.assert_array_equal(rep.logprobs, out.logprobs)
    assert list(rep.token_versions) == vers


def test_stream_carry_versions_reach_training_arrays():
    """The streaming generator polls sync_params between ticks; a version
    bump mid-rollout must surface as mixed token_versions in the group
    dicts the trainer consumes."""
    import jax
    import jax.numpy as jnp

    from repro.rl import SamplerConfig
    from repro.rl.rollout import generate_continuous_stream
    from repro.serve import RolloutSpec

    m, params = get_model("internlm2-1.8b")
    params2 = m.init(jax.random.PRNGKey(7))
    prompts = jnp.asarray(np.stack(
        [np.asarray(tok.encode(p, bos=True), np.int32)
         for p in ["1+2=", "1+2=", "7+8=", "7+8="]]))
    sampler = SamplerConfig(max_new_tokens=8, temperature=0.0)
    state = {"n": 0}

    def sync_params():
        state["n"] += 1
        # bump the version after a few polls -> mid-rollout weight sync
        return (params2, 1) if state["n"] > 3 else (params, 0)

    gouts = list(generate_continuous_stream(
        m, params, prompts, jax.random.PRNGKey(0), sampler,
        spec=RolloutSpec(num_slots=2, group=2), sync_params=sync_params))
    assert state["n"] > 3                   # the generator really polled
    tv = np.concatenate([np.asarray(g["token_versions"]) for g in gouts])
    msk = np.concatenate([np.asarray(g["mask"]) for g in gouts]) > 0
    seen = set(int(v) for v in tv[msk])
    assert 1 in seen                        # post-sync tokens are tagged
    assert -1 not in seen                   # padding never leaks into mask


# ---------------------------------------------------------------------------
# Checkpoint round-trips with suspended handles, int8 scales, radix pins
# ---------------------------------------------------------------------------
def test_export_import_roundtrip_with_suspended_int8_radix():
    m, params = get_model("internlm2-1.8b")
    stop = _pick_stop(m, params)

    def fill(eng):
        r0 = _req(rid=0, stop_tokens=(stop,))
        r1 = _req(rid=1, prompt=PROMPT)     # exact-duplicate prompt
        r0.prefix_key = r1.prefix_key = "g0"
        r2 = _req(rid=2, prompt="30+4=")
        for r in (r0, r1, r2):
            eng.submit(r)

    def run_out(eng):
        outs = {}
        while True:
            eng.run()
            for o in eng.harvest():
                outs[o.rid] = o
            sus = eng.harvest_suspended()
            if not sus and eng.idle:
                return outs
            for s in sus:
                n0 = len(s.out.tokens)
                eng.resume(s, (), max_new_tokens=MAX_NEW - n0,
                           rid=100 + s.req.rid, stop_tokens=())
                outs[s.req.rid] = s.out

    kw = dict(prefix_share=True)
    ref_eng = _build(m, params, "mono", "paged", "int8", **kw)
    fill(ref_eng)
    ref_outs = run_out(ref_eng)

    eng = _build(m, params, "mono", "paged", "int8", **kw)
    fill(eng)
    for _ in range(6):                      # mid-flight: pins + partial gens
        eng.step()
    state = eng.export_state()
    fresh = _build(m, params, "mono", "paged", "int8", **kw)
    fresh.import_state(state)
    outs = run_out(fresh)

    assert sorted(outs) == sorted(ref_outs)
    for rid in ref_outs:
        assert outs[rid].tokens == ref_outs[rid].tokens, rid
        np.testing.assert_allclose(outs[rid].logprobs,
                                   ref_outs[rid].logprobs, atol=1e-5)
    fresh.harvest()
    fresh.reset(params)                     # radix pins fully unwound
    fresh.slots.alloc.assert_clean(context="post-roundtrip reset")


# ---------------------------------------------------------------------------
# Recurrent families: rollback-free boundary requires block_size == 1
# ---------------------------------------------------------------------------
def test_rwkv6_suspend_block1_ok_and_blocked_otherwise():
    m, params = get_model("rwkv6-7b")
    ref_t, _ = reference(m, params, _req(max_new=8), max_new=8)
    stop = ref_t[2]
    eng = _build(m, params, "mono", "contiguous", None)
    eng.submit(_req(stop_tokens=(stop,), max_new=8))
    eng.run()
    [sreq] = eng.harvest_suspended()
    eng.resume(sreq, (), max_new_tokens=8 - len(sreq.out.tokens), rid=1,
               stop_tokens=())
    [out] = eng.run()
    assert sreq.out.tokens + out.tokens == ref_t

    fused = _build(m, params, "mono", "contiguous", None, block_size=4)
    with pytest.raises(ValueError, match="block_size"):
        fused.submit(_req(stop_tokens=(stop,), max_new=8))
