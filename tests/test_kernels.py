"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels import ref


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D,causal,window", [
    (2, 128, 4, 2, 64, True, None),
    (1, 100, 4, 4, 32, True, None),       # padding (100 % 64 != 0)
    (2, 256, 8, 2, 64, True, 64),         # sliding window + GQA
    (1, 64, 2, 2, 128, False, None),      # bidirectional (whisper encoder)
    (1, 192, 6, 3, 32, True, None),       # G = 2, odd head count
])
def test_flash_attention_sweep(B, S, H, Hkv, D, causal, window, dtype,
                               rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=64, block_k=64)
    expected = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 5e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,Hkv,D,length,bk", [
    (2, 1024, 8, 2, 64, 700, 128),
    (1, 512, 4, 4, 128, 512, 256),
    (3, 256, 16, 8, 32, 1, 64),           # single live token
    (1, 130, 4, 2, 64, 77, 64),           # ragged padding
])
def test_decode_attention_sweep(B, S, H, Hkv, D, length, bk, dtype, rng_key):
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, S, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, S, Hkv, D), dtype)
    out = decode_attention(q, k, v, length, block_k=bk)
    expected = ref.decode_attention_ref(q, k, v, length)
    tol = 5e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.slow
@pytest.mark.parametrize("B,S,H,Dk,Dv,chunk", [
    (2, 100, 3, 16, 16, 32),
    (1, 64, 2, 64, 64, 64),
    (2, 130, 4, 32, 16, 32),              # ragged padding
    (1, 33, 2, 8, 8, 16),
])
def test_rwkv6_scan_sweep(B, S, H, Dk, Dv, chunk, rng_key):
    ks = jax.random.split(rng_key, 5)
    r = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, Dk)))
    u = jax.random.normal(ks[4], (H, Dk))
    y, st = rwkv6_scan(r, k, v, lw, u, chunk=chunk)
    y_ref, st_ref = ref.rwkv6_scan_ref(r, k, v, lw, u)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-3, atol=5e-4)


def test_kernel_matches_model_attention_path(rng_key):
    """The Pallas flash kernel and the model's blockwise jnp path agree."""
    from repro.models.attention import multi_head_attention
    B, S, H, Hkv, D = 1, 128, 4, 2, 32
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.arange(S)
    a = multi_head_attention(q, k, v, pos, pos, force_blockwise=True)
    b = flash_attention(q, k, v, block_q=64, block_k=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.slow
@pytest.mark.parametrize("B,S,H,N,hd,chunk", [
    (2, 100, 3, 16, 32, 32),
    (1, 64, 2, 64, 64, 64),
    (2, 130, 4, 8, 16, 32),               # ragged padding
])
def test_mamba2_scan_sweep(B, S, H, N, hd, chunk, rng_key):
    from repro.kernels.mamba2_scan import mamba2_scan
    ks = jax.random.split(rng_key, 4)
    r = jax.random.normal(ks[0], (B, S, H, N))
    k = jax.random.normal(ks[1], (B, S, H, N))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, 1)))
    y, st = mamba2_scan(r, k, v, lw, chunk=chunk)
    y_ref, st_ref = ref.mamba2_scan_ref(r, k, v, lw)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=2e-3, atol=5e-4)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               rtol=2e-3, atol=5e-4)
