"""Phase-multiplexed GRPO executors (``rl.coexec``) + the engine contracts
they rely on.

The load-bearing guarantees:

  * ``pipeline`` with the staleness guard forced to sync
    (``max_staleness=0``) is *bit-exact* to the sequential back-to-back
    path — same per-step losses, same final params — while ``>= 1`` only
    ever lags the rollout weights by the guarded bound.
  * ``coexec`` changes the schedule, never the math: each co-executed
    job's losses/params match running that job alone, its state
    warm-starting from the host actor cache between every phase.
  * the round-robin permit timeline is well-formed: zero overlapping
    intervals per pool (run permits are exclusive) and strict job
    alternation once both jobs are queued.
  * warm-start offload/restore round-trips params *and* optimizer state
    bit-exactly (the actor-cache contract the executors lean on).
  * the engine reports "no work" distinctly (no busy spin while waiting on
    late submissions) and can checkpoint/resume live slots mid-flight.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.core.phase_control import RollMuxRuntime
from repro.core.simulator import simulate_profiles
from repro.data import tokenizer as tok
from repro.models import build_model
from repro.rl.coexec import (GRPOJob, MuxConfig, run_coexec, run_pipelined,
                             run_sequential)
from repro.serve import Engine, EngineConfig, Request, run_trace
from repro.train.checkpoints import HostStateCache

_MODELS = {}


def get_model(arch="internlm2-1.8b"):
    if arch not in _MODELS:
        _MODELS[arch] = build_model(arch, reduced=True)
    return _MODELS[arch]


def toy_reward(completions, mask, answers):
    """Deterministic reward with intra-group variance (random-init models
    rarely earn the arithmetic reward, which would zero all advantages)."""
    c = np.asarray(completions, np.int64)
    m = np.asarray(mask)
    return ((c * m).sum(axis=1) % 5).astype(np.float32)


KW = dict(steps=3, batch=2, group=2, max_new=4, temperature=1.0)


def make_job(jid="job0", seed=0, **over):
    kw = {**KW, **over}
    return GRPOJob(jid, model=get_model(), seed=seed, reward_fn=toy_reward,
                   **kw)


def losses(history):
    return [r["loss"] for r in history]


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Equivalence: mux changes the schedule, not the math
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rollout", ["static", "engine"])
def test_pipeline_forced_sync_is_bit_exact(rollout):
    s_off, h_off, r_off = run_sequential(make_job(rollout=rollout))
    s_syn, h_syn, r_syn = run_pipelined(make_job(rollout=rollout),
                                        max_staleness=0)
    assert losses(h_off) == losses(h_syn)
    assert [r["reward"] for r in h_off] == [r["reward"] for r in h_syn]
    assert all(r["rollout_staleness"] == 0 for r in h_syn)
    assert_trees_equal(s_off["params"], s_syn["params"])
    assert_trees_equal(s_off["opt"], s_syn["opt"])
    # back-to-back executes zero overlap by construction
    assert r_off.overlap_s == 0.0


def test_pipeline_staleness_guard_bounds_lag():
    _, hist, _ = run_pipelined(make_job(steps=5), max_staleness=1)
    stale = [r["rollout_staleness"] for r in hist]
    assert all(0 <= s <= 1 for s in stale)
    assert all(np.isfinite(r["loss"]) for r in hist)


def test_coexec_jobs_match_solo_runs_bit_exactly():
    jobs = [make_job("job0", seed=0), make_job("job1", seed=1)]
    states, hists, report = run_coexec(jobs)
    for jid, seed in (("job0", 0), ("job1", 1)):
        s_solo, h_solo, _ = run_sequential(make_job(jid, seed=seed))
        assert losses(hists[jid]) == losses(h_solo), jid
        assert_trees_equal(states[jid]["params"], s_solo["params"])
        assert_trees_equal(states[jid]["opt"], s_solo["opt"])
    # every context switch after seeding was a warm start from host DRAM
    assert report.cache_stats["cold_misses"] == 0
    assert report.cache_stats["warm_hits"] > 0


# ---------------------------------------------------------------------------
# Round-robin permit timeline
# ---------------------------------------------------------------------------
def test_coexec_round_robin_timeline_no_overlap():
    """Deterministic two-job interleaving contract: per pool, permit
    intervals never overlap (the run permit is exclusive) and jobs strictly
    alternate once both are in the FIFO — job X can only re-request a pool
    after its other phase completed, which serializes behind job Y's
    already-queued request."""
    jobs = [make_job("job0", seed=0), make_job("job1", seed=1)]
    _, _, report = run_coexec(jobs)
    for pool in ("rollout", "train"):
        tl = sorted(report.timelines[pool], key=lambda e: e[1])
        assert len(tl) == 2 * KW["steps"]
        # zero overlapping intervals (train especially: one optimizer step
        # at a time on the shared train pool)
        for (_, _, t1_prev), (_, t0_next, _) in zip(tl, tl[1:]):
            assert t0_next >= t1_prev - 1e-9
        users = [who.split(":")[0] for who, _, _ in tl]
        assert set(users) == {"job0", "job1"}
        # strict alternation in the interior (first entry may race)
        for u_prev, u_next in zip(users[1:], users[2:]):
            assert u_prev != u_next, users
    # per-job phase profiles carry one measured duration per executed phase
    for jid in ("job0", "job1"):
        prof = report.profiles[jid]
        assert len(prof.rollout_s) == KW["steps"]
        assert len(prof.train_s) == KW["steps"]
        assert prof.iterations == KW["steps"]


def test_measured_profiles_drive_the_simulator():
    jobs = [make_job("job0", seed=0), make_job("job1", seed=1)]
    _, _, report = run_coexec(jobs)
    res = simulate_profiles(report.profiles.values())
    assert set(res.iter_time) == {"job0", "job1"}
    for jid, prof in report.profiles.items():
        # a job's iteration can't beat its own serial phase sum, and the
        # round-robin bound is phases of both jobs in the cycle
        assert res.iter_time[jid] >= prof.t_roll_mean * 0.5
        assert res.iter_time[jid] <= (sum(p.t_roll + p.t_train
                                          for p in report.profiles.values())
                                      + 1e-6)
    assert 0.0 <= res.rollout_bubble <= 1.0


# ---------------------------------------------------------------------------
# Warm-start actor cache: bit-exact state round trip
# ---------------------------------------------------------------------------
def test_host_cache_roundtrips_train_state_bit_exactly():
    job = make_job()
    state = job.init_state()
    cache = HostStateCache(1 << 30)
    cache.offload("job0/train", state)
    back, dt = cache.restore("job0/train")
    assert dt >= 0
    assert_trees_equal(state["params"], back["params"])
    assert_trees_equal(state["opt"], back["opt"])
    # dtypes survive the host round trip too (bf16/f32 moments alike)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        assert np.asarray(a).dtype == np.asarray(b).dtype


def test_mux_config_validates():
    with pytest.raises(ValueError):
        MuxConfig(mode="sideways")
    with pytest.raises(ValueError):
        MuxConfig(max_staleness=-1)
    assert MuxConfig(mode="pipeline").max_staleness == 1


# ---------------------------------------------------------------------------
# Engine contracts the mux driver relies on
# ---------------------------------------------------------------------------
def _prompt():
    return np.asarray(tok.encode("5+5=", bos=True), np.int32)


def test_engine_step_reports_no_work_distinctly():
    m = get_model()
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=24,
                                         temperature=0.0))
    assert eng.step() == 0 and eng.idle
    eng.submit(Request(rid=0, prompt=_prompt(), max_new_tokens=3))
    assert eng.step() == eng.config.block_size      # did real decode work
    eng.run()
    assert eng.step() == 0                          # drained again


def test_run_trace_sleeps_until_next_arrival_no_spin():
    """An idle engine waiting on a late submission must sleep the gap away,
    not poll: the whole idle window costs O(1) step() calls."""
    m = get_model()
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=24,
                                         temperature=0.0))
    # warm the jit caches so the timed replay only measures scheduling
    eng.submit(Request(rid=-1, prompt=_prompt(), max_new_tokens=2))
    eng.run()
    eng.finished.clear()
    calls = {"n": 0}
    orig = eng.step

    def counting_step():
        calls["n"] += 1
        return orig()

    eng.step = counting_step
    gap = 0.25
    reqs = [Request(rid=0, prompt=_prompt(), max_new_tokens=2,
                    arrival_time=0.0),
            Request(rid=1, prompt=_prompt(), max_new_tokens=2,
                    arrival_time=gap)]
    t0 = time.perf_counter()
    report = run_trace(eng, reqs, realtime=True)
    wall = time.perf_counter() - t0
    assert sorted(o.rid for o in report["outputs"]) == [0, 1]
    assert wall >= gap                       # really waited for the arrival
    # a 10ms-poll busy loop would burn ~gap/10ms calls in the idle window;
    # sleeping until the arrival costs a handful of ticks total
    assert calls["n"] <= 12, calls["n"]


def test_engine_submit_while_running_mid_flight():
    """The mux driver submits while earlier requests are still decoding."""
    m = get_model()
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=24,
                                         temperature=0.0))
    eng.submit(Request(rid=0, prompt=_prompt(), max_new_tokens=4))
    eng.run(max_ticks=2)
    assert not eng.idle                      # preempted with work in flight
    eng.submit(Request(rid=1, prompt=_prompt(), max_new_tokens=2))
    outs = eng.run()
    assert [o.rid for o in outs] == [0, 1]
    assert all(o.num_tokens > 0 for o in outs)


@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_engine_checkpoint_resume_mid_flight_identical(layout):
    """export_state mid-decode + import_state into a fresh engine resumes
    token-for-token (drain/checkpoint of live slots for permit handoff)."""
    m = get_model()
    params = m.init(jax.random.PRNGKey(0))
    cfg = EngineConfig(num_slots=2, max_seq_len=24, temperature=0.0,
                       kv_layout=layout, kv_block_size=4)
    e1 = Engine(m, params, cfg)
    for i in range(5):
        e1.submit(Request(rid=i, prompt=_prompt(), max_new_tokens=3 + i % 3))
    e1.step()
    e1.step()                                # live slots + queued requests
    snap = e1.export_state()
    ref = [(o.rid, o.tokens, o.logprobs) for o in e1.run()]
    e2 = Engine(m, params, cfg)
    e2.import_state(snap)
    got = [(o.rid, o.tokens, o.logprobs) for o in e2.run()]
    assert got == ref
    if layout == "paged":
        e2.slots.check()                     # allocator invariants survived


def test_engine_checkpoint_through_host_cache():
    """The device half of an engine snapshot survives the host-DRAM actor
    cache (offload -> numpy -> device_put) — the coexec suspend path."""
    m = get_model()
    params = m.init(jax.random.PRNGKey(0))
    cfg = EngineConfig(num_slots=2, max_seq_len=24, temperature=0.0)
    e1 = Engine(m, params, cfg)
    for i in range(3):
        e1.submit(Request(rid=i, prompt=_prompt(), max_new_tokens=4))
    e1.step()
    snap = e1.export_state()
    cache = HostStateCache(1 << 30)
    cache.offload("job0/engine", snap["device"])
    dev, _ = cache.restore("job0/engine")
    ref = [(o.rid, o.tokens) for o in e1.run()]
    e2 = Engine(m, params, cfg)
    e2.import_state({"device": dev, "host": snap["host"]})
    assert [(o.rid, o.tokens) for o in e2.run()] == ref


def test_engine_reset_requires_drained_engine():
    m = get_model()
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, EngineConfig(num_slots=1, max_seq_len=24))
    eng.submit(Request(rid=0, prompt=_prompt(), max_new_tokens=3))
    eng.step()
    with pytest.raises(RuntimeError):
        eng.reset()
    eng.run()
    eng.reset(rng=jax.random.PRNGKey(7))
    assert eng.idle and not eng.finished


def test_runtime_permit_records_timeline():
    rt = RollMuxRuntime()
    done = []

    def worker(jid, delay):
        with rt.permit("train", f"{jid}:train"):
            time.sleep(delay)
            done.append(jid)

    ts = [threading.Thread(target=worker, args=(f"j{i}", 0.01))
          for i in range(3)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    tl = sorted(rt.pools["train"].timeline, key=lambda e: e[1])
    assert len(tl) == 3 and len(done) == 3
    for (_, _, t1), (_, t0, _) in zip(tl, tl[1:]):
        assert t0 >= t1 - 1e-9               # capacity-1 pool: no overlap
    assert rt.pools["train"].busy_time > 0
