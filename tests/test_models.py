"""Model-level correctness: prefill+decode vs full forward, ring decode,
blockwise-vs-direct attention, linear-scan chunking."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.configs import list_archs
from repro.models import build_model
from repro.models.attention import multi_head_attention
from repro.models.linear_scan import (chunked_decay_attention,
                                      decay_attention_decode_step,
                                      naive_decay_attention)


@pytest.mark.parametrize("arch", list_archs())
def test_prefill_decode_matches_forward(arch, rng_key):
    m = build_model(arch, reduced=True)
    B, S_p, n_dec = 2, 16, 3
    S = S_p + n_dec
    tokens = jax.random.randint(rng_key, (B, S), 0, m.cfg.vocab_size)
    fr = None
    if m.cfg.frontend == "vision":
        fr = jax.random.normal(rng_key, (B, m.cfg.num_frontend_tokens,
                                         m.cfg.d_model))
    if m.cfg.frontend == "audio":
        fr = jax.random.normal(rng_key, (B, m.cfg.max_source_len,
                                         m.cfg.d_model))
    params = m.init(rng_key)
    ref, _ = m.forward(params, tokens, frontend=fr)
    cache = m.init_cache(B, S)
    lg, cache = m.prefill(params, tokens[:, :S_p], cache, frontend=fr)
    errs = [float(jnp.abs(lg - ref[:, S_p - 1]).max())]
    for t in range(S_p, S):
        lg, cache = m.decode_step(params, tokens[:, t:t + 1], cache)
        errs.append(float(jnp.abs(lg - ref[:, t]).max()))
    assert max(errs) < 2e-3, errs


def test_ring_decode_matches_full_cache_sliding_window(rng_key):
    """Ring-buffer decode == full-cache decode while positions < window, for
    a pure sliding-window config (ring long_500k carve)."""
    import dataclasses
    m = build_model("gemma3-4b", reduced=True)
    # make every layer windowed so ring and full paths share semantics
    cfg = dataclasses.replace(m.cfg, local_global_ratio=0)
    from repro.models.model import Model
    m = Model(cfg)
    w = cfg.sliding_window
    B, steps = 1, 2 * w
    params = m.init(rng_key)
    tokens = jax.random.randint(rng_key, (B, steps), 0, cfg.vocab_size)
    full_cache = m.init_cache(B, steps)
    ring_cache = m.init_cache(B, steps, ring=True)
    assert ring_cache["k"].shape[2] == w < full_cache["k"].shape[2]
    for t in range(steps):
        lf, full_cache = m.decode_step(params, tokens[:, t:t + 1], full_cache)
        lr, ring_cache = m.decode_step(params, tokens[:, t:t + 1],
                                       ring_cache, ring=True)
        err = float(jnp.abs(lf - lr).max())
        assert err < 2e-3, (t, err)


def test_blockwise_attention_matches_direct(rng_key):
    B, S, H, Hkv, D = 2, 192, 4, 2, 32
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.arange(S)
    for window in (None, 64):
        a = multi_head_attention(q, k, v, pos, pos, window=window,
                                 force_blockwise=False)
        b = multi_head_attention(q, k, v, pos, pos, window=window,
                                 force_blockwise=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(2, 6), st.integers(20, 80), st.integers(1, 3),
       st.booleans(), st.integers(8, 32))
def test_chunked_decay_attention_property(chunk_pow, S, H, decay_out, Dk):
    """Property: chunked == naive scan for any shape/chunk/mode."""
    chunk = 2 ** chunk_pow
    key = jax.random.PRNGKey(S * 131 + H)
    B, Dv = 2, 16
    ks = jax.random.split(key, 5)
    r = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, Dk)))
    u = None if decay_out else jax.random.normal(ks[4], (H, Dk))
    y1, s1 = naive_decay_attention(r, k, v, lw, u, decay_in_output=decay_out)
    y2, s2 = chunked_decay_attention(r, k, v, lw, u, chunk=chunk,
                                     decay_in_output=decay_out)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-3, atol=2e-3)


def test_decode_step_matches_naive(rng_key):
    B, S, H, Dk, Dv = 1, 24, 2, 8, 8
    ks = jax.random.split(rng_key, 5)
    r = jax.random.normal(ks[0], (B, S, H, Dk))
    k = jax.random.normal(ks[1], (B, S, H, Dk))
    v = jax.random.normal(ks[2], (B, S, H, Dv))
    lw = -jnp.exp(jax.random.normal(ks[3], (B, S, H, Dk)))
    u = jax.random.normal(ks[4], (H, Dk))
    y_ref, _ = naive_decay_attention(r, k, v, lw, u)
    st_ = jnp.zeros((B, H, Dk, Dv))
    for t in range(S):
        yt, st_ = decay_attention_decode_step(st_, r[:, t], k[:, t], v[:, t],
                                              lw[:, t], u)
        np.testing.assert_allclose(np.asarray(yt), np.asarray(y_ref[:, t]),
                                   rtol=1e-4, atol=1e-4)
