"""Inter-group scheduler (Algorithm 1) behaviour + group DES invariants."""
import numpy as np
import pytest

from repro.core import (CoExecutionGroup, InterGroupScheduler,
                        Node, NodeAllocator, Placement, RLJob, H20, H800)


def mk_job(jid, troll, ttrain, slo=2.0, mem_r=100.0, mem_t=100.0, n=8):
    return RLJob(jid, t_roll=troll, t_train=ttrain, slo=slo,
                 mem_roll_gb=mem_r, mem_train_gb=mem_t,
                 n_roll_gpus=n, n_train_gpus=n)


def test_complementary_jobs_share_cycle():
    sched = InterGroupScheduler(NodeAllocator())
    d1 = sched.schedule(mk_job("a", 100, 90))
    d2 = sched.schedule(mk_job("b", 95, 85))
    assert d2.group is d1.group            # packed together
    res = d1.group.simulate()
    # both share the cycle: t_load = 195 (slightly-overloaded direct pack,
    # Fig 10a semantics) — 2.6% over the 190 s solo cycle, within SLO
    assert res.iter_time["a"] == pytest.approx(195.0)
    assert res.iter_time["b"] == pytest.approx(195.0)
    assert res.iter_time["a"] <= 1.1 * d1.group.t_cycle()


def test_rollout_heavy_jobs_share_train_pool():
    sched = InterGroupScheduler(NodeAllocator())
    for i in range(4):
        d = sched.schedule(mk_job(f"rh{i}", 600, 150, slo=1.5))
    G = d.group
    assert len(G.jobs) == 4
    assert len(G.train_nodes) == 1          # one shared train pool
    assert len(G.rollout_nodes) == 4        # rollout scaling per job
    res = G.simulate()
    for j in G.jobs.values():
        assert res.iter_time[j.job_id] <= j.slo * j.t_solo + 1e-6


def test_saturation_pruning():
    """A saturated group never admits more work (Algorithm 1 line 4)."""
    sched = InterGroupScheduler(NodeAllocator())
    d1 = sched.schedule(mk_job("a", 100, 100, slo=2.0))
    d2 = sched.schedule(mk_job("b", 100, 100, slo=2.0))
    if d2.group is d1.group:
        # group load = 200 train = cycle -> saturated now
        assert d1.group.saturated() or d1.group.t_load() <= d1.group.t_cycle()
        d3 = sched.schedule(mk_job("c", 100, 100, slo=2.0))
        assert d3.group is not d1.group or not d1.group.saturated()


def test_memory_residency_blocks_admission():
    sched = InterGroupScheduler(NodeAllocator())
    big = 900.0  # GB; two of these exceed the 1536 GB node budget
    d1 = sched.schedule(mk_job("a", 600, 100, mem_r=big, mem_t=big))
    d2 = sched.schedule(mk_job("b", 600, 100, mem_r=big, mem_t=big))
    # cannot share the train node: must be a different group
    assert d2.group is not d1.group


def test_slo_admission_rejects_slow_pairing():
    sched = InterGroupScheduler(NodeAllocator())
    d1 = sched.schedule(mk_job("long", 500, 500, slo=2.0))
    # short job with tight SLO cannot absorb the long job's cycle
    d2 = sched.schedule(mk_job("short", 50, 50, slo=1.1))
    assert d2.group is not d1.group


def test_marginal_cost_prefers_packing():
    sched = InterGroupScheduler(NodeAllocator())
    sched.schedule(mk_job("a", 300, 100, slo=2.0))
    d = sched.schedule(mk_job("b", 280, 90, slo=2.0))
    assert d.strategy in ("pack", "scale_rollout")
    assert d.delta_cost < sched._isolated_cost(mk_job("b", 280, 90))


def test_release_frees_nodes():
    alloc = NodeAllocator()
    sched = InterGroupScheduler(alloc)
    sched.schedule(mk_job("a", 100, 90))
    sched.schedule(mk_job("b", 95, 85))
    cost_before = sched.total_cost_per_hour()
    sched.release("a")
    sched.release("b")
    assert sched.total_cost_per_hour() == 0.0
    assert not sched.groups


def test_group_des_migration_improves_packing():
    """Long-tail migration frees rollout nodes early -> faster iterations
    when the shared rollout node is the binding resource (rollout-heavy)."""
    nodes_r = [Node("r0", H20)]
    nodes_t = [Node("t0", H800)]
    G = CoExecutionGroup("g", nodes_r, nodes_t)
    a = mk_job("a", 200, 80)
    b = mk_job("b", 200, 80)
    a.t80_frac = b.t80_frac = 0.5
    G.add_job(a, Placement(("r0",)))
    G.add_job(b, Placement(("r0",)))
    base = G.simulate(migration=False)
    mig = G.simulate(migration=True)
    assert mig.makespan < base.makespan
    assert all(mig.iter_time[j] < base.iter_time[j] - 1e-6 for j in ("a", "b"))


def test_gavel_job_atomic_is_worse():
    nodes_r = [Node("r0", H20), Node("r1", H20)]
    nodes_t = [Node("t0", H800)]
    G = CoExecutionGroup("g", nodes_r, nodes_t)
    G.add_job(mk_job("a", 100, 100), Placement(("r0",)))
    G.add_job(mk_job("b", 100, 100), Placement(("r1",)))
    phased = G.simulate()
    atomic = G.simulate(job_atomic=True)
    assert atomic.iter_time["a"] > phased.iter_time["a"]


def test_decision_latency_scales():
    import time
    from repro.core.trace import make_sim_job
    rng = np.random.default_rng(0)
    sched = InterGroupScheduler(NodeAllocator())
    for i in range(60):
        sched.schedule(make_sim_job(rng, f"j{i}", duration=1e9))
    t0 = time.perf_counter()
    sched.schedule(make_sim_job(rng, "probe", duration=1e9))
    assert time.perf_counter() - t0 < 1.0   # sub-second (paper Table 5)
