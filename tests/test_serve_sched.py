"""Pluggable admission policies (``repro.serve.sched``): decision-logic
unit tests, the bounded-starvation property of ``DeadlinePolicy``, and
engine-level guarantees — every policy's greedy output is bit-identical to
the FIFO engine (admission order changes *when* a request decodes, never
*what* it decodes), deadline-aware head skipping actually reorders
admission, per-job token budgets actually gate, the backpressure path
(``RequestQueue.push`` -> ``Engine.submit`` -> ``run_trace`` deferral)
never crashes, and the SLO contract flows from the inter-group scheduler
into an engine policy.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from test_serve_engine import MAX_LEN, get_model, make_requests, reference

from repro.data import tokenizer as tok
from repro.serve import (DeadlinePolicy, Engine, EngineConfig, FIFOPolicy,
                         Request, RequestQueue, SLOPolicy, make_policy,
                         run_trace)


def req(rid, *, max_new=4, deadline=None, priority=0, job_id=None,
        arrival=0.0, prompt_len=4):
    return Request(rid=rid, prompt=np.zeros(prompt_len, np.int32),
                   max_new_tokens=max_new, arrival_time=arrival,
                   deadline=deadline, priority=priority, job_id=job_id)


# ---------------------------------------------------------------------------
# Policy decision logic (no engine, no model)
# ---------------------------------------------------------------------------
def test_fifo_picks_head_only():
    p = FIFOPolicy()
    waiting = [req(0), req(1)]
    assert p.pick(waiting, lambda r: True) == 0
    # head inadmissible -> nothing, even though rid 1 would fit
    assert p.pick(waiting, lambda r: r.rid != 0) is None
    assert p.pick([], lambda r: True) is None


def test_deadline_orders_by_deadline_then_priority():
    p = DeadlinePolicy()
    waiting = [req(0, deadline=9.0), req(1, deadline=3.0),
               req(2), req(3, deadline=3.0, priority=5)]
    # EDF: rid 3 wins the 3.0 tie on priority; no-deadline sorts last
    assert waiting[p.pick(waiting, lambda r: True)].rid == 3
    waiting = [req(0, deadline=9.0), req(1, deadline=3.0), req(2)]
    assert waiting[p.pick(waiting, lambda r: True)].rid == 1


def test_deadline_skips_blocked_head():
    p = DeadlinePolicy()
    waiting = [req(0, deadline=1.0, max_new=30), req(1, deadline=2.0)]
    # head (earliest deadline) does not fit -> the next deadline does
    assert waiting[p.pick(waiting, lambda r: r.max_new_tokens < 10)].rid == 1


def test_deadline_token_budget_gates_job():
    p = DeadlinePolicy(token_budgets={"j": 10})
    waiting = [req(0, deadline=1.0, job_id="j", max_new=6),
               req(1, deadline=2.0, job_id="k", max_new=6)]
    # job j already has 8 tokens in flight: 8 + 6 > 10 -> rid 1 instead
    i = p.pick(waiting, lambda r: True, live_tokens={"j": 8})
    assert waiting[i].rid == 1
    # budget frees up -> EDF order again
    p2 = DeadlinePolicy(token_budgets={"j": 10})
    assert waiting[p2.pick(waiting, lambda r: True,
                           live_tokens={"j": 4})].rid == 0


def test_slo_policy_derives_deadline_from_bound():
    p = SLOPolicy(slowdown=2.0, time_per_token=0.5)
    r = req(0, max_new=8, arrival=10.0)
    # no explicit deadline: arrival + slowdown * time_per_token * budget
    assert p.effective_deadline(r, now=0.0) == pytest.approx(10.0 + 2 * 4.0)
    r2 = req(1, deadline=11.0)
    assert p.effective_deadline(r2, now=0.0) == 11.0
    # contract plumbing
    p3 = SLOPolicy.from_contract({"jobA": 1.5}, "jobA", time_per_token=0.1)
    assert p3.slowdown == 1.5


def test_make_policy_validates():
    assert isinstance(make_policy("fifo"), FIFOPolicy)
    assert isinstance(make_policy("deadline"), DeadlinePolicy)
    assert isinstance(make_policy("slo"), SLOPolicy)
    with pytest.raises(ValueError):
        make_policy("lifo")
    with pytest.raises(ValueError):
        SLOPolicy(slowdown=0.5)
    with pytest.raises(ValueError):
        EngineConfig(sched="lifo")


# ---------------------------------------------------------------------------
# Bounded starvation: no request is overtaken by newer arrivals more than
# max_skips times, under random deadlines, admissibility and arrivals.
# ---------------------------------------------------------------------------
def _drive_starvation(ops, max_skips):
    p = DeadlinePolicy(max_skips=max_skips)
    waiting: list[Request] = []
    overtakes: dict[int, int] = {}          # rid -> admissions of newer reqs
    born: dict[int, int] = {}               # rid -> arrival order
    rid = 0
    for kind, val in ops:
        if kind == 0:                        # arrival
            dl = None if val % 3 == 0 else float(val % 17)
            waiting.append(req(rid, deadline=dl, priority=val % 2))
            born[rid] = rid
            rid += 1
        else:                                # admission attempt
            # val encodes which requests the engine could admit this round
            admissible = {r.rid for j, r in enumerate(waiting)
                          if (val >> (j % 10)) & 1}
            i = p.pick(waiting, lambda r: r.rid in admissible)
            if i is None:
                continue
            chosen = waiting.pop(i)
            for r in waiting:
                if born[r.rid] < born[chosen.rid]:
                    overtakes[r.rid] = overtakes.get(r.rid, 0) + 1
    for rid_, n in overtakes.items():
        assert n <= max_skips, f"request {rid_} overtaken {n} times"


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 1023)),
                min_size=1, max_size=60),
       st.integers(0, 5))
def test_deadline_policy_bounded_starvation(ops, max_skips):
    _drive_starvation(ops, max_skips)


@pytest.mark.slow
@settings(max_examples=300, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 8191)),
                min_size=1, max_size=200),
       st.integers(0, 7))
def test_deadline_policy_bounded_starvation_sweep(ops, max_skips):
    _drive_starvation(ops, max_skips)


# ---------------------------------------------------------------------------
# Engine-level: every policy produces FIFO-identical greedy output
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("kv", ["contiguous", "paged"])
@pytest.mark.parametrize("sched", ["deadline", "slo"])
def test_policies_bit_identical_to_fifo_engine(sched, kv):
    m, params = get_model("internlm2-1.8b")
    kw = dict(num_slots=2, max_seq_len=MAX_LEN, temperature=0.0)
    if kv == "paged":
        kw.update(kv_layout="paged", kv_block_size=8)

    def run(sched_name):
        eng = Engine(m, params, EngineConfig(sched=sched_name, **kw))
        for i, r in enumerate(make_requests(4, max_new=6)):
            r.deadline = float(10 - i)      # reversed deadlines vs arrival
            eng.submit(r)
        return eng.run()

    base = run("fifo")
    outs = run(sched)
    for r, o, c in zip(make_requests(4, max_new=6), outs, base):
        ref_t, ref_l = reference(m, params, r, max_new=6)
        assert o.tokens == c.tokens == ref_t, (sched, kv, o.rid)
        np.testing.assert_allclose(o.logprobs, c.logprobs, atol=0)
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)


def test_deadline_engine_reorders_admission():
    """One slot, reversed deadlines: the deadline engine admits in EDF
    order while FIFO sticks to arrival order."""
    m, params = get_model("internlm2-1.8b")

    def admit_order(sched):
        eng = Engine(m, params, EngineConfig(
            num_slots=1, max_seq_len=MAX_LEN, temperature=0.0, sched=sched))
        for i, r in enumerate(make_requests(3)):
            r.deadline = float(10 - i)
            eng.submit(r)
        eng.run()
        return [rid for ev, rid, _ in eng.slots.events if ev == "assign"]

    assert admit_order("fifo") == [0, 1, 2]
    assert admit_order("deadline") == [2, 1, 0]


def test_deadline_head_skip_on_block_pressure():
    """Paged pool sized so a big-budget EDF head can't fit while a smaller,
    later deadline can: the head is skipped (FIFO would stall the slot)."""
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0, sched="deadline",
        kv_layout="paged", kv_block_size=8,
        num_kv_blocks=7))                   # rid 0 reserves 6, leaving 1
    prompt = np.asarray(tok.encode("5+5=", bos=True), np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=40,
                       deadline=1.0))      # 6 blocks: takes the whole pool
    eng.step()
    # head needs the whole pool (occupied); rid 2 fits in what's left
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=40,
                       deadline=2.0))
    eng.submit(Request(rid=2, prompt=prompt, max_new_tokens=2,
                       deadline=3.0))
    eng.run()
    order = [rid for ev, rid, _ in eng.slots.events if ev == "assign"]
    assert order == [0, 2, 1]              # rid 2 overtook the blocked rid 1
    for r, o in [(2, eng.finished[2]), (1, eng.finished[1])]:
        assert o.finish_reason == "length"
    eng.slots.check()


def test_engine_stalls_loud_on_impossible_budget():
    """A per-job token budget smaller than a single request's decode budget
    can never admit: the engine raises instead of spinning forever."""
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params,
                 EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                              temperature=0.0),
                 policy=DeadlinePolicy(token_budgets={"j": 2}))
    eng.submit(req(0, max_new=8, job_id="j", prompt_len=6))
    with pytest.raises(RuntimeError, match="stalled"):
        eng.run()


# ---------------------------------------------------------------------------
# Backpressure: full queue defers instead of crashing
# ---------------------------------------------------------------------------
def test_queue_push_backpressure_signal():
    q = RequestQueue(max_waiting=2)
    assert q.push(req(0)) and q.push(req(1))
    assert not q.push(req(2))              # full: refused, not raised
    assert len(q) == 2 and q.rejected == 1
    q.pop()
    assert q.push(req(2))                  # drained: accepted again


def test_engine_submit_backpressure_and_run_trace_defers():
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(
        num_slots=1, max_seq_len=MAX_LEN, temperature=0.0, max_waiting=1))
    reqs = make_requests(4, max_new=3)
    assert eng.submit(reqs[0])
    eng.step()                             # rid 0 admitted into the slot
    assert eng.submit(reqs[1])             # queue: 1 waiting (= max)
    assert not eng.submit(reqs[2])         # full: deferred, not raised
    # run_trace retries deferred submissions and still finishes everything
    eng2 = Engine(m, params, EngineConfig(
        num_slots=1, max_seq_len=MAX_LEN, temperature=0.0, max_waiting=1))
    trace = [Request(rid=i, prompt=r.prompt, max_new_tokens=3,
                     arrival_time=0.0)
             for i, r in enumerate(make_requests(4, max_new=3))]
    report = run_trace(eng2, trace, realtime=False)
    assert sorted(o.rid for o in report["outputs"]) == [0, 1, 2, 3]
    assert report["rejected_submits"] > 0  # backpressure actually happened


# ---------------------------------------------------------------------------
# SLO contract: planner bound -> engine policy -> per-request deadlines
# ---------------------------------------------------------------------------
def test_slo_contract_flows_from_inter_group_scheduler():
    from repro.core import InterGroupScheduler, NodeAllocator, RLJob

    alloc = NodeAllocator(n_rollout_gpus=64, n_train_gpus=64)
    sched = InterGroupScheduler(alloc)
    sched.schedule(RLJob("jobA", t_roll=60, t_train=30, slo=1.8))
    sched.schedule(RLJob("jobB", t_roll=50, t_train=25, slo=1.4))
    contract = sched.slo_contract()
    assert set(contract) == {"jobA", "jobB"}
    # the exported bound is the admitted slo tightened by the margin
    assert contract["jobA"] == pytest.approx(1.8 * sched.admission_margin)
    G = next(iter(sched.groups.values()))
    assert G.slowdown_bound("jobA") == pytest.approx(1.8)
    # group-level bound = tightest co-member
    assert G.slowdown_bound() <= min(contract.values()) / \
        sched.admission_margin + 1e-9

    policy = SLOPolicy.from_contract(contract, "jobA", time_per_token=0.01)
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params,
                 EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                              temperature=0.0, sched="slo"), policy=policy)
    for r in make_requests(3):
        eng.submit(r)
    outs = eng.run()
    for r, o in zip(make_requests(3), outs):
        ref_t, _ = reference(m, params, r)
        assert o.tokens == ref_t           # contract never changes tokens


# ---------------------------------------------------------------------------
# Expired-starving interaction (regression): expiry must not demote a
# request that already hit its skip bound, and an expired barrier still
# blocks younger work — otherwise expired-heavy overload re-opens the
# starvation window the barrier exists to close.
# ---------------------------------------------------------------------------
def test_expired_starving_request_keeps_edf_position():
    """A request at its skip bound with an *expired* deadline must keep its
    EDF position.  The barrier usually leaves it as the only candidate, but
    an older not-starving request can coexist with it (e.g. a rid readmitted
    with stale bookkeeping on a persistent engine): demoting the starving
    request for being expired would then let that older work jump it every
    tick — the wedge the demotion carve-out closes."""
    p = DeadlinePolicy(max_skips=2)
    a = req(0, deadline=50.0)               # older, not urgent, admissible
    b = req(1, deadline=5.0)                # overtaken max_skips times
    waiting = [a, b]
    p._note(waiting)
    p._skips[1] = 2                          # b hit its bound -> barrier
    # b's deadline has expired (now > 5).  Best-effort-last demotion would
    # sort a first and pick it — the regression.  Starving b must win EDF.
    i = p.pick(waiting, lambda r: True, now=10.0)
    assert waiting[i].rid == 1


def test_expired_barrier_still_blocks_younger():
    p = DeadlinePolicy(max_skips=0)          # any refusal makes a barrier
    a = req(0, deadline=5.0, max_new=30)
    # a refused (too big), nothing else -> a is now a barrier
    assert p.pick([a], lambda r: r.max_new_tokens < 10, now=0.0) is None
    b = req(1, deadline=6.0)
    # a's deadline expires; the younger admissible b must still wait
    assert p.pick([a, b], lambda r: r.max_new_tokens < 10, now=20.0) is None
    # a becomes admissible -> served first despite being expired
    waiting = [a, b]
    i = p.pick(waiting, lambda r: True, now=20.0)
    assert waiting[i].rid == 0


def _drive_starvation_with_clock(ops, max_skips):
    """Bounded-starvation sweep with an advancing clock and short deadlines,
    so a large fraction of the queue is *expired* at every decision — the
    regime the expired-demotion bug wedged."""
    p = DeadlinePolicy(max_skips=max_skips)
    waiting: list[Request] = []
    overtakes: dict[int, int] = {}
    born: dict[int, int] = {}
    rid, now = 0, 0.0
    for kind, val in ops:
        now += (val % 5)                     # clock advances past deadlines
        if kind == 0:
            waiting.append(req(rid, deadline=now + float(val % 4)))
            born[rid] = rid
            rid += 1
        else:
            admissible = {r.rid for j, r in enumerate(waiting)
                          if (val >> (j % 10)) & 1}
            i = p.pick(waiting, lambda r: r.rid in admissible, now=now)
            if i is None:
                continue
            chosen = waiting.pop(i)
            for r in waiting:
                if born[r.rid] < born[chosen.rid]:
                    overtakes[r.rid] = overtakes.get(r.rid, 0) + 1
    for rid_, n in overtakes.items():
        assert n <= max_skips, f"request {rid_} overtaken {n} times"


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 1), st.integers(0, 1023)),
                min_size=1, max_size=60),
       st.integers(0, 5))
def test_bounded_starvation_holds_with_expired_deadlines(ops, max_skips):
    _drive_starvation_with_clock(ops, max_skips)


# ---------------------------------------------------------------------------
# on_reset: per-request state drops, measured hardware state survives
# ---------------------------------------------------------------------------
def test_deadline_on_reset_clears_per_request_state():
    p = DeadlinePolicy(max_skips=0)
    a = req(0, deadline=5.0, max_new=30)
    assert p.pick([a], lambda r: r.max_new_tokens < 10) is None
    assert p._skips.get(0, 0) >= 0 and 0 in p._seq
    p.on_reset()
    assert not p._seq and not p._skips
    # next batch reuses rid 0: without the reset it would inherit the old
    # arrival seq (and any barrier status) — now it is simply fresh
    fresh = req(0, deadline=1.0)
    assert p.pick([fresh], lambda r: True) == 0


def test_slo_on_reset_keeps_service_estimate_and_discard_state():
    p = SLOPolicy(time_per_token=0.5)
    p.observe_step(9.0, 1)                   # sample 1: compile, discarded
    p.observe_step(0.2, 2)                   # sample 2: initializes estimate
    assert p.time_per_token == pytest.approx(0.1)
    assert p._step_samples == 2
    p.on_reset()
    # the jit cache survives Engine.reset, so the calibration must too:
    # a re-triggered first-sample discard would throw away a clean step
    assert p.time_per_token == pytest.approx(0.1)
    assert p._step_samples == 2
    p.observe_step(0.3, 3)                   # post-reset step: EMA, no discard
    assert p.time_per_token == pytest.approx(0.7 * 0.1 + 0.3 * 0.1)
    assert not p._seq and not p._skips


def test_slo_observe_step_guards_zero_tokens():
    p = SLOPolicy(time_per_token=0.5)
    p.observe_step(1.0, 0)                   # admitted-only tick: no decode
    p.observe_step(-1.0, 4)                  # clock glitch
    assert p._step_samples == 0              # neither consumed a sample
    assert p.time_per_token == 0.5
    p.observe_step(9.0, 1)
    p.observe_step(0.2, 2)
    assert p.time_per_token == pytest.approx(0.1)   # still NaN/inf-free


def test_engine_reset_calls_policy_on_reset():
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0, sched="deadline"))
    for r in make_requests(2):
        eng.submit(r)
    eng.run()
    assert eng.policy._seq or eng.policy._next_seq > 0
    eng.reset()
    assert not eng.policy._seq and not eng.policy._skips
