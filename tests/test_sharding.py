"""Logical-axis sharding rules: divisibility fallbacks, spec trees."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import list_archs
from repro.models import build_model
from repro.models.sharding import ShardingRules


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_divisibility_fallback():
    rules = ShardingRules()
    mesh = FakeMesh({"data": 16, "model": 16})
    # 28 heads not divisible by 16 -> replicated
    spec = rules.resolve(("embed", "heads", "head_dim"), (3584, 28, 128), mesh)
    assert spec == P("data", None, None)
    # divisible head count -> sharded over model
    spec = rules.resolve(("embed", "heads", "head_dim"), (4096, 32, 128), mesh)
    assert spec == P("data", "model", None)
    # whisper vocab 51865 not divisible -> replicated
    spec = rules.resolve(("embed", "vocab"), (384, 51865), mesh)
    assert spec == P("data", None)


def test_no_double_axis_assignment():
    rules = ShardingRules()
    mesh = FakeMesh({"data": 16, "model": 16})
    # cache: seq grabs model first; kv_heads must not also claim it
    spec = rules.resolve(("layers", "batch", "cache_seq", "kv_heads", None),
                         (24, 128, 32768, 32, 128), mesh)
    assert spec == P(None, "data", "model", None, None)


def test_batch_pod_fallback():
    rules = ShardingRules()
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = rules.resolve(("batch", "seq"), (256, 4096), mesh)
    assert spec == P(("pod", "data"), None)
    # batch=1 (long_500k): replicate
    spec = rules.resolve(("batch", "seq"), (1, 524288), mesh)
    assert spec == P(None, None)


@pytest.mark.parametrize("arch", list_archs())
def test_param_specs_cover_tree(arch):
    """Every param leaf has a logical spec with matching rank."""
    m = build_model(arch, reduced=True)
    params = m.init_abstract()
    specs = m.logical_specs()
    flat_p = jax.tree.leaves(params)
    def is_spec(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
    flat_s = jax.tree.leaves(specs, is_leaf=is_spec)
    assert len(flat_p) == len(flat_s)
    pd = jax.tree.structure(params)
    sd = jax.tree.structure(specs, is_leaf=is_spec)
    assert pd == sd
    for p, s in zip(flat_p, flat_s):
        assert len(s) == len(p.shape), (s, p.shape)


@pytest.mark.parametrize("arch", list_archs())
def test_cache_specs_cover_tree(arch):
    m = build_model(arch, reduced=True)
    cache = jax.eval_shape(lambda: m.init_cache(2, 32))
    specs = m.cache_logical_specs()
    def is_spec(x):
        return isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
    flat_c = jax.tree.leaves(cache)
    flat_s = jax.tree.leaves(specs, is_leaf=is_spec)
    assert len(flat_c) == len(flat_s)
    for c, s in zip(flat_c, flat_s):
        assert len(s) == len(c.shape), (s, c.shape)
