"""Optional-``hypothesis`` shim for the property-based test modules.

When ``hypothesis`` is installed, this module simply re-exports its
``given`` / ``settings`` / ``strategies``.  When it is not (the tier-1
container pins only pytest + jax), a tiny deterministic stand-in replaces
them: each ``@given`` test is run as a seeded-random sweep of
``max_examples`` draws from the declared strategies, so the same value
sequence is exercised on every run.  Only the strategy combinators used by
this suite are implemented (integers / floats / booleans / tuples / lists /
sampled_from).
"""
from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401
except ModuleNotFoundError:
    import random
    import zlib

    class _Strategy:
        def __init__(self, sample_fn):
            self._sample_fn = sample_fn

        def sample(self, rng: random.Random):
            return self._sample_fn(rng)

    class strategies:  # noqa: N801 - mirrors `hypothesis.strategies` module
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

        @staticmethod
        def tuples(*elems):
            return _Strategy(lambda rng: tuple(e.sample(rng) for e in elems))

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(lambda rng: [
                elem.sample(rng)
                for _ in range(rng.randint(min_size, max_size))])

        @staticmethod
        def sampled_from(options):
            options = list(options)
            return _Strategy(lambda rng: options[rng.randrange(len(options))])

    def settings(max_examples: int = 20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            def runner():
                n = getattr(runner, "_max_examples", 20)
                seed = zlib.crc32(fn.__name__.encode("utf-8"))
                rng = random.Random(seed)
                for _ in range(n):
                    fn(*(s.sample(rng) for s in strats))
            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner._max_examples = getattr(fn, "_max_examples", 20)
            return runner
        return deco
