"""Elastic capacity: resize actuators, the closed-loop controller, the
unified telemetry API, and overload admission control.

The master invariant under test is the same one every serving feature in
this repo carries: capacity changes (resizes, admission clamps, permit
retunes) move *when* work runs, never *what* it computes — greedy tokens
of every admitted, non-degraded request are bit-identical to a static
run, a degraded request's tokens are an exact prefix of its unclamped
ones, and shed requests are always recorded, never silently dropped.
"""
import threading
import warnings

import numpy as np
import pytest
from test_serve_engine import MAX_LEN, get_model, reference

from repro.core import MetricsSnapshot
from repro.core.phase_control import PermitPool, PhaseProfile
from repro.data import tokenizer as tok
from repro.serve import (DisaggConfig, DisaggRouter, ElasticConfig,
                         ElasticController, Engine, EngineConfig, Request,
                         rederive_slo, resize_engine, resize_router,
                         run_trace)
from repro.serve.sched import FIFOPolicy, SLOPolicy

PROMPTS = [f"{i}+{i + 1}=" for i in range(8)]


def _requests(n, max_new=6, deadline=None):
    return [Request(rid=i,
                    prompt=np.asarray(tok.encode(PROMPTS[i % len(PROMPTS)],
                                                 bos=True), np.int32),
                    max_new_tokens=max_new, deadline=deadline)
            for i in range(n)]


def _engine(slots, **over):
    m, params = get_model("internlm2-1.8b")
    kw = dict(num_slots=slots, max_seq_len=MAX_LEN, temperature=0.0,
              eos_id=-1)
    kw.update(over)
    return Engine(m, params, EngineConfig(**kw))


# ---------------------------------------------------------------------------
# Resize actuators: live work carried, tokens unchanged, pools conserved
# ---------------------------------------------------------------------------
def test_elastic_trace_matches_static_tokens():
    """End-to-end: a trace replayed through the controller (forced onto a
    1->2->4 growth path) finishes every request with exactly the tokens
    the static engine produces, sheds nothing, and logs its resizes."""
    static = run_trace(_engine(4), _requests(8), realtime=False)
    ctrl = ElasticController(ElasticConfig(
        ladder=(1, 2, 4), interval_s=0.0, cooldown_s=0.0,
        grow_pressure=0.5))
    rep = run_trace(_engine(1), _requests(8), realtime=False,
                    controller=ctrl)
    e = rep["elastic"]
    assert e["resizes"] >= 1 and e["resizes"] == len(e["resize_log"])
    assert e["sheds"] == 0 and e["shed_records"] == []
    assert e["class_counts"]["batch"]["admitted"] == 8
    # capacity log opens at the static shape and tracks every resize
    assert e["capacity_log"][0][1] == 1
    assert [c[1] for c in e["capacity_log"][1:]] == \
        [r[2] for r in e["resize_log"]]
    ref = {o.rid: o.tokens for o in static["outputs"]}
    assert {o.rid for o in rep["outputs"]} == set(ref)
    for o in rep["outputs"]:
        assert o.tokens == ref[o.rid], o.rid


def test_resize_engine_carries_live_work_and_monotone_counters():
    m, params = get_model("internlm2-1.8b")
    eng = _engine(2)
    reqs = _requests(4)
    for r in reqs:
        eng.submit(r)
    for _ in range(3):
        eng.step()
    before = eng.metrics()
    assert before.num_active == 2 and before.queue_depth == 2
    new = resize_engine(eng, 4)
    assert new is not eng and new.config.num_slots == 4
    after = new.metrics()
    # shared counter record: nothing reset, suspend/resume traffic visible
    assert after.steps == before.steps
    assert after.prefills >= before.prefills
    assert after.suspends == before.suspends + 2
    assert after.resumes == after.suspends
    new.run()
    assert sorted(new.finished) == [0, 1, 2, 3]
    for r in reqs:
        ref_t, _ = reference(m, params, r, max_new=6, eos_id=-1)
        assert new.finished[r.rid].tokens == ref_t, r.rid


def test_resize_shrink_refuses_to_strand_live_work():
    eng = _engine(4)
    for r in _requests(4):
        eng.submit(r)
    eng.step()
    assert eng.num_active == 4
    with pytest.raises(ValueError, match="live requests"):
        resize_engine(eng, 2)
    # same-size resize is a no-op, not a rebuild
    assert resize_engine(eng, 4) is eng


def test_resize_conserves_blocks_with_suspended_handle_and_radix():
    """The hard conservation case: the old paged pool holds radix pins
    AND an agentic suspended handle at resize time.  The actuator's
    internal conservation check must pass (handle pins are the only
    residue), the handle must resume on the *new* engine, and the old
    pool must be provably empty once the handle's view materializes."""
    m, params = get_model("internlm2-1.8b")
    eng = _engine(2, kv_layout="paged", kv_block_size=4, num_kv_blocks=64,
                  prefix_share=True)
    reqs = _requests(3, max_new=6)
    for r in reqs:
        eng.submit(r)
    for _ in range(2):
        eng.step()
    held = eng._suspend_slot(sorted(eng._active)[0])
    assert held.req.rid in {s.req.rid for s in eng.suspended.values()}
    new = resize_engine(eng, 4)         # conservation asserted inside
    new.resume(held, continue_output=True)
    new.run()
    assert sorted(new.finished) == [0, 1, 2]
    for r in reqs:
        ref_t, _ = reference(m, params, r, max_new=6, eos_id=-1)
        assert new.finished[r.rid].tokens == ref_t, r.rid
    # the handle's pins were released at materialization: old pool clean
    # (the old radix was flushed by the resize — its snapshots referenced
    # the old pool)
    eng.slots.alloc.assert_clean(context="test")
    new.radix.flush()                   # drop the new tree's live pins
    new.slots.alloc.assert_clean(context="test")


def test_elastic_router_trace_matches_static_tokens():
    m, params = get_model("internlm2-1.8b")

    def build(decode):
        return DisaggRouter(m, params, DisaggConfig(
            prefill_slots=1, decode_slots=decode, max_seq_len=MAX_LEN,
            temperature=0.0, eos_id=-1))

    static = run_trace(build(4), _requests(6), realtime=False)
    ctrl = ElasticController(ElasticConfig(
        ladder=(1, 2, 4), interval_s=0.0, cooldown_s=0.0,
        grow_pressure=0.5))
    rep = run_trace(build(1), _requests(6), realtime=False, controller=ctrl)
    assert rep["elastic"]["resizes"] >= 1
    ref = {o.rid: o.tokens for o in static["outputs"]}
    assert {o.rid for o in rep["outputs"]} == set(ref)
    for o in rep["outputs"]:
        assert o.tokens == ref[o.rid], o.rid


# ---------------------------------------------------------------------------
# Unified telemetry: one snapshot shape, warn-once legacy shims
# ---------------------------------------------------------------------------
def test_stats_shims_warn_once_and_metrics_is_silent():
    import repro.serve.engine as em
    import repro.serve.router as rm

    m, params = get_model("internlm2-1.8b")
    eng = _engine(2)
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0))
    for mod, obj, label in ((em, eng, "Engine"), (rm, router,
                                                  "DisaggRouter")):
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            mod._warned_legacy[0] = False   # fresh process view
            obj.stats
            obj.stats                       # second access: no new warning
        deps = [x for x in w if issubclass(x.category, DeprecationWarning)]
        assert len(deps) == 1, label
        assert "metrics()" in str(deps[0].message)
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        assert isinstance(eng.metrics(), MetricsSnapshot)
        assert isinstance(router.metrics(), MetricsSnapshot)
    assert not [x for x in w if issubclass(x.category, DeprecationWarning)]


def test_metrics_snapshot_merge_rules():
    a = MetricsSnapshot(source="engine", steps=10, decode_time_s=1.0,
                        peak_active=3, queue_depth=5, num_slots=4,
                        pool_busy_frac={"rollout": 0.5},
                        attainment={"interactive": 1.0})
    b = MetricsSnapshot(source="runtime", steps=2, decode_time_s=0.5,
                        peak_active=2, queue_depth=0, num_slots=0,
                        pool_busy_frac={"rollout": 0.9, "train": 0.1})
    m = a.merge(b)
    assert m.source == "engine+runtime"
    assert m.steps == 12                          # counters sum
    assert m.peak_active == 3                     # peaks max
    assert m.queue_depth == 5 and m.num_slots == 4  # gauges: b unset -> a
    assert m.pool_busy_frac == {"rollout": 0.9, "train": 0.1}  # dict union
    assert m.attainment == {"interactive": 1.0}
    # gauge where b carries a reading: b wins
    c = a.merge(MetricsSnapshot(queue_depth=1))
    assert c.queue_depth == 1
    # derived ratios
    assert m.time_per_token == pytest.approx(1.5 / 12)
    assert a.queue_pressure == pytest.approx(5 / 4)
    assert MetricsSnapshot.merged([a, b]).steps == 12
    assert "time_per_token" in a.to_dict()


def test_engine_and_router_metrics_share_one_shape():
    m, params = get_model("internlm2-1.8b")
    eng = _engine(2)
    for r in _requests(3):
        eng.submit(r)
    eng.run()
    snap = eng.metrics()
    assert snap.source == "engine"
    assert snap.prefills == 3 and snap.generated_tokens > 0
    assert 0.0 < snap.slot_utilization <= 1.0
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0, eos_id=-1))
    for r in _requests(3):
        router.submit(r)
    router.run()
    rs = router.metrics()
    assert rs.source == "router"
    assert rs.transfers == 3 and rs.prefills >= 3
    assert rs.num_slots == 2                  # decode plane gauge
    # snapshots merge across components without shape knowledge
    assert snap.merge(rs).transfers == 3


# ---------------------------------------------------------------------------
# Overload admission control: degrade before shed, never silent
# ---------------------------------------------------------------------------
def _seed_served(engine, time_per_token=0.05, steps=100):
    """Give the engine a measured decode history so the admission
    predictor has a real time-per-token to reason from."""
    engine._stats.steps += steps
    engine._stats.decode_time_s += time_per_token * steps


def test_admission_gate_degrades_then_sheds_and_records():
    eng = _engine(2)
    _seed_served(eng, time_per_token=0.05)
    ctrl = ElasticController(ElasticConfig(
        ladder=(2,), shed=True, min_degrade_tokens=8))
    ctrl.attach(eng, 0.0)
    # plenty of slack: admitted at full budget
    v, r = ctrl.admit(_requests(1, max_new=10, deadline=10.0)[0], 0.0, eng)
    assert v == "admit" and r.max_new_tokens == 10
    # slack fits 8..31 tokens at 0.05 s/tok: degraded, budget clamped
    req = Request(rid=1, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=32, deadline=1.0)
    v, clamped = ctrl.admit(req, 0.0, eng)
    assert v == "degrade"
    assert 8 <= clamped.max_new_tokens < 32
    assert ctrl.degrade_records[0]["rid"] == 1
    # deadline already unmeetable even at the minimum budget: shed
    req = Request(rid=2, prompt=np.arange(4, dtype=np.int32),
                  max_new_tokens=32, deadline=0.1)
    v, _ = ctrl.admit(req, 0.0, eng)
    assert v == "shed"
    assert ctrl.shed_records[0]["rid"] == 2
    assert "deadline" in ctrl.shed_records[0]["reason"]
    cc = ctrl.class_counts["interactive"]
    assert cc == {"admitted": 2, "degraded": 1, "shed": 1}
    # driver retry after queue backpressure: cached verdict, no recount
    v2, _ = ctrl.admit(req, 0.5, eng)
    assert v2 == "shed"
    assert len(ctrl.shed_records) == 1
    assert ctrl.class_counts["interactive"]["shed"] == 1


def test_subsaturation_sheds_exactly_zero():
    """The predictor is conservative by construction: with no measured
    service time, or with deadlines the measured backlog provably meets,
    nothing is shed — even with admission control armed."""
    ctrl = ElasticController(ElasticConfig(ladder=(2,), shed=True))
    rep = run_trace(_engine(2), _requests(6, deadline=1e9), realtime=False,
                    controller=ctrl)
    assert rep["elastic"]["sheds"] == 0
    assert rep["elastic"]["degrades"] == 0
    assert len(rep["outputs"]) == 6


def test_overload_sheds_are_reported_not_silent():
    eng = _engine(2)
    _seed_served(eng, time_per_token=0.2)      # slow engine, hard deadlines
    ctrl = ElasticController(ElasticConfig(ladder=(2,), shed=True))
    reqs = _requests(4, max_new=6, deadline=1e-4)
    rep = run_trace(eng, reqs, realtime=False, controller=ctrl)
    e = rep["elastic"]
    assert e["sheds"] == 4 == len(e["shed_records"])
    assert sorted(r["rid"] for r in e["shed_records"]) == [0, 1, 2, 3]
    assert len(rep["outputs"]) == 0
    # accounting closes: every arrival is admitted, degraded-admitted,
    # or shed — nothing vanishes
    cc = e["class_counts"]["interactive"]
    assert cc["admitted"] + cc["shed"] == len(reqs)


def test_degraded_budget_yields_exact_prefix():
    """A degrade is a max_new clamp and nothing else: the clamped
    request's greedy tokens are an exact prefix of the unclamped run."""
    m, params = get_model("internlm2-1.8b")
    full = _requests(1, max_new=8)[0]
    eng = _engine(1)
    eng.submit(full)
    eng.run()
    long_toks = eng.finished[0].tokens
    eng2 = _engine(1)
    eng2.submit(Request(rid=0, prompt=full.prompt, max_new_tokens=4))
    eng2.run()
    short = eng2.finished[0].tokens
    assert short == long_toks[:len(short)] and len(short) == 4


# ---------------------------------------------------------------------------
# SLO re-derivation from measured profiles
# ---------------------------------------------------------------------------
def test_rederive_slo_updates_policy_from_profiles():
    class FakeRuntime:
        def phase_profiles(self):
            return {"job0": PhaseProfile(job_id="job0",
                                         rollout_s=(2.0, 2.2),
                                         train_s=(1.0, 1.1))}

    policy = SLOPolicy(slowdown=2.0)
    bound = rederive_slo(policy, FakeRuntime())
    assert bound is not None and bound >= 1.0
    assert policy.slowdown == bound
    # no contract / no runtime / no profiles: explicit no-op
    assert rederive_slo(FIFOPolicy(), FakeRuntime()) is None
    assert rederive_slo(policy, None) is None

    class EmptyRuntime:
        def phase_profiles(self):
            return {}

    assert rederive_slo(policy, EmptyRuntime()) is None


# ---------------------------------------------------------------------------
# Radix boundary-snapshot TTL demotion
# ---------------------------------------------------------------------------
def test_radix_snapshot_ttl_demotion_counts_and_survives_roundtrip():
    eng = _engine(2, kv_layout="paged", kv_block_size=4, num_kv_blocks=64,
                  prefix_share=True)
    reqs = [Request(rid=i, prompt=np.asarray(tok.encode("12+34=", bos=True),
                                             np.int32), max_new_tokens=4)
            for i in range(2)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    radix = eng.radix
    assert radix.stats["snapshots"] >= 1
    n_before = radix.stats["snapshots"]
    assert radix.demote_stale(10 ** 9) == 0          # generous ttl: keep all
    # age everything past the horizon, then demote
    for _ in range(5):
        radix._bump()
    n = radix.demote_stale(0)
    assert n == n_before
    assert radix.stats["snapshots"] == 0
    assert radix.stats["snapshot_demotions"] == n
    assert eng.metrics().snapshot_demotions == n
    # tree structure (and block pins) untouched: still block-shares
    assert radix.stats["pinned_blocks"] > 0
    # counters and last_used survive the checkpoint round-trip
    host, dev = radix.export_host_state(), radix.export_device_state()
    assert host["counters"]["demotions"] == n
    eng2 = _engine(2, kv_layout="paged", kv_block_size=4, num_kv_blocks=64,
                   prefix_share=True)
    eng2.radix.import_state(host, dev)
    assert eng2.radix.snapshot_demotions == n
    assert {x.last_used for x in eng2.radix.nodes.values()} == \
        {x.last_used for x in radix.nodes.values()}


# ---------------------------------------------------------------------------
# Router restore: re-routed spread + shared policy (PR 9 residual)
# ---------------------------------------------------------------------------
def test_router_requeue_spreads_over_prefill_engines():
    m, params = get_model("internlm2-1.8b")
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0, prefill_engines=2))
    # one shared admission-policy object across every prefill engine
    assert len({id(pe.policy) for pe in router.prefills}) == 1
    router._requeue(_requests(6, max_new=4))
    lens = [len(pe.queue._q) for pe in router.prefills]
    assert sum(lens) == 6
    assert all(n > 0 for n in lens), lens    # spread, not engine-0 pile-up


# ---------------------------------------------------------------------------
# PermitPool.resize: grow wakes waiters, shrink never revokes
# ---------------------------------------------------------------------------
def test_permit_pool_resize_under_contention():
    pool = PermitPool("reward", capacity=1)
    pool.acquire()
    got = threading.Event()

    def waiter():
        pool.acquire()
        got.set()

    t = threading.Thread(target=waiter)
    t.start()
    assert not got.wait(0.1)                # blocked behind the bound
    assert pool.waiting == 1
    pool.resize(2)                          # grow: waiter admitted now
    assert got.wait(2.0)
    t.join()
    pool.resize(1)                          # shrink with 2 permits held
    pool.release()                          # neither holder was revoked
    pool.release()
    assert pool.waiting == 0
    pool.acquire()                          # bound is 1 again
    reacquired = threading.Event()
    t2 = threading.Thread(target=lambda: (pool.acquire(), reacquired.set()))
    t2.start()
    assert not reacquired.wait(0.1)
    pool.release()
    assert reacquired.wait(2.0)
    t2.join()
    pool.release()
    with pytest.raises(ValueError):
        pool.resize(0)


# ---------------------------------------------------------------------------
# Streaming executor: permit retune rides the same telemetry loop
# ---------------------------------------------------------------------------
def test_stream_elastic_retunes_permits_without_changing_math():
    from test_stream import make_job

    from repro.rl.stream import run_streaming

    _, h_ref, _ = run_streaming(make_job(), max_staleness=0,
                                reward_workers=3)
    _, h_el, _ = run_streaming(make_job(), max_staleness=0,
                               reward_workers=3, elastic=True)
    assert [r["loss"] for r in h_ref] == [r["loss"] for r in h_el]
    assert all(1 <= r["reward_permits"] <= 3 for r in h_el)
    assert all("reward_permits" not in r for r in h_ref)
