"""RL substrate: rewards, GRPO advantages, PG loss, rollout semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.data import ArithmeticTask, tokenizer as tok
from repro.models import build_model
from repro.rl import (SamplerConfig, arithmetic_reward, generate,
                      group_advantages, policy_gradient_loss)


def test_tokenizer_roundtrip():
    s = "12+34=46"
    assert tok.decode(tok.encode(s)) == s
    batch = tok.pad_batch([tok.encode("7+8=")], 10)
    assert batch.shape == (1, 10)
    assert batch[0, 0] == tok.PAD


def test_task_answers():
    t = ArithmeticTask(seed=0)
    b = t.sample_batch(16)
    for txt, ans in zip(b.prompt_text, b.answers):
        a, rest = txt.split("+") if "+" in txt else txt.split("-")
        bnum = rest[:-1]
        expect = int(a) + int(bnum) if "+" in txt else int(a) - int(bnum)
        assert str(expect) == ans


def test_arithmetic_reward():
    # completions: "46" exact, "4x" junk, "12" wrong-but-numeric
    seqs = [tok.encode("46") + [tok.EOS], tok.encode("4x") + [tok.EOS],
            tok.encode("12") + [tok.EOS]]
    comp = np.full((3, 4), tok.EOS, np.int32)
    mask = np.zeros((3, 4), np.float32)
    for i, s in enumerate(seqs):
        comp[i, :len(s)] = s
        mask[i, :len(s) - 1] = 1.0   # mask covers pre-EOS tokens
    r = arithmetic_reward(jnp.asarray(comp), jnp.asarray(mask),
                          ["46", "46", "46"])
    assert r[0] == 1.0 and r[1] == 0.0 and r[2] == pytest.approx(0.1)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.floats(0, 1), min_size=8, max_size=8))
def test_group_advantages_zero_mean(rs):
    adv = group_advantages(np.asarray(rs, np.float32), group_size=4)
    g = adv.reshape(-1, 4)
    np.testing.assert_allclose(g.mean(axis=1), 0.0, atol=1e-5)


def test_policy_gradient_clipping():
    B, S, V = 2, 4, 11
    key = jax.random.PRNGKey(0)
    logits = jax.random.normal(key, (B, S, V))
    labels = jax.random.randint(key, (B, S), 0, V)
    adv = jnp.ones((B, S))
    mask = jnp.ones((B, S))
    # behaviour logp far from current -> heavy clipping
    beh = jnp.full((B, S), -20.0)
    _, m = policy_gradient_loss(logits, labels, adv, mask,
                                behavior_logp=beh, clip_eps=0.2)
    assert float(m["clip_frac"]) == 1.0
    # on-policy: no clipping
    from repro.rl.grpo import token_logprobs
    beh2 = token_logprobs(logits, labels)
    _, m2 = policy_gradient_loss(logits, labels, adv, mask,
                                 behavior_logp=beh2, clip_eps=0.2)
    assert float(m2["clip_frac"]) == 0.0


def test_generate_stops_masking_after_eos(rng_key):
    m = build_model("internlm2-1.8b", reduced=True)
    params = m.init(rng_key)
    prompts = jnp.asarray(tok.pad_batch([tok.encode("1+1=", bos=True)] * 2, 8))
    out = generate(m, params, prompts, rng_key,
                   SamplerConfig(max_new_tokens=6, temperature=1.0))
    assert out["completions"].shape == (2, 6)
    assert out["mask"].shape == (2, 6)
    mask = np.asarray(out["mask"])
    comp = np.asarray(out["completions"])
    for b in range(2):
        seen_eos = False
        for t in range(6):
            if seen_eos:
                assert mask[b, t] == 0.0
            if comp[b, t] == tok.EOS:
                seen_eos = True
    # behaviour logprobs are valid log-probabilities
    assert np.all(np.asarray(out["behavior_logp"]) <= 0.0)
