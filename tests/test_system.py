"""End-to-end system tests: the full synchronous on-policy RL loop under the
RollMux phase-centric runtime (real execution plane), plus co-execution of
two jobs on shared pools."""
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.phase_control import RollMuxRuntime
from repro.data import ArithmeticTask
from repro.launch.train import build_train_batch, run_training
from repro.models import build_model
from repro.rl import (SamplerConfig, arithmetic_reward, generate,
                      group_advantages, init_train_state, make_train_step)
from repro.sync import sync_params_between_jobs


def test_single_job_rl_loop_runs():
    """A few real GRPO iterations: rollout -> reward -> train -> sync."""
    _, hist = run_training("internlm2-1.8b", reduced=True, steps=3,
                           batch=2, group=2, max_new=4, log_every=100)
    assert len(hist) == 3
    assert all(np.isfinite(h["loss"]) for h in hist)


def test_co_executed_jobs_under_runtime():
    """Two RL jobs time-multiplex the rollout/train pools via the
    phase-centric runtime; both make progress, switches are warm."""
    rt = RollMuxRuntime(host_cache_gb=4.0)
    rt.pool("rollout", 1)
    rt.pool("train", 1)
    results = {}

    def make_job(jid, seed):
        model = build_model("internlm2-1.8b", reduced=True)
        key = jax.random.PRNGKey(seed)
        task = ArithmeticTask(seed=seed)
        sampler = SamplerConfig(max_new_tokens=4)
        train_step = jax.jit(make_train_step(model, remat=False))

        def init_rollout():
            return {"params": init_train_state(model, key)["params"]}

        def init_train():
            return init_train_state(model, key)

        @rt.phase("rollout", name="roll", init_fn=init_rollout)
        def roll(state, prompts, k):
            out = generate(model, state["params"], prompts, k, sampler)
            return state, out

        @rt.phase("train", name="train", init_fn=init_train)
        def train(state, batch):
            state, metrics = train_step(state, batch)
            return state, (state["params"], metrics)

        def loop(iters=2):
            k = key
            for i in range(iters):
                b = task.sample_batch(2)
                prompts = jnp.asarray(np.repeat(b.prompts, 2, axis=0))
                k, k1 = jax.random.split(k)
                out = roll(jid, prompts, k1)
                answers = [a for a in b.answers for _ in range(2)]
                r = arithmetic_reward(out["completions"], out["mask"], answers)
                adv = group_advantages(r, 2)
                tb = build_train_batch(out, adv, b.prompts.shape[1])
                new_params, metrics = train(jid, tb)
                # sync phase: push updated weights into the rollout actor
                rstate, _ = rt.cache.restore(f"{jid}/rollout")
                rstate["params"] = sync_params_between_jobs(
                    new_params, rstate["params"])
                rt.cache.offload(f"{jid}/rollout", rstate)
            results[jid] = float(metrics["loss"])
        return loop

    threads = [threading.Thread(target=make_job(f"job{i}", i))
               for i in range(2)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()

    assert set(results) == {"job0", "job1"}
    assert all(np.isfinite(v) for v in results.values())
    # both pools served both jobs (co-execution happened)
    for pool in ("rollout", "train"):
        users = {w.split(":")[0] for w, _, _ in rt.pools[pool].timeline}
        assert users == {"job0", "job1"}
    # warm starts dominate after the first (cold) touch
    for i in range(2):
        s = rt.stats[f"job{i}:roll"]
        assert s.cold_starts == 1
        assert s.warm_starts == s.runs - 1
