"""Topology-aware model sync: analytic model + collective structure."""
import jax
import pytest

from repro.sync import ClusterTopology, sync_params_between_jobs


def test_single_node_speedup_matches_paper():
    topo = ClusterTopology()
    s = topo.speedup_single_node(14e9, 8)
    # paper Fig 12: 7.87-8.33x for 8 H800 -> 8 H20
    assert 6.5 <= s <= 9.0


def test_multi_node_speedup_positive():
    topo = ClusterTopology()
    s = topo.speedup_multi_node(28e9, 16)
    assert s > 1.5   # paper: 2.62-2.75x (our ring model is conservative)


def test_one_copy_crosses_slow_link():
    topo = ClusterTopology()
    m = 10e9
    t_hier = topo.hierarchical_time_s(m, 8, 8)
    # stage-1 time == exactly one copy over the slow link (fast stage ~free)
    one_copy = m * 8 / (topo.inter_cluster_gbps * 1e9 * topo.stream_efficiency)
    assert t_hier == pytest.approx(one_copy, rel=0.05)


def test_warm_vs_cold_start_gap():
    topo = ClusterTopology()
    state = 275e9   # 7B rollout actor (paper Table 2)
    cold = topo.cold_start_s(state)
    warm = topo.warm_start_s(state)
    assert cold / warm > 10          # paper: up to 48x
    assert cold > 60                 # paper Fig 4: up to ~80 s


def test_sync_params_between_jobs():
    a = {"w": jax.numpy.ones(3)}
    b = {"w": jax.numpy.zeros(3)}
    out = sync_params_between_jobs(a, b)
    assert float(out["w"].sum()) == 3.0


@pytest.mark.skipif(jax.device_count() < 16,
                    reason="hierarchical sync collectives need a 2x8 mesh "
                           "(covered by benchmarks/model_sync.py subprocess)")
def test_hierarchical_sync_collectives():
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.sync import hierarchical_sync, make_sync_mesh
    mesh = make_sync_mesh(8)
    flat = jax.numpy.arange(8 * 100, dtype=jax.numpy.bfloat16) % 97
    x = jax.device_put(flat, NamedSharding(mesh, P("intra")))
    out = np.asarray(hierarchical_sync(mesh, x))
    assert (out[1, 0] == np.asarray(flat)).all()
