"""Phase-centric execution plane: permits, warm starts, interleaving."""
import threading
import time

import numpy as np

from repro.core.phase_control import PermitPool, RollMuxRuntime
from repro.train.checkpoints import HostStateCache


def test_host_cache_roundtrip():
    cache = HostStateCache(capacity_bytes=1 << 30)
    tree = {"w": np.arange(100, dtype=np.float32),
            "b": {"x": np.ones((3, 3))}}
    cache.offload("job1/train", tree)
    out, dt = cache.restore("job1/train")
    assert dt >= 0
    np.testing.assert_array_equal(np.asarray(out["w"]), tree["w"])
    missing, _ = cache.restore("nope")
    assert missing is None
    assert cache.stats["warm_hits"] == 1 and cache.stats["cold_misses"] == 1


def test_permit_pool_fifo():
    pool = PermitPool("p", capacity=1)
    order = []

    def worker(i):
        time.sleep(0.01 * i)
        pool.acquire()
        order.append(i)
        time.sleep(0.01)
        pool.release()

    ts = [threading.Thread(target=worker, args=(i,)) for i in range(4)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert sorted(order) == [0, 1, 2, 3]


def test_runtime_phases_interleave_and_warm_start():
    """Two jobs' rollout/train phases time-multiplex the two pools; after the
    first (cold) touch every switch is warm (paper §5.1)."""
    rt = RollMuxRuntime(host_cache_gb=1.0)
    rt.pool("rollout", 1)
    rt.pool("train", 1)
    events = []

    @rt.runtime_hook
    def trace(job, phase, ev):
        events.append((job, phase, ev))

    def make_phases(jid):
        @rt.phase("rollout", name="roll", init_fn=lambda: {"n": np.zeros(4)})
        def roll(state):
            time.sleep(0.01)
            return {"n": state["n"] + 1}, float(state["n"].sum())

        @rt.phase("train", name="train", init_fn=lambda: {"w": np.zeros(4)})
        def train(state, x):
            time.sleep(0.01)
            return {"w": state["w"] + x}, None
        return roll, train

    def job_loop(jid, iters=3):
        roll, train = make_phases(jid)
        for _ in range(iters):
            out = roll(jid)
            train(jid, out)

    ts = [threading.Thread(target=job_loop, args=(f"j{i}",)) for i in range(2)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()

    stats = rt.stats
    for jid in ("j0", "j1"):
        s = stats[f"{jid}:roll"]
        assert s.runs == 3
        assert s.cold_starts == 1 and s.warm_starts == 2
    # both pools actually multiplexed between the two jobs
    roll_users = {w.split(":")[0] for w, _, _ in rt.pools["rollout"].timeline}
    assert roll_users == {"j0", "j1"}
    # state accumulated across suspends (warm restore preserved data)
    final, _ = rt.cache.restore("j0/rollout")
    assert final["n"].sum() == 12  # 3 increments x 4 elems
