"""Theorem 1 (round-robin utilization optimality) as property-based tests."""
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.theory import check_theorem1, make_group

dur = st.floats(20.0, 400.0)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(dur, dur), min_size=2, max_size=4))
def test_round_robin_beats_repetition(pairs):
    """For any unsaturated group, repeating any job's phases lowers aggregate
    utilization (Theorem 1, appendix)."""
    t_rolls = [p[0] for p in pairs]
    t_trains = [p[1] for p in pairs]
    G = make_group(t_rolls, t_trains)
    if G.saturated():
        return  # theorem's precondition
    res = check_theorem1(t_rolls, t_trains)
    # Theorem 1's content: REPETITION is strictly suboptimal
    assert res["max_repetition"] <= res["round_robin"] + 1e-6
    # orders are equivalent for clearly-unsaturated groups; near the
    # saturation boundary finite-horizon transients cause small diffs
    G = make_group(t_rolls, t_trains)
    if G.t_load() <= 0.9 * G.t_cycle():
        assert res["max_order"] <= res["round_robin"] * 1.005 + 1e-6
    else:
        assert res["max_order"] <= res["round_robin"] * 1.03 + 1e-6


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(dur, dur), min_size=1, max_size=4))
def test_unsaturated_group_achieves_cycle_time(pairs):
    """Meta-iteration of an unsaturated group completes in T_cycle — every
    member's iteration time equals the longest job's solo time."""
    G = make_group([p[0] for p in pairs], [p[1] for p in pairs])
    if G.saturated():
        return
    res = G.simulate(n_cycles=30, discard=8)
    t_cycle = G.t_cycle()
    for jid, it in res.iter_time.items():
        assert it <= t_cycle + 1e-6
    assert max(res.iter_time.values()) == pytest.approx(t_cycle, rel=1e-6)


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(dur, dur), min_size=2, max_size=4))
def test_monotonic_in_durations(pairs):
    """Strict-RR schedule is monotone: scaling all phase durations down never
    increases any job's iteration time (no scheduling anomalies) — the
    property that makes conservative admission a guarantee."""
    t_rolls = [p[0] for p in pairs]
    t_trains = [p[1] for p in pairs]
    G1 = make_group(t_rolls, t_trains)
    G2 = make_group([t * 0.7 for t in t_rolls], [t * 0.7 for t in t_trains])
    r1 = G1.simulate(n_cycles=20, discard=5)
    r2 = G2.simulate(n_cycles=20, discard=5)
    for j in r1.iter_time:
        assert r2.iter_time[j] <= r1.iter_time[j] + 1e-6


def test_saturated_group_exceeds_cycle():
    G = make_group([100, 100, 100], [100, 100, 100])
    assert G.saturated()
    res = G.simulate(n_cycles=30, discard=8)
    assert max(res.iter_time.values()) > G.t_cycle() - 1e-6
