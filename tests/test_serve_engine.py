"""Continuous-batching engine: equivalence with per-request ``generate``,
slot-manager invariants, and FIFO admission fairness.

Equivalence is the engine's core guarantee: greedy decoding through the
slot pool (fewer slots than requests, so queueing + recycling actually
happen) must produce token-identical outputs and matching behaviour
logprobs to running ``rl.rollout.generate`` one request at a time — in
BOTH KV layouts (contiguous slot stripes and the paged block pool).
Covered architectures: attention (internlm2), rwkv6 (SSM state cache) and
gemma3 (sliding-window attention layers); the paged cases include mixed
prompt-length traces and a block size that forces block-boundary
crossings mid-decode.  Deeper paged-only coverage (allocator/slot-manager
property sweeps, gated admission, the block-table kernel) lives in
``tests/test_serve_paged.py``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import tokenizer as tok
from repro.models import build_model
from repro.rl import SamplerConfig, generate, generate_continuous
from repro.serve import Engine, EngineConfig, Request

MAX_LEN = 48          # shared across tests so jitted engine fns are reused
PROMPTS = ["1+2=", "10+20=", "7+8=", "30+4="]

_MODELS = {}


def get_model(arch):
    if arch not in _MODELS:
        m = build_model(arch, reduced=True)
        _MODELS[arch] = (m, m.init(jax.random.PRNGKey(1)))
    return _MODELS[arch]


def make_requests(n, max_new=5):
    return [Request(rid=i, prompt=np.asarray(tok.encode(p, bos=True),
                                             np.int32),
                    max_new_tokens=max_new)
            for i, p in enumerate(PROMPTS[:n])]


def reference(m, params, req, *, max_new=5, eos_id=tok.EOS):
    """Per-request greedy generate; returns (tokens, logprobs) EOS-truncated."""
    out = generate(m, params, jnp.asarray(req.prompt)[None],
                   jax.random.PRNGKey(1),
                   SamplerConfig(max_new_tokens=max_new, temperature=0.0,
                                 eos_id=eos_id))
    n = int(np.asarray(out["mask"])[0].sum())
    return (np.asarray(out["completions"])[0][:n].tolist(),
            np.asarray(out["behavior_logp"])[0][:n])


# ---------------------------------------------------------------------------
# Equivalence: continuous batching == sequential per-request generate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["internlm2-1.8b",   # dense GQA attention
                                  "rwkv6-7b",          # SSM recurrent cache
                                  "gemma3-4b"])        # sliding-window layers
def test_engine_matches_sequential_generate(arch):
    m, params = get_model(arch)
    reqs = make_requests(3)
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0))
    for r in reqs:
        eng.submit(r)
    outs = eng.run()
    assert [o.rid for o in outs] == [0, 1, 2]
    for r, o in zip(reqs, outs):
        ref_t, ref_l = reference(m, params, r)
        assert o.tokens == ref_t, (arch, o.rid)
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)


def test_engine_fused_block_matches_per_token():
    """block_size > 1 (fused decode scan) changes scheduling granularity,
    never token content."""
    m, params = get_model("internlm2-1.8b")
    reqs = make_requests(4, max_new=6)
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0, block_size=4))
    for r in reqs:
        eng.submit(r)
    outs = eng.run()
    for r, o in zip(reqs, outs):
        ref_t, ref_l = reference(m, params, r, max_new=6)
        assert o.tokens == ref_t
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)


def test_engine_eos_early_exit_and_recycle():
    """Pick eos_id = a token the greedy path actually emits, so one request
    finishes early: its output must match generate with the same eos_id,
    finish with reason 'eos', and free its slot for the queued request."""
    m, params = get_model("internlm2-1.8b")
    reqs = make_requests(3, max_new=6)
    probe_t, _ = reference(m, params, reqs[0], max_new=6)
    eos = probe_t[2]                       # greedy step-3 token of request 0
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0, eos_id=eos))
    for r in reqs:
        eng.submit(r)
    outs = eng.run()
    for r, o in zip(reqs, outs):
        ref_t, ref_l = reference(m, params, r, max_new=6, eos_id=eos)
        assert o.tokens == ref_t
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    assert outs[0].tokens[-1] == eos and outs[0].finish_reason == "eos"
    assert len(outs[0].tokens) == 3        # EOS token itself is recorded
    # slot recycling happened: request 2 waited for a released slot
    events = eng.slots.events
    first_release = min(i for i, e in enumerate(events) if e[0] == "release")
    assign_r2 = next(i for i, e in enumerate(events)
                     if e[0] == "assign" and e[1] == 2)
    assert assign_r2 > first_release


# ---------------------------------------------------------------------------
# Equivalence: paged KV layout == contiguous == sequential generate
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["internlm2-1.8b",   # dense GQA attention
                                  "gemma3-4b"])       # sliding-window layers
def test_paged_engine_matches_contiguous_and_generate(arch):
    """The paged engine's greedy tokens/logprobs are identical to the
    contiguous engine's and to per-request ``generate`` — the block-table
    gather is a permutation-copy, never an approximation."""
    m, params = get_model(arch)
    reqs = make_requests(3)

    def run(cfg):
        eng = Engine(m, params, cfg)
        for r in reqs:
            eng.submit(r)
        return eng, eng.run()

    _, base = run(EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                               temperature=0.0))
    eng, outs = run(EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                 temperature=0.0, kv_layout="paged",
                                 kv_block_size=8))
    for r, o, c in zip(reqs, outs, base):
        ref_t, ref_l = reference(m, params, r)
        assert o.tokens == c.tokens == ref_t, (arch, o.rid)
        np.testing.assert_allclose(o.logprobs, c.logprobs, atol=1e-6)
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    eng.slots.check()                      # no block leaked after drain
    assert eng.slots.blocks_in_use == 0


def test_paged_engine_mixed_lengths_block_boundary_crossing():
    """Mixed prompt lengths + a small KV block size, so decode crosses
    block boundaries mid-flight and tables grow on demand (some request
    materializes more blocks than its prompt needed)."""
    m, params = get_model("internlm2-1.8b")
    texts = ["1+2=", "100+200=", "7+8=", "3000+4000="]    # 2 prompt lengths
    reqs = [Request(rid=i, prompt=np.asarray(tok.encode(p, bos=True),
                                             np.int32), max_new_tokens=9)
            for i, p in enumerate(texts)]
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0, kv_layout="paged",
                                         kv_block_size=4))
    for r in reqs:
        eng.submit(r)
    outs = eng.run()
    for r, o in zip(reqs, outs):
        ref_t, ref_l = reference(m, params, r, max_new=9)
        assert o.tokens == ref_t, o.rid
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    # on-demand growth actually happened: some request ended up with more
    # blocks than its prompt required at admit time
    allocs = {}
    for ev, rid, _ in eng.slots.alloc.events:
        if ev == "alloc":
            allocs[rid] = allocs.get(rid, 0) + 1
    grew = [r for r in reqs
            if allocs[r.rid] > -(-r.prompt_len // 4)]
    assert grew, "no request crossed a block boundary mid-decode"
    eng.slots.check()


def test_paged_engine_fused_block_matches_per_token():
    """Fused K-step decode over the paged pool still scatters each written
    block between steps — token content is unchanged."""
    m, params = get_model("internlm2-1.8b")
    reqs = make_requests(4, max_new=6)
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0, block_size=4,
                                         kv_layout="paged", kv_block_size=8))
    for r in reqs:
        eng.submit(r)
    outs = eng.run()
    for r, o in zip(reqs, outs):
        ref_t, ref_l = reference(m, params, r, max_new=6)
        assert o.tokens == ref_t
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)


# ---------------------------------------------------------------------------
# GRPO smoke: one training step through the engine == static-batch rollout
# ---------------------------------------------------------------------------
def test_grpo_step_via_engine_matches_static_rollout():
    """`launch.train` wired to the serving engine: one greedy GRPO step via
    ``rl.generate_continuous`` (paged KV) produces the same metrics and the
    same post-step parameters as the static-batch ``generate`` path."""
    from repro.launch.train import run_training
    m, _ = get_model("internlm2-1.8b")
    kw = dict(model=m, steps=1, batch=2, group=2, max_new=4,
              temperature=0.0, seed=3, log_every=100)
    s1, h1 = run_training(rollout="static", **kw)
    s2, h2 = run_training(rollout="engine", kv="paged", kv_block_size=4, **kw)
    for key in ("reward", "acc", "loss", "entropy"):
        assert h1[0][key] == pytest.approx(h2[0][key], abs=1e-5), key
    for a, b in zip(jax.tree.leaves(s1["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


# ---------------------------------------------------------------------------
# Slot-manager invariants
# ---------------------------------------------------------------------------
def test_slot_invariants_no_reuse_while_alive():
    m, params = get_model("internlm2-1.8b")
    reqs = [Request(rid=i, prompt=np.asarray(tok.encode("9+9=", bos=True),
                                             np.int32),
                    max_new_tokens=2 + (i % 3)) for i in range(7)]
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0))
    for r in reqs:
        eng.submit(r)
    eng.run()
    owned = {}                            # slot -> rid currently holding it
    assigns = {}
    for ev, rid, slot in eng.slots.events:
        if ev == "assign":
            assert slot not in owned, f"slot {slot} reused while alive"
            owned[slot] = rid
            assigns[rid] = assigns.get(rid, 0) + 1
        else:
            assert owned.pop(slot) == rid
    assert not owned                      # every assign matched by a release
    assert all(n == 1 for n in assigns.values())   # one slot per request
    assert len(assigns) == len(reqs)
    assert eng.slots.num_free == 2


def test_slot_manager_rejects_bad_transitions():
    m, _ = get_model("internlm2-1.8b")
    from repro.serve import SlotManager
    sm = SlotManager(m, 2, MAX_LEN)
    s = sm.assign(0)
    with pytest.raises(AssertionError):
        sm.owner[s] = None                # simulate corruption
        sm.release(s)
    sm2 = SlotManager(m, 1, MAX_LEN)
    sm2.assign(1)
    with pytest.raises(RuntimeError):
        sm2.assign(2)                     # no free slot


# ---------------------------------------------------------------------------
# Queue FIFO fairness under staggered arrivals
# ---------------------------------------------------------------------------
def test_queue_fifo_under_staggered_arrivals():
    """Requests arriving mid-flight are admitted strictly in arrival order,
    even when they could fit an earlier-freed slot out of order."""
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0))
    prompt = np.asarray(tok.encode("5+5=", bos=True), np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=6))
    eng.step()                            # both admitted, decoding
    # staggered late arrivals, shortest last
    eng.submit(Request(rid=2, prompt=prompt, max_new_tokens=6))
    eng.submit(Request(rid=3, prompt=prompt, max_new_tokens=1))
    eng.run()
    admit_order = [rid for ev, rid, _ in eng.slots.events if ev == "assign"]
    assert admit_order == [0, 1, 2, 3]
    assert sorted(eng.finished) == [0, 1, 2, 3]


def test_submit_rejects_oversized_request():
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(num_slots=1, max_seq_len=16))
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(10, np.int32),
                           max_new_tokens=8))


# ---------------------------------------------------------------------------
# generate_continuous: GRPO-compatible rollout output
# ---------------------------------------------------------------------------
def test_generate_continuous_matches_generate_contract():
    m, params = get_model("internlm2-1.8b")
    B, T = 3, 6
    prompts = jnp.asarray(tok.pad_batch(
        [tok.encode(p, bos=True) for p in PROMPTS[:B]], 8))
    rng = jax.random.PRNGKey(1)
    sampler = SamplerConfig(max_new_tokens=T, temperature=0.0)
    out = generate_continuous(m, params, prompts, rng, sampler, num_slots=2)
    assert out["completions"].shape == (B, T)
    assert out["behavior_logp"].shape == (B, T)
    assert out["mask"].shape == (B, T)
    assert out["tokens"].shape == (B, prompts.shape[1] + T)
    assert np.all(np.asarray(out["behavior_logp"]) <= 0.0)
    # greedy rows match per-request generate on the same padded rows
    for i in range(B):
        ref = generate(m, params, prompts[i:i + 1], rng, sampler)
        n = int(np.asarray(ref["mask"])[0].sum())
        got = np.asarray(out["completions"])[i]
        assert got[:n].tolist() == np.asarray(ref["completions"])[0][:n].tolist()
        assert np.asarray(out["mask"])[i, :n].all()
        assert not np.asarray(out["mask"])[i, n:].any()
