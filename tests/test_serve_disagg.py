"""Disaggregated prefill/decode serving (``repro.serve.disagg`` +
``repro.serve.router``): bit-exactness against the monolithic engine
across every policy × layout × sharing combination, KV-handle refcount
conservation under random interleavings and mid-flight drops, reset-cycle
leak invariants (for the router *and* the monolithic prefix-share engine),
planner visibility of the transfer phase, and the ``disagg=`` wiring in
``rl.generate_continuous``.

The router's core guarantee mirrors the scheduler one: disaggregation
changes *where* a prompt's KV lives and *when* its decode starts, never
*what* it decodes — greedy tokens and behaviour logprobs are bit-identical
to the monolithic engine for the same requests.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from test_serve_engine import MAX_LEN, get_model, make_requests, reference

from repro.rl import SamplerConfig, generate_continuous
from repro.serve import (DisaggConfig, DisaggRouter, Engine, EngineConfig,
                         KVTransferHandle, Request)


def _mono_outputs(m, params, reqs, *, kv, sched="fifo", prefix_share=False):
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0, kv_layout=kv,
        kv_block_size=4, sched=sched, prefix_share=prefix_share))
    for r in reqs:
        eng.submit(r)
    return {o.rid: o for o in eng.run()}


def _disagg_outputs(m, params, reqs, *, kv, sched="fifo",
                    prefix_share=False, prefill_slots=1, decode_slots=2,
                    **cfg_kw):
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=prefill_slots, decode_slots=decode_slots,
        max_seq_len=MAX_LEN, temperature=0.0, kv_layout=kv,
        kv_block_size=4, sched=sched, prefix_share=prefix_share, **cfg_kw))
    for r in reqs:
        router.submit(r)
    return {o.rid: o for o in router.run()}, router


def _assert_same(mono, dis):
    assert sorted(mono) == sorted(dis)
    for rid in mono:
        assert dis[rid].tokens == mono[rid].tokens, rid
        np.testing.assert_array_equal(dis[rid].logprobs,
                                      mono[rid].logprobs)


# ---------------------------------------------------------------------------
# Bit-exactness: every policy × layout × sharing combination
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("sched", ["fifo", "deadline", "slo"])
@pytest.mark.parametrize("kv,prefix_share", [
    ("contiguous", False), ("paged", False), ("paged", True)])
def test_disagg_matches_monolithic(kv, prefix_share, sched):
    m, params = get_model("internlm2-1.8b")
    reqs = make_requests(4, max_new=6)
    if sched != "fifo":
        for i, r in enumerate(reqs):
            r.deadline = 10.0 - i          # reverse-EDF: forces reordering
    if prefix_share:
        for r in reqs[2:]:                 # two exact-duplicate prompts
            r.prompt = np.array(reqs[0].prompt)
            r.prefix_key = "g0"
        reqs[0].prefix_key = "g0"
    mono = _mono_outputs(m, params, reqs, kv=kv, sched=sched,
                         prefix_share=prefix_share)
    dis, router = _disagg_outputs(m, params, reqs, kv=kv, sched=sched,
                                  prefix_share=prefix_share)
    _assert_same(mono, dis)
    assert router.stats.transfers == len(reqs)
    if prefix_share:
        assert router.stats.prefix_hits >= 1   # later members: zero compute
    # reference cross-check: disagg == per-request generate, not just == mono
    ref_t, ref_l = reference(m, params, reqs[0], max_new=6)
    assert dis[0].tokens == ref_t
    np.testing.assert_allclose(dis[0].logprobs, ref_l, atol=1e-5)


@pytest.mark.parametrize("arch", ["internlm2-1.8b",   # dense GQA attention
                                  "rwkv6-7b",          # no paged leaves
                                  "gemma3-4b"])        # sliding-window mix
def test_disagg_matches_monolithic_across_caches(arch):
    """The handle protocol must survive every cache family: attention
    (paged K/V leaves), rwkv6 (state rides entirely in the slot-leaf
    snapshot) and gemma3 (paged + sliding-window layers)."""
    m, params = get_model(arch)
    reqs = make_requests(3, max_new=5)
    mono = _mono_outputs(m, params, reqs, kv="paged")
    dis, _ = _disagg_outputs(m, params, reqs, kv="paged")
    _assert_same(mono, dis)


def test_disagg_pool_sizing_independent():
    """Prefill and decode pools size independently: a 1-slot prefill side
    with a tiny block pool still serves (handles pin, slot recycles), and
    the decode pool bounds concurrency exactly like a monolithic engine."""
    m, params = get_model("internlm2-1.8b")
    reqs = make_requests(4, max_new=5)
    mono = _mono_outputs(m, params, reqs, kv="paged")
    dis, router = _disagg_outputs(
        m, params, reqs, kv="paged", prefill_slots=1, decode_slots=2,
        prefill_kv_blocks=6, decode_kv_blocks=40)
    _assert_same(mono, dis)
    assert router.prefill.slots.alloc.num_blocks == 6
    assert router.decode.slots.alloc.num_blocks == 40
    router.reset()                          # both pools leak-free


def test_disagg_rejects_oversized_for_either_pool():
    m, params = get_model("internlm2-1.8b")
    _, router = _disagg_outputs(m, params, [], kv="paged",
                                decode_kv_blocks=4)
    with pytest.raises(ValueError):         # decode pool can never fit it
        router.submit(Request(rid=0, prompt=np.zeros(8, np.int32),
                              max_new_tokens=30))
    with pytest.raises(ValueError):         # over max_seq_len entirely
        router.submit(Request(rid=1, prompt=np.zeros(MAX_LEN, np.int32),
                              max_new_tokens=4))


# ---------------------------------------------------------------------------
# generate_continuous wiring (the rl.rollout + launch surface)
# ---------------------------------------------------------------------------
def test_generate_continuous_disagg_flag_bit_exact():
    m, params = get_model("internlm2-1.8b")
    prompts = jnp.asarray(np.array([[1, 5, 7, 9], [1, 8, 3, 3],
                                    [1, 2, 2, 5], [1, 7, 7, 7]], np.int32))
    sampler = SamplerConfig(max_new_tokens=6, temperature=0.0)
    key = jax.random.PRNGKey(0)
    mono = generate_continuous(m, params, prompts, key, sampler,
                               num_slots=2, kv_layout="paged",
                               kv_block_size=4)
    # decode pool sized like the monolithic slot pool -> bit-exact (the
    # decode computation is the same jitted code over the same batch shape)
    dis = generate_continuous(m, params, prompts, key, sampler,
                              num_slots=2, kv_layout="paged",
                              kv_block_size=4,
                              disagg={"prefill_slots": 1,
                                      "decode_slots": 2})
    np.testing.assert_array_equal(mono["completions"], dis["completions"])
    np.testing.assert_array_equal(mono["behavior_logp"],
                                  dis["behavior_logp"])
    assert dis["engine_stats"].transfers == prompts.shape[0]
    # disagg=True picks a 1:3-ish split -> different decode batch shape,
    # so logprobs agree to kernel-fusion tolerance, tokens exactly
    auto = generate_continuous(m, params, prompts, key, sampler,
                               num_slots=2, kv_layout="paged",
                               kv_block_size=4, disagg=True)
    np.testing.assert_array_equal(mono["completions"], auto["completions"])
    np.testing.assert_allclose(mono["behavior_logp"],
                               auto["behavior_logp"], atol=1e-5)


# ---------------------------------------------------------------------------
# Handle refcount conservation: random interleavings + mid-flight drops
# ---------------------------------------------------------------------------
def _conservation(alloc):
    assert alloc.num_free + alloc.num_live == alloc.num_blocks
    for bid, rc in alloc.refcount.items():
        assert rc > 0, f"dangling refcount on block {bid}"


@pytest.mark.slow
@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(0, 3), min_size=4, max_size=24),
       st.integers(0, 2 ** 16 - 1))
def test_handle_refcounts_under_random_interleaving(ops, seed):
    """Random interleaving of {submit, prefill tick, adopt, drop, decode
    tick}: block conservation (free + live == num_blocks, no dangling
    refcounts) holds at *every* step, and after the final drain + reset
    both pools are exactly clean."""
    m, params = get_model("internlm2-1.8b")
    rng = np.random.RandomState(seed)
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=2, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0, kv_layout="paged", kv_block_size=4,
        prefix_share=True))
    next_rid = 0
    for op in ops:
        if op == 0 and next_rid < 6:                       # submit
            plen = int(rng.randint(3, 9))
            router.submit(Request(
                rid=next_rid, prompt=rng.randint(1, 50, plen).astype(
                    np.int32), max_new_tokens=int(rng.randint(1, 5)),
                prefix_key=f"g{next_rid % 2}"))
            next_rid += 1
        elif op == 1:                                      # prefill only
            router.prefill.step()
            router.pending_transfer.extend(router.prefill.pop_ready())
        elif op == 2 and router.pending_transfer:          # drop mid-flight
            router.pending_transfer.popleft().release()
        else:                                              # full tick
            if not router.idle:
                router.step()
        _conservation(router.prefill.slots.alloc)
        _conservation(router.decode.slots.alloc)
    while router.pending_transfer or not router.decode.idle \
            or router.prefill.queue:
        if not router.idle:
            router.step()
        else:
            break
    router.reset()
    router.prefill.slots.alloc.assert_clean()
    router.decode.slots.alloc.assert_clean()


def test_handle_release_is_idempotent():
    m, params = get_model("internlm2-1.8b")
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=1, max_seq_len=MAX_LEN,
        temperature=0.0, kv_layout="paged", kv_block_size=4))
    router.submit(make_requests(1)[0])
    router.prefill.step()
    (h,) = router.prefill.pop_ready()
    assert isinstance(h, KVTransferHandle) and h.block_ids
    h.release()
    h.release()                             # second release must be a no-op
    router.prefill.slots.alloc.assert_clean()
    with pytest.raises(RuntimeError):       # adopted-after-release is loud
        router.prefill.export_cache(h)


def test_dropped_handle_restores_conservation_and_reset_is_clean():
    """The ISSUE's mid-flight-drop invariant: prefill N, adopt some, drop
    the rest — the prefill pool must return to exactly-clean on reset."""
    m, params = get_model("internlm2-1.8b")
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=2, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0, kv_layout="paged", kv_block_size=4))
    for r in make_requests(4, max_new=4):
        router.submit(r)
    router.prefill.step()                   # 2 handles pinned, un-adopted
    router.pending_transfer.extend(router.prefill.pop_ready())
    assert router.prefill.slots.alloc.num_live > 0
    dropped = router.drop_pending()
    assert dropped == 2
    router.run()                            # remaining two serve normally
    router.reset()
    router.prefill.slots.alloc.assert_clean()
    router.decode.slots.alloc.assert_clean()


def test_prefill_reset_refuses_live_handles():
    m, params = get_model("internlm2-1.8b")
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=1, max_seq_len=MAX_LEN,
        temperature=0.0, kv_layout="paged", kv_block_size=4))
    router.submit(make_requests(1)[0])
    router.prefill.step()
    (h,) = router.prefill.pop_ready()
    with pytest.raises(RuntimeError):
        router.prefill.reset()
    h.release()
    router.prefill.reset()                  # now clean


# ---------------------------------------------------------------------------
# Reset-cycle leak invariants (satellite: monolithic prefix-share too)
# ---------------------------------------------------------------------------
def test_monolithic_prefix_share_reset_cycles_leak_free():
    """``Engine.reset`` with ``prefix_share`` must fully release the radix
    pins: across repeated run/reset cycles the block pool returns to
    exactly ``free + live == num_blocks`` with zero dangling refcounts."""
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4, prefix_share=True))
    base = make_requests(2, max_new=4)
    for cycle in range(3):
        for i, proto in enumerate(base * 2):   # duplicates -> radix hits
            eng.submit(Request(rid=i, prompt=np.array(proto.prompt),
                               max_new_tokens=4,
                               prefix_key=f"c{cycle}-g{i % 2}"))
        eng.run()
        eng.reset()                         # asserts pool cleanliness itself
        alloc = eng.slots.alloc
        assert alloc.num_free == alloc.num_blocks
        assert not alloc.refcount and not alloc.quota
        assert len(eng.radix) == 0


def test_router_reset_cycles_leak_free_with_prefix_share():
    m, params = get_model("internlm2-1.8b")
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0, kv_layout="paged", kv_block_size=4,
        prefix_share=True))
    base = make_requests(2, max_new=4)
    for cycle in range(3):
        for i, proto in enumerate(base * 2):
            router.submit(Request(rid=i, prompt=np.array(proto.prompt),
                                  max_new_tokens=4,
                                  prefix_key=f"c{cycle}-g{i % 2}"))
        outs = router.run()
        assert len(outs) == 4
        router.reset()
        router.prefill.slots.alloc.assert_clean()
        router.decode.slots.alloc.assert_clean()


# ---------------------------------------------------------------------------
# Planner visibility: transfers are a phase on the co-execution timeline
# ---------------------------------------------------------------------------
def test_transfer_phase_lands_on_runtime_timeline():
    from repro.core.phase_control import RollMuxRuntime

    m, params = get_model("internlm2-1.8b")
    rt = RollMuxRuntime(host_cache_gb=0.5)
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0, kv_layout="paged", kv_block_size=4),
        runtime=rt, job_id="jobA")
    reqs = make_requests(3, max_new=4)
    for r in reqs:
        router.submit(r)
    router.run()
    pool = rt.pools["transfer"]
    assert len(pool.timeline) == len(reqs)
    assert all(who == "jobA:transfer" for who, _, _ in pool.timeline)
    prof = rt.phase_profiles()["jobA"]
    assert len(prof.transfer_s) == len(reqs)
    assert prof.t_transfer > 0.0
    # the transfer load is folded into the job's rollout-side critical path
    assert prof.to_job().t_roll == pytest.approx(
        prof.t_roll + prof.t_transfer)
