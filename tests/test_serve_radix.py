"""Radix prompt-prefix KV sharing (``repro.serve.radix``): engine-level
greedy equivalence (shared == unshared == per-request ``generate``, bit
for bit), the equal-memory concurrency win on GRPO-group traffic, and the
allocator/slot-manager invariants under random shared admit/grow/release
interleavings (refcounts conserved, no double free, null block untouched,
index pins accounted).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from test_serve_engine import MAX_LEN, get_model, reference

from repro.data import tokenizer as tok
from repro.serve import (Engine, EngineConfig, PagedSlotManager, Request,
                         blocks_for)


def group_requests(texts, group, *, max_new=6, job="j"):
    """GRPO-shaped trace: each prompt duplicated ``group`` times, members
    tagged with one shared prefix key."""
    reqs = []
    rid = 0
    for gi, text in enumerate(texts):
        prompt = np.asarray(tok.encode(text, bos=True), np.int32)
        for _ in range(group):
            reqs.append(Request(rid=rid, prompt=prompt.copy(),
                                max_new_tokens=max_new,
                                prefix_key=(job, gi)))
            rid += 1
    return reqs


def run_engine(m, params, reqs, **cfg):
    eng = Engine(m, params, EngineConfig(max_seq_len=MAX_LEN,
                                         temperature=0.0, **cfg))
    for r in reqs:
        eng.submit(Request(rid=r.rid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens,
                           prefix_key=r.prefix_key))
    return eng, eng.run()


# ---------------------------------------------------------------------------
# Exact-hit sharing: bit-identical output, prefill once per group
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["internlm2-1.8b",   # dense GQA attention
                                  "gemma3-4b"])       # sliding-window layers
def test_shared_engine_bit_identical_to_unshared(arch):
    """Two interleaved GRPO groups (different prompt lengths, small blocks
    so prompts span several full blocks + a partial tail): the sharing
    engine's greedy tokens/logprobs equal the unshared paged engine's and
    per-request ``generate``'s, while prefilling each prompt once."""
    m, params = get_model(arch)
    reqs = group_requests(["123+456=", "7+8="], group=3)
    kw = dict(num_slots=3, kv_layout="paged", kv_block_size=4)
    _, base = run_engine(m, params, reqs, **kw)
    eng, outs = run_engine(m, params, reqs, prefix_share=True, **kw)
    for r, o, c in zip(reqs, outs, base):
        ref_t, ref_l = reference(m, params, r, max_new=6)
        assert o.tokens == c.tokens == ref_t, (arch, o.rid)
        np.testing.assert_allclose(o.logprobs, c.logprobs, atol=0)
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    assert eng.stats.prefix_hits == 4        # 2 groups x (3 members - donor)
    assert eng.radix.misses == 2             # one prefill per group
    assert eng.stats.blocks_saved > 0
    # every live structure drained; index pins are the only refs left
    eng.slots.check(extra_pins=eng.radix.pinned_blocks())
    eng.radix.flush()
    eng.slots.check()
    assert eng.slots.blocks_in_use == 0


def test_shared_blocks_pinned_under_multiple_owners():
    """While a group is in flight, its prompt's full blocks carry one ref
    per live member (+ the index pin) — several slot owners per block."""
    m, params = get_model("internlm2-1.8b")
    reqs = group_requests(["1234+5678="], group=3, max_new=8)
    eng = Engine(m, params, EngineConfig(
        num_slots=3, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4, prefix_share=True))
    for r in reqs:
        eng.submit(r)
    eng.step()                               # all three admitted, 1 decode
    entry = next(iter(eng.radix.entries.values()))
    assert len(entry.block_ids) >= 1
    for bid in entry.block_ids:
        # donor's own ref + 2 sharers + the index pin
        assert eng.slots.alloc.refcount[bid] == 4
    eng.slots.check(extra_pins=eng.radix.pinned_blocks())
    eng.run()
    # members gone: only the index pin remains
    for bid in entry.block_ids:
        assert eng.slots.alloc.refcount[bid] == 1


def test_shared_admits_more_groups_at_equal_memory():
    """The acceptance criterion in miniature: at the same KV pool size,
    prefix sharing admits strictly more concurrent GRPO-group members
    than the unshared paged engine (prompt blocks are pinned, not
    duplicated, so admission's net-new demand shrinks)."""
    m, params = get_model("internlm2-1.8b")
    reqs = group_requests(["123+456="], group=6, max_new=8)
    total = reqs[0].total_budget
    # pool sized for ~3 unshared members' worst case
    blocks = 3 * blocks_for(total, 4)
    kw = dict(num_slots=6, kv_layout="paged", kv_block_size=4,
              num_kv_blocks=blocks)
    unshared, _ = run_engine(m, params, reqs, **kw)
    shared, outs = run_engine(m, params, reqs, prefix_share=True, **kw)
    assert shared.stats.peak_active > unshared.stats.peak_active
    for r, o in zip(reqs, outs):
        ref_t, _ = reference(m, params, r, max_new=8)
        assert o.tokens == ref_t


def test_rwkv6_degenerate_sharing_is_prefill_cache():
    """No ``cache_seq`` leaves: nothing to page, but an exact hit still
    skips prefill via the slot-state snapshot — outputs unchanged."""
    m, params = get_model("rwkv6-7b")
    reqs = group_requests(["12+34="], group=3)
    kw = dict(num_slots=2, kv_layout="paged", kv_block_size=8)
    _, base = run_engine(m, params, reqs, **kw)
    eng, outs = run_engine(m, params, reqs, prefix_share=True, **kw)
    assert [o.tokens for o in outs] == [o.tokens for o in base]
    assert eng.stats.prefix_hits == 2 and eng.stats.blocks_saved == 0


def test_prefix_hit_extension_shares_blocks():
    """A prompt that *extends* a registered prefix (same key, longer
    prompt) can't skip prefill but pins the matching full blocks and
    still decodes exactly (write-masked scatter never touches them)."""
    m, params = get_model("internlm2-1.8b")
    base_text, ext_text = "1234+5678=", "1234+5678=9"
    prompt0 = np.asarray(tok.encode(base_text, bos=True), np.int32)
    prompt1 = np.asarray(tok.encode(ext_text, bos=True), np.int32)
    assert np.array_equal(prompt1[:len(prompt0)], prompt0)
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4, prefix_share=True))
    r0 = Request(rid=0, prompt=prompt0, max_new_tokens=5, prefix_key="p")
    r1 = Request(rid=1, prompt=prompt1, max_new_tokens=5, prefix_key="p")
    eng.submit(r0)
    eng.submit(r1)
    outs = eng.run()
    assert eng.stats.prefix_partial_hits == 1
    assert outs[1].prefix_shared_blocks > 0
    for r, o in zip((r0, r1), outs):
        ref_t, ref_l = reference(m, params, r, max_new=5)
        assert o.tokens == ref_t, o.rid
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    eng.slots.check(extra_pins=eng.radix.pinned_blocks())


def test_frontend_requests_never_share():
    """Prompt tokens alone don't identify frontend-conditioned KV (prefill
    conditions on the embeddings), so requests carrying a frontend must
    miss the radix index even with matching keys and tokens."""
    import jax.numpy as jnp
    m, _ = get_model("internlm2-1.8b")
    from repro.models import build_model
    vm = build_model("qwen2-vl-7b", reduced=True)
    import jax
    vparams = vm.init(jax.random.PRNGKey(1))
    fr0 = jnp.zeros((1, vm.cfg.num_frontend_tokens, vm.cfg.d_model))
    fr1 = jnp.ones((1, vm.cfg.num_frontend_tokens, vm.cfg.d_model))
    # frontend embeddings overlay the first num_frontend_tokens prompt
    # positions, so the padded prompt must be at least that long
    prompt = np.asarray(tok.pad_batch(
        [tok.encode("1+2=", bos=True)],
        vm.cfg.num_frontend_tokens + 8)[0], np.int32)
    eng = Engine(vm, vparams, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4, prefix_share=True))
    for rid, fr in enumerate((fr0, fr1)):
        eng.submit(Request(rid=rid, prompt=prompt.copy(), max_new_tokens=4,
                           prefix_key="k", frontend=fr))
    outs = eng.run()
    assert eng.stats.prefix_hits == 0 and not eng.radix.entries
    # same tokens, different frontends -> genuinely different generations
    from repro.rl import SamplerConfig, generate
    for rid, fr in enumerate((fr0, fr1)):
        ref = generate(vm, vparams, jnp.asarray(prompt)[None],
                       jax.random.PRNGKey(0),
                       SamplerConfig(max_new_tokens=4, temperature=0.0),
                       frontend=fr)
        n = int(np.asarray(ref["mask"])[0].sum())
        assert outs[rid].tokens[:n] == \
            np.asarray(ref["completions"])[0][:n].tolist(), rid


def test_eviction_under_block_pressure_and_reset_flush():
    """Index pins are evicted LRU when admission needs the blocks; reset
    flushes everything (new params invalidate cached prefills)."""
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4,
        num_kv_blocks=blocks_for(MAX_LEN, 4),  # one stripe's worth
        prefix_share=True))
    eng.submit(Request(rid=0, prompt=np.asarray(
        tok.encode("11+22=", bos=True), np.int32), max_new_tokens=4,
        prefix_key="a"))
    eng.run()
    assert len(eng.radix) == 1
    # a big unrelated request needs (almost) the whole pool: entry evicted
    eng.submit(Request(rid=1, prompt=np.asarray(
        tok.encode("3+4=", bos=True), np.int32), max_new_tokens=40,
        prefix_key="b"))
    eng.run()
    assert eng.radix.evictions >= 1
    assert "a" not in eng.radix.entries
    eng.reset(params)
    assert len(eng.radix) == 0
    eng.slots.check()
    assert eng.slots.blocks_in_use == 0


def test_export_import_roundtrip_with_sharing_mid_flight():
    """Checkpoint a sharing engine with live shared slots; a fresh engine
    resumes token-for-token and keeps the invariants."""
    m, params = get_model("internlm2-1.8b")
    reqs = group_requests(["123+456="], group=3, max_new=8)
    cfg = EngineConfig(num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
                       kv_layout="paged", kv_block_size=4,
                       prefix_share=True)
    eng = Engine(m, params, cfg)
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.step()                              # live shared slots + 1 waiting
    state = eng.export_state()
    done_a = Engine(m, params, cfg)
    done_a.import_state(state)
    outs_a = done_a.run()
    outs_b = eng.run()                      # original continues too
    assert [o.tokens for o in outs_a] == [o.tokens for o in outs_b]
    for r, o in zip(reqs, outs_a):
        ref_t, _ = reference(m, params, r, max_new=8)
        assert o.tokens == ref_t
    done_a.slots.check(extra_pins=done_a.radix.pinned_blocks())


# ---------------------------------------------------------------------------
# Property: shared interleavings preserve allocator/slot invariants
# ---------------------------------------------------------------------------
def _drive_shared_slot_manager(ops, sm: PagedSlotManager, index_pins):
    """Random admit/admit-shared/grow/finish/evict interleavings.

    ``index_pins`` plays the radix index: it pins (increfs) the full
    blocks of whichever live donor the op stream picks, and releases
    (decrefs) pins at random — exactly the lifecycle the engine drives.
    Invariants are checked after every op.
    """
    live, rid = [], 0
    for kind, val in ops:
        if kind == 0:                      # plain admit
            plen = 1 + val % 10
            budget = plen + 1 + val % 12
            if sm.can_admit(budget):
                slot = sm.assign(rid, prompt_len=plen, total_budget=budget)
                live.append((slot, plen, budget))
                rid += 1
        elif kind == 1 and live:           # shared admit from a live donor
            dslot, dplen, _ = live[val % len(live)]
            n_full = min(dplen // sm.block_size, sm.nblocks[dslot])
            shared = [int(b) for b in sm.tables[dslot, :n_full]]
            plen = max(dplen, 1 + val % 10)
            budget = plen + 1 + val % 12
            if sm.can_admit(budget, shared_blocks=len(shared)):
                slot = sm.assign_shared(rid, prompt_len=plen,
                                        total_budget=budget,
                                        shared_ids=shared)
                live.append((slot, plen, budget))
                rid += 1
        elif kind == 2 and live:           # decode progress -> table growth
            slot, plen, budget = live[val % len(live)]
            sm.ensure(slot, min(plen + val % 8, budget - 1))
        elif kind == 3 and live:           # pin a donor's blocks (register)
            dslot, dplen, _ = live[val % len(live)]
            n_full = min(dplen // sm.block_size, sm.nblocks[dslot])
            for b in sm.tables[dslot, :n_full]:
                sm.alloc.incref(int(b))
                index_pins.append(int(b))
        elif kind == 4 and index_pins:     # evict one pin
            sm.alloc.decref(index_pins.pop(val % len(index_pins)))
        elif kind == 5 and live:           # finish
            slot, _, _ = live.pop(val % len(live))
            sm.release(slot)
        sm.check(extra_pins=index_pins)
    for slot, _, _ in live:
        sm.release(slot)
    while index_pins:
        sm.alloc.decref(index_pins.pop())
    sm.check()
    assert sm.blocks_in_use == 0 and sm.num_free == sm.num_slots


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 63)),
                min_size=1, max_size=30))
def test_shared_slot_manager_interleaving(ops):
    m, _ = get_model("internlm2-1.8b")
    _drive_shared_slot_manager(
        ops, PagedSlotManager(m, 4, MAX_LEN, block_size=4, num_blocks=24),
        [])


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 1023)),
                min_size=1, max_size=100),
       st.integers(2, 6),                  # block size
       st.integers(8, 32))                 # pool blocks
def test_shared_slot_manager_interleaving_sweep(ops, bs, nb):
    m, _ = get_model("internlm2-1.8b")
    _drive_shared_slot_manager(
        ops, PagedSlotManager(m, 5, MAX_LEN, block_size=bs, num_blocks=nb),
        [])
