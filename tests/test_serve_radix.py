"""Content-addressed radix-tree KV sharing (``repro.serve.radix``):
engine-level greedy equivalence (shared == unshared == per-request
``generate``, bit for bit), cross-request/untagged/multi-turn sharing by
token content, namespace isolation, strict-LRU node eviction, tree
checkpoint round-trips, KV-aware routing across prefill engines, and the
allocator/slot-manager invariants under random shared
admit/grow/release interleavings (refcounts conserved, no double free,
null block untouched, tree pins accounted).
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from test_serve_engine import MAX_LEN, get_model, reference

from repro.data import tokenizer as tok
from repro.serve import (Engine, EngineConfig, PagedSlotManager, Request,
                         blocks_for)
from repro.serve.blocks import BlockAllocator
from repro.serve.radix import RadixPrefixIndex


def group_requests(texts, group, *, max_new=6, job="j"):
    """GRPO-shaped trace: each prompt duplicated ``group`` times, members
    tagged with one shared namespace key (isolation between groups — the
    sharing itself is by content)."""
    reqs = []
    rid = 0
    for gi, text in enumerate(texts):
        prompt = np.asarray(tok.encode(text, bos=True), np.int32)
        for _ in range(group):
            reqs.append(Request(rid=rid, prompt=prompt.copy(),
                                max_new_tokens=max_new,
                                prefix_key=(job, gi)))
            rid += 1
    return reqs


def run_engine(m, params, reqs, **cfg):
    eng = Engine(m, params, EngineConfig(max_seq_len=MAX_LEN,
                                         temperature=0.0, **cfg))
    for r in reqs:
        eng.submit(Request(rid=r.rid, prompt=r.prompt,
                           max_new_tokens=r.max_new_tokens,
                           prefix_key=r.prefix_key))
    return eng, eng.run()


# ---------------------------------------------------------------------------
# Exact-hit sharing: bit-identical output, prefill once per group
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["internlm2-1.8b",   # dense GQA attention
                                  "gemma3-4b"])       # sliding-window layers
def test_shared_engine_bit_identical_to_unshared(arch):
    """Two interleaved GRPO groups (different prompt lengths, small blocks
    so prompts span several full blocks + a partial tail): the sharing
    engine's greedy tokens/logprobs equal the unshared paged engine's and
    per-request ``generate``'s, while prefilling each prompt once."""
    m, params = get_model(arch)
    reqs = group_requests(["123+456=", "7+8="], group=3)
    kw = dict(num_slots=3, kv_layout="paged", kv_block_size=4)
    _, base = run_engine(m, params, reqs, **kw)
    eng, outs = run_engine(m, params, reqs, prefix_share=True, **kw)
    for r, o, c in zip(reqs, outs, base):
        ref_t, ref_l = reference(m, params, r, max_new=6)
        assert o.tokens == c.tokens == ref_t, (arch, o.rid)
        np.testing.assert_allclose(o.logprobs, c.logprobs, atol=0)
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    assert eng.stats.prefix_hits == 4        # 2 groups x (3 members - donor)
    assert eng.radix.misses == 2             # one prefill per group
    assert eng.stats.blocks_saved > 0
    # every live structure drained; tree pins are the only refs left
    eng.slots.check(extra_pins=eng.radix.pinned_blocks())
    eng.radix.flush()
    eng.slots.check()
    assert eng.slots.blocks_in_use == 0


def test_untagged_cross_request_sharing_by_content():
    """No keys anywhere: an exact prompt repeat admits with zero compute
    and an extension pins the common full blocks — content alone drives
    sharing, and probes (``count=False``) never skew the counters."""
    m, params = get_model("internlm2-1.8b")
    prompt = np.asarray(tok.encode("1234+5678=", bos=True), np.int32)
    ext = np.concatenate([prompt, np.asarray([9, 9, 9], np.int32)])
    reqs = [Request(rid=0, prompt=prompt.copy(), max_new_tokens=5),
            Request(rid=1, prompt=prompt.copy(), max_new_tokens=5),
            Request(rid=2, prompt=ext, max_new_tokens=5)]
    kw = dict(num_slots=3, kv_layout="paged", kv_block_size=4)
    _, base = run_engine(m, params, reqs, **kw)
    eng, outs = run_engine(m, params, reqs, prefix_share=True, **kw)
    for r, o, c in zip(reqs, outs, base):
        ref_t, ref_l = reference(m, params, r, max_new=5)
        assert o.tokens == c.tokens == ref_t, o.rid
        np.testing.assert_allclose(o.logprobs, c.logprobs, atol=0)
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    assert eng.radix.misses == 1             # only the first prompt prefills
    assert eng.radix.hits == 1               # the exact repeat
    assert eng.radix.partial_hits == 1       # the extension
    assert eng.stats.blocks_saved >= 2 * (len(prompt) // 4)
    # a capacity-probe style lookup must not move the admission counters
    before = dict(eng.radix.stats)
    assert eng.radix.match(reqs[0]) is not None
    assert dict(eng.radix.stats) == before


def test_namespace_isolation():
    """Identical prompts under distinct ``prefix_key`` namespaces never
    share — each namespace grows its own root and pays its own prefill."""
    m, params = get_model("internlm2-1.8b")
    prompt = np.asarray(tok.encode("123+456=", bos=True), np.int32)
    reqs = [Request(rid=i, prompt=prompt.copy(), max_new_tokens=4,
                    prefix_key=key)
            for i, key in enumerate(("tenant-a", "tenant-b", None))]
    eng, outs = run_engine(m, params, reqs, num_slots=3, kv_layout="paged",
                           kv_block_size=4, prefix_share=True)
    assert eng.radix.hits == 0 and eng.radix.partial_hits == 0
    assert eng.radix.misses == 3
    assert eng.stats.blocks_saved == 0
    # one node path per namespace, same content thrice
    assert len(eng.radix.roots) == 3
    assert len(eng.radix) == 3 * (len(prompt) // 4)
    ref_t, _ = reference(m, params, reqs[0], max_new=4)
    for o in outs:
        assert o.tokens == ref_t
    eng.slots.check(extra_pins=eng.radix.pinned_blocks())


def test_shared_blocks_pinned_under_multiple_owners():
    """While a group is in flight, its prompt's full blocks carry one ref
    per live member (+ the tree pin) — several slot owners per block."""
    m, params = get_model("internlm2-1.8b")
    reqs = group_requests(["1234+5678="], group=3, max_new=8)
    eng = Engine(m, params, EngineConfig(
        num_slots=3, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4, prefix_share=True))
    for r in reqs:
        eng.submit(r)
    eng.step()                               # all three admitted, 1 decode
    probe = eng.radix.match(reqs[0])
    assert probe is not None and probe.exact
    assert len(probe.block_ids) >= 1
    for bid in probe.block_ids:
        # donor's own ref + 2 sharers + the tree pin
        assert eng.slots.alloc.refcount[bid] == 4
    eng.slots.check(extra_pins=eng.radix.pinned_blocks())
    eng.run()
    # members gone: only the tree pin remains
    for bid in probe.block_ids:
        assert eng.slots.alloc.refcount[bid] == 1


def test_shared_admits_more_groups_at_equal_memory():
    """The acceptance criterion in miniature: at the same KV pool size,
    prefix sharing admits strictly more concurrent GRPO-group members
    than the unshared paged engine (prompt blocks are pinned, not
    duplicated, so admission's net-new demand shrinks)."""
    m, params = get_model("internlm2-1.8b")
    reqs = group_requests(["123+456="], group=6, max_new=8)
    total = reqs[0].total_budget
    # pool sized for ~3 unshared members' worst case
    blocks = 3 * blocks_for(total, 4)
    kw = dict(num_slots=6, kv_layout="paged", kv_block_size=4,
              num_kv_blocks=blocks)
    unshared, _ = run_engine(m, params, reqs, **kw)
    shared, outs = run_engine(m, params, reqs, prefix_share=True, **kw)
    assert shared.stats.peak_active > unshared.stats.peak_active
    for r, o in zip(reqs, outs):
        ref_t, _ = reference(m, params, r, max_new=8)
        assert o.tokens == ref_t


def test_rwkv6_degenerate_sharing_is_prefill_cache():
    """No ``cache_seq`` leaves: nothing to page, but an exact hit still
    skips prefill via the root boundary snapshot — outputs unchanged."""
    m, params = get_model("rwkv6-7b")
    reqs = group_requests(["12+34="], group=3)
    kw = dict(num_slots=2, kv_layout="paged", kv_block_size=8)
    _, base = run_engine(m, params, reqs, **kw)
    eng, outs = run_engine(m, params, reqs, prefix_share=True, **kw)
    assert [o.tokens for o in outs] == [o.tokens for o in base]
    assert eng.stats.prefix_hits == 2 and eng.stats.blocks_saved == 0


def test_prefix_hit_extension_shares_blocks():
    """A prompt that *extends* a registered prefix (longer prompt, same
    leading tokens) can't skip prefill but pins the matching full blocks
    and still decodes exactly (write-masked scatter never touches them).
    The extension registers in turn, so a repeat of the longer prompt is
    then an exact hit."""
    m, params = get_model("internlm2-1.8b")
    base_text, ext_text = "1234+5678=", "1234+5678=9"
    prompt0 = np.asarray(tok.encode(base_text, bos=True), np.int32)
    prompt1 = np.asarray(tok.encode(ext_text, bos=True), np.int32)
    assert np.array_equal(prompt1[:len(prompt0)], prompt0)
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4, prefix_share=True))
    r0 = Request(rid=0, prompt=prompt0, max_new_tokens=5)
    r1 = Request(rid=1, prompt=prompt1, max_new_tokens=5)
    eng.submit(r0)
    eng.submit(r1)
    outs = eng.run()
    assert eng.stats.prefix_partial_hits == 1
    assert outs[1].prefix_shared_blocks > 0
    for r, o in zip((r0, r1), outs):
        ref_t, ref_l = reference(m, params, r, max_new=5)
        assert o.tokens == ref_t, o.rid
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    eng.slots.check(extra_pins=eng.radix.pinned_blocks())
    # the extension's own tail boundary is now registered too
    m1 = eng.radix.match(r1)
    assert m1 is not None and m1.exact


def test_multi_turn_resume_history_registers():
    """A resumed episode's history (prompt + generated turn + tool tokens)
    registers in the tree, so a sibling rollout submitting that same
    history matches it — turn k+1 shares turn k's blocks instead of
    re-prefilling the whole conversation."""
    m, params = get_model("internlm2-1.8b")
    prompt = np.asarray(tok.encode("1+2=", bos=True), np.int32)
    ref_t, _ = reference(
        m, params, Request(rid=0, prompt=prompt, max_new_tokens=10),
        max_new=10)
    stop = ref_t[2]
    tool = np.asarray([7, 11, 13], np.int32)
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4, prefix_share=True))
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=10,
                       stop_tokens=(stop,)))
    eng.run()
    [sreq] = eng.harvest_suspended()
    eng.resume(sreq, tool, max_new_tokens=6, rid=1, stop_tokens=())
    [resumed] = eng.run()
    history = np.concatenate([prompt,
                              np.asarray(sreq.out.tokens, np.int32), tool])
    sibling = Request(rid=2, prompt=history, max_new_tokens=6)
    assert eng.radix.match(sibling) is not None   # history is in the tree
    hits0 = eng.radix.hits + eng.radix.partial_hits
    eng.submit(sibling)
    eng.run()
    out = eng.finished[2]
    assert eng.radix.hits + eng.radix.partial_hits == hits0 + 1
    assert out.prefix_shared_blocks > 0
    # same continuation the resume produced (the adoption path is
    # semantically a prefill of the history prompt)
    assert out.tokens == resumed.tokens
    eng.slots.check(extra_pins=eng.radix.pinned_blocks())


def test_frontend_requests_never_share():
    """Prompt tokens alone don't identify frontend-conditioned KV (prefill
    conditions on the embeddings), so requests carrying a frontend must
    bypass the radix tree even with matching keys and tokens."""
    import jax.numpy as jnp
    m, _ = get_model("internlm2-1.8b")
    from repro.models import build_model
    vm = build_model("qwen2-vl-7b", reduced=True)
    import jax
    vparams = vm.init(jax.random.PRNGKey(1))
    fr0 = jnp.zeros((1, vm.cfg.num_frontend_tokens, vm.cfg.d_model))
    fr1 = jnp.ones((1, vm.cfg.num_frontend_tokens, vm.cfg.d_model))
    # frontend embeddings overlay the first num_frontend_tokens prompt
    # positions, so the padded prompt must be at least that long
    prompt = np.asarray(tok.pad_batch(
        [tok.encode("1+2=", bos=True)],
        vm.cfg.num_frontend_tokens + 8)[0], np.int32)
    eng = Engine(vm, vparams, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4, prefix_share=True))
    for rid, fr in enumerate((fr0, fr1)):
        eng.submit(Request(rid=rid, prompt=prompt.copy(), max_new_tokens=4,
                           prefix_key="k", frontend=fr))
    outs = eng.run()
    assert eng.stats.prefix_hits == 0
    assert len(eng.radix) == 0 and eng.radix.stats["entries"] == 0
    # same tokens, different frontends -> genuinely different generations
    from repro.rl import SamplerConfig, generate
    for rid, fr in enumerate((fr0, fr1)):
        ref = generate(vm, vparams, jnp.asarray(prompt)[None],
                       jax.random.PRNGKey(0),
                       SamplerConfig(max_new_tokens=4, temperature=0.0),
                       frontend=fr)
        n = int(np.asarray(ref["mask"])[0].sum())
        assert outs[rid].tokens[:n] == \
            np.asarray(ref["completions"])[0][:n].tolist(), rid


def test_eviction_under_block_pressure_and_reset_flush():
    """Tree pins are evicted LRU when admission needs the blocks; reset
    flushes everything (new params invalidate cached prefills)."""
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=4,
        num_kv_blocks=blocks_for(MAX_LEN, 4),  # one stripe's worth
        prefix_share=True))
    probe = Request(rid=9, prompt=np.asarray(
        tok.encode("11+22=", bos=True), np.int32), max_new_tokens=4)
    eng.submit(Request(rid=0, prompt=probe.prompt.copy(), max_new_tokens=4))
    eng.run()
    assert len(eng.radix) >= 1
    assert eng.radix.match(probe) is not None
    # a big unrelated request needs (almost) the whole pool: path evicted
    eng.submit(Request(rid=1, prompt=np.asarray(
        tok.encode("3+4=", bos=True), np.int32), max_new_tokens=40))
    eng.run()
    assert eng.radix.evictions >= 1
    assert eng.radix.match(probe) is None
    eng.reset(params)
    assert len(eng.radix) == 0
    eng.slots.check()
    assert eng.slots.blocks_in_use == 0


# ---------------------------------------------------------------------------
# Strict-LRU eviction order (single-pass heap, leaf-first)
# ---------------------------------------------------------------------------
def _fake_req(tokens, key=None):
    return Request(rid=0, prompt=np.asarray(tokens, np.int32),
                   max_new_tokens=1, prefix_key=key)


def _register_blocks(index, alloc, owner, tokens):
    """Register a block-aligned prompt, materializing its blocks as a
    transient owner the way a donor slot would (refcount drops to the
    tree's single pin on free_all)."""
    req = _fake_req(tokens)
    n = len(tokens) // alloc.block_size
    alloc.reserve(owner, n)
    bids = [alloc.allocate(owner) for _ in range(n)]
    index.register(req, bids, logits=None, tail={}, slot_leaves={})
    alloc.free_all(owner)
    return req


def test_evict_for_strict_lru_order():
    """Eviction drains least-recently-used leaves first: three
    single-block paths registered A, B, C then A touched must evict in
    order B, C, A — and ``touch`` (recency) is what reorders, not
    registration order."""
    alloc = BlockAllocator(8, 4)
    index = RadixPrefixIndex(alloc)
    ra = _register_blocks(index, alloc, 1, [1, 2, 3, 4])
    rb = _register_blocks(index, alloc, 2, [5, 6, 7, 8])
    rc = _register_blocks(index, alloc, 3, [9, 10, 11, 12])
    ids = {name: index.match(r).node_ids[0]
           for name, r in (("a", ra), ("b", rb), ("c", rc))}
    index.touch(index.match(ra))             # A most recent
    assert index.evict_for(8)                # needs the whole pool
    assert index.eviction_log == [ids["b"], ids["c"], ids["a"]]
    assert len(index) == 0
    alloc.assert_clean()


def test_evict_for_leaf_first_parent_after_child():
    """A two-block path evicts leaf before parent (the parent enters the
    victim heap only once its last child is gone), and a node shared by
    a live pin (refcount > 1) or on the ``protect`` path survives."""
    alloc = BlockAllocator(8, 4)
    index = RadixPrefixIndex(alloc)
    rd = _register_blocks(index, alloc, 1, [1, 2, 3, 4, 5, 6, 7, 8])
    child_id = index.match(rd).node_ids[1]
    parent_id = index.match(rd).node_ids[0]
    # protect the whole path: nothing evictable
    assert not index.evict_for(8, protect=index.match(rd).node_ids)
    assert index.eviction_log == []
    # pin the parent like a live slot would: only the leaf goes
    alloc.incref(index.match(rd).nodes[0].block_id)
    assert not index.evict_for(8)
    assert index.eviction_log == [child_id]
    parent_bid = index.match(rd).nodes[0].block_id
    alloc.decref(parent_bid)
    assert index.evict_for(8)
    assert index.eviction_log == [child_id, parent_id]
    alloc.assert_clean()


# ---------------------------------------------------------------------------
# Tree checkpoint round-trips
# ---------------------------------------------------------------------------
def test_tree_export_import_structural_roundtrip():
    """Host/device export of the *tree* (parent links, tokens, boundary
    snapshots, counters) rebuilds an equivalent index: every match that
    hit before hits after, node identity and LRU clocks included."""
    alloc = BlockAllocator(16, 4)
    index = RadixPrefixIndex(alloc)
    ra = _register_blocks(index, alloc, 1, [1, 2, 3, 4, 5, 6, 7, 8])
    rb = _register_blocks(index, alloc, 2, [1, 2, 3, 4, 9, 9])  # shared head
    rc = _fake_req([20, 21, 22, 23], key="ns")
    alloc.reserve(3, 1)
    index.register(rc, [alloc.allocate(3)], logits=np.arange(4.0),
                   tail={"k": np.ones(2)}, slot_leaves={"s": np.zeros(3)})
    alloc.free_all(3)
    index.match(ra, count=True)
    index.touch(index.match(ra))
    host, device = index.export_host_state(), index.export_device_state()
    clone = RadixPrefixIndex(alloc)          # pins travel with the alloc
    clone.import_state(host, device)
    assert len(clone) == len(index)
    assert set(clone.roots) == {None, "ns"}
    for req in (ra, rb, rc):
        a, b = index.match(req), clone.match(req)
        assert a.node_ids == b.node_ids and a.block_ids == b.block_ids
        assert a.exact == b.exact
    # shared head: rb's first node IS ra's first node, after import too
    assert clone.match(ra).node_ids[0] == clone.match(rb).node_ids[0]
    snap = clone.match(rc).snapshot
    np.testing.assert_array_equal(np.asarray(snap.logits), np.arange(4.0))
    np.testing.assert_array_equal(np.asarray(snap.tail["k"]), np.ones(2))
    assert clone.stats == index.stats
    assert clone._tick == index._tick
    # the clone shares the alloc's pins; only the original may drop them
    index.flush()
    alloc.assert_clean()


def test_engine_roundtrip_int8_with_suspended_handle_mid_tree():
    """Engine-level checkpoint with the tree populated (multi-node paths,
    int8 scale leaves in the pool) *and* a suspended handle pinning
    blocks mid-tree: the import rebuilds the tree, the suspended request
    resumes, and new exact hits against imported snapshots stay
    token-identical."""
    m, params = get_model("internlm2-1.8b")
    cfg = EngineConfig(num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
                       kv_layout="paged", kv_block_size=4, kv_dtype="int8",
                       prefix_share=True)
    prompt = np.asarray(tok.encode("1234+5678=", bos=True), np.int32)
    eng = Engine(m, params, cfg)
    eng.submit(Request(rid=0, prompt=prompt.copy(), max_new_tokens=8))
    eng.run()
    ref_t, _ = reference(m, params,
                         Request(rid=0, prompt=prompt, max_new_tokens=8),
                         max_new=8)
    # suspend a second request mid-generation so the checkpoint carries a
    # live handle next to the tree pins
    stop = ref_t[2]
    eng.submit(Request(rid=1, prompt=prompt.copy(), max_new_tokens=8,
                       stop_tokens=(stop,)))
    eng.run()
    [sreq] = eng.harvest_suspended()
    state = eng.export_state()
    fresh = Engine(m, params, cfg)
    fresh.import_state(state)
    a = eng.radix.export_host_state()
    b = fresh.radix.export_host_state()
    assert a["counters"] == b["counters"]
    assert ([(n["id"], n["parent"], n["block_id"]) for n in a["nodes"]]
            == [(n["id"], n["parent"], n["block_id"]) for n in b["nodes"]])
    # an exact hit against the imported snapshot decodes identically
    hits0 = fresh.radix.hits
    fresh.submit(Request(rid=2, prompt=prompt.copy(), max_new_tokens=8))
    fresh.run()
    assert fresh.finished[2].tokens == ref_t
    assert fresh.radix.hits == hits0 + 1
    # the imported suspended handle still resumes (same rid bookkeeping)
    fsreq = fresh.suspended[1]
    fresh.resume(fsreq, (), max_new_tokens=4, rid=3, stop_tokens=())
    fresh.run()
    eng.resume(sreq, (), max_new_tokens=4, rid=3, stop_tokens=())
    eng.run()
    assert fresh.finished[3].tokens == eng.finished[3].tokens
    fresh.slots.check(extra_pins=fresh.radix.pinned_blocks())


def test_export_import_roundtrip_with_sharing_mid_flight():
    """Checkpoint a sharing engine with live shared slots; a fresh engine
    resumes token-for-token and keeps the invariants."""
    m, params = get_model("internlm2-1.8b")
    reqs = group_requests(["123+456="], group=3, max_new=8)
    cfg = EngineConfig(num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
                       kv_layout="paged", kv_block_size=4,
                       prefix_share=True)
    eng = Engine(m, params, cfg)
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.step()                              # live shared slots + 1 waiting
    state = eng.export_state()
    done_a = Engine(m, params, cfg)
    done_a.import_state(state)
    outs_a = done_a.run()
    outs_b = eng.run()                      # original continues too
    assert [o.tokens for o in outs_a] == [o.tokens for o in outs_b]
    for r, o in zip(reqs, outs_a):
        ref_t, _ = reference(m, params, r, max_new=8)
        assert o.tokens == ref_t
    done_a.slots.check(extra_pins=done_a.radix.pinned_blocks())


# ---------------------------------------------------------------------------
# KV-aware routing across prefill engines
# ---------------------------------------------------------------------------
def test_kv_aware_routing_steers_to_prefix_holder():
    """With two prefill engines, a request is routed to the engine whose
    tree already holds its prefix (not round-robin/least-loaded), turning
    repeats into zero-compute handles — outputs identical to monolithic."""
    from repro.serve import DisaggConfig, DisaggRouter
    m, params = get_model("internlm2-1.8b")
    pa = np.asarray(tok.encode("1234+5678=", bos=True), np.int32)
    pb = np.asarray(tok.encode("111+222=", bos=True), np.int32)
    cfg = DisaggConfig(prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
                       temperature=0.0, kv_layout="paged", kv_block_size=4,
                       prefix_share=True, prefill_engines=2,
                       kv_routing="kv_aware")
    router = DisaggRouter(m, params, cfg)
    assert router.prefill is router.prefills[0]
    # warm each engine with a different prompt (engine 1 warmed directly —
    # routing ties fall to engine 0 on an empty fleet)
    router.submit(Request(rid=0, prompt=pa.copy(), max_new_tokens=5))
    router.prefills[1].submit(Request(rid=1, prompt=pb.copy(),
                                      max_new_tokens=5))
    outs = {o.rid: o for o in router.run()}
    assert len(router.prefills[0].radix) > 0
    assert len(router.prefills[1].radix) > 0
    # repeats must land on their prefix holder, regardless of submit order
    router.submit(Request(rid=2, prompt=pb.copy(), max_new_tokens=5))
    router.submit(Request(rid=3, prompt=pa.copy(), max_new_tokens=5))
    outs.update({o.rid: o for o in router.run()})
    assert router.stats.kv_routed == 2
    assert router.prefills[0].stats.prefix_hits == 1
    assert router.prefills[1].stats.prefix_hits == 1
    assert outs[3].tokens == outs[0].tokens
    assert outs[2].tokens == outs[1].tokens
    for rid, prompt in ((0, pa), (1, pb)):
        ref_t, _ = reference(m, params,
                             Request(rid=rid, prompt=prompt,
                                     max_new_tokens=5), max_new=5)
        assert outs[rid].tokens == ref_t
    router.reset(params)


def test_queue_routing_balances_without_kv_affinity():
    """``kv_routing="queue"`` ignores prefix residency — requests spread
    by load alone and outputs stay correct (sharing still happens when a
    repeat happens to land on the holder)."""
    from repro.serve import DisaggConfig, DisaggRouter
    m, params = get_model("internlm2-1.8b")
    prompt = np.asarray(tok.encode("12+34=", bos=True), np.int32)
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0, kv_layout="paged", kv_block_size=4,
        prefix_share=True, prefill_engines=2, kv_routing="queue"))
    for rid in range(4):
        router.submit(Request(rid=rid, prompt=prompt.copy(),
                              max_new_tokens=5))
    outs = router.run()
    assert router.stats.kv_routed == 0
    ref_t, _ = reference(m, params,
                         Request(rid=0, prompt=prompt, max_new_tokens=5),
                         max_new=5)
    for o in outs:
        assert o.tokens == ref_t
    router.reset(params)


def test_router_config_validation():
    from repro.serve import DisaggConfig, DisaggRouter
    m, params = get_model("internlm2-1.8b")
    with pytest.raises(ValueError, match="prefill_engines"):
        DisaggRouter(m, params, DisaggConfig(prefill_engines=0))
    with pytest.raises(ValueError, match="kv_routing"):
        DisaggRouter(m, params, DisaggConfig(kv_routing="sticky"))


# ---------------------------------------------------------------------------
# Property: shared interleavings preserve allocator/slot invariants
# ---------------------------------------------------------------------------
def _drive_shared_slot_manager(ops, sm: PagedSlotManager, index_pins):
    """Random admit/admit-shared/grow/finish/evict interleavings.

    ``index_pins`` plays the radix tree: it pins (increfs) the full
    blocks of whichever live donor the op stream picks, and releases
    (decrefs) pins at random — exactly the lifecycle the engine drives.
    Invariants are checked after every op.
    """
    live, rid = [], 0
    for kind, val in ops:
        if kind == 0:                      # plain admit
            plen = 1 + val % 10
            budget = plen + 1 + val % 12
            if sm.can_admit(budget):
                slot = sm.assign(rid, prompt_len=plen, total_budget=budget)
                live.append((slot, plen, budget))
                rid += 1
        elif kind == 1 and live:           # shared admit from a live donor
            dslot, dplen, _ = live[val % len(live)]
            n_full = min(dplen // sm.block_size, sm.nblocks[dslot])
            shared = [int(b) for b in sm.tables[dslot, :n_full]]
            plen = max(dplen, 1 + val % 10)
            budget = plen + 1 + val % 12
            if sm.can_admit(budget, shared_blocks=len(shared)):
                slot = sm.assign_shared(rid, prompt_len=plen,
                                        total_budget=budget,
                                        shared_ids=shared)
                live.append((slot, plen, budget))
                rid += 1
        elif kind == 2 and live:           # decode progress -> table growth
            slot, plen, budget = live[val % len(live)]
            sm.ensure(slot, min(plen + val % 8, budget - 1))
        elif kind == 3 and live:           # pin a donor's blocks (register)
            dslot, dplen, _ = live[val % len(live)]
            n_full = min(dplen // sm.block_size, sm.nblocks[dslot])
            for b in sm.tables[dslot, :n_full]:
                sm.alloc.incref(int(b))
                index_pins.append(int(b))
        elif kind == 4 and index_pins:     # evict one pin
            sm.alloc.decref(index_pins.pop(val % len(index_pins)))
        elif kind == 5 and live:           # finish
            slot, _, _ = live.pop(val % len(live))
            sm.release(slot)
        sm.check(extra_pins=index_pins)
    for slot, _, _ in live:
        sm.release(slot)
    while index_pins:
        sm.alloc.decref(index_pins.pop())
    sm.check()
    assert sm.blocks_in_use == 0 and sm.num_free == sm.num_slots


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 63)),
                min_size=1, max_size=30))
def test_shared_slot_manager_interleaving(ops):
    m, _ = get_model("internlm2-1.8b")
    _drive_shared_slot_manager(
        ops, PagedSlotManager(m, 4, MAX_LEN, block_size=4, num_blocks=24),
        [])


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 1023)),
                min_size=1, max_size=100),
       st.integers(2, 6),                  # block size
       st.integers(8, 32))                 # pool blocks
def test_shared_slot_manager_interleaving_sweep(ops, bs, nb):
    m, _ = get_model("internlm2-1.8b")
    _drive_shared_slot_manager(
        ops, PagedSlotManager(m, 5, MAX_LEN, block_size=bs, num_blocks=nb),
        [])
