"""Live Pallas decode path: backend bit-exactness, int8 KV blocks, and the
kernel/scheduler bugfix regressions this sweep locked in.

The engine's ``kernel_backend="pallas"`` contract is that greedy decode is
**token-identical** to the default vmapped-model-step path (and logprobs
match to float tolerance) across every serving configuration: both KV
layouts, every admission policy, prefix sharing, and disaggregated
prefill/decode.  ``kv_dtype="int8"`` relaxes only the *cross-precision*
comparison — quantization legitimately perturbs logits, so int8 output is
compared within the int8 family (jnp vs pallas, monolithic vs disagg),
where tokens must again be identical.

Also locked in here, as regressions for this PR's bugfix sweep:

* ``paged_decode_attention`` at block-boundary lengths (the ragged-tail /
  null-block masking fix) — every length in {bs-1, bs, bs+1, 2bs, 2bs+1};
* the fused sampling kernels vs their pure-jnp oracles (first-occurrence
  argmax tie-breaking included);
* int8 quantize/dequantize round-trip error bounds and idempotence (the
  property block re-quantization correctness rests on);
* backend flips invalidating ``SLOPolicy``'s learned service-time state
  (``on_backend_change`` re-arms the first-sample compile discard);
* lazy per-call interpret resolution (override > env var > backend).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import tokenizer as tok
from repro.models import build_model
from repro.serve import Engine, EngineConfig, Request
from repro.serve.blocks import blocks_for

MAX_LEN = 48
PROMPTS = ["1+2=", "10+20=", "7+8=", "30+4="]

_MODELS = {}


def get_model(arch):
    if arch not in _MODELS:
        m = build_model(arch, reduced=True)
        _MODELS[arch] = (m, m.init(jax.random.PRNGKey(1)))
    return _MODELS[arch]


def make_requests(n, max_new=5, prefix_key=None):
    return [Request(rid=i, prompt=np.asarray(tok.encode(p, bos=True),
                                             np.int32),
                    max_new_tokens=max_new, prefix_key=prefix_key)
            for i, p in enumerate(PROMPTS[:n])]


def run_engine(m, params, cfg, n=3, **req_kw):
    eng = Engine(m, params, cfg)
    for r in make_requests(n, **req_kw):
        eng.submit(r)
    outs = eng.run()
    return {o.rid: (o.tokens, np.asarray(o.logprobs)) for o in outs}, eng


def assert_same(got, ref, *, logp_atol=1e-5, ctx=""):
    assert set(got) == set(ref)
    for rid in ref:
        assert got[rid][0] == ref[rid][0], (ctx, rid, got[rid][0],
                                            ref[rid][0])
        np.testing.assert_allclose(got[rid][1], ref[rid][1],
                                   atol=logp_atol, err_msg=f"{ctx} rid={rid}")


# ---------------------------------------------------------------------------
# Backend bit-exactness: pallas engine == jnp engine
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-4b"])
@pytest.mark.parametrize("layout", ["contiguous", "paged"])
def test_pallas_engine_matches_jnp(arch, layout):
    m, params = get_model(arch)
    base = dict(num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
                kv_layout=layout,
                **({"kv_block_size": 8} if layout == "paged" else {}))
    ref, _ = run_engine(m, params, EngineConfig(**base))
    got, eng = run_engine(m, params,
                          EngineConfig(**base, kernel_backend="pallas"))
    assert eng.kernel_backend == "pallas"
    assert_same(got, ref, ctx=f"{arch}/{layout}")


@pytest.mark.slow
@pytest.mark.parametrize("sched", ["fifo", "deadline", "slo"])
@pytest.mark.parametrize("share", [False, True])
def test_pallas_sched_prefix_matrix(sched, share):
    """Scheduling policy and prefix sharing reorder *when* requests decode,
    never what they decode — the pallas path must honour that too."""
    m, params = get_model("internlm2-1.8b")
    base = dict(num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
                kv_layout="paged", kv_block_size=8, sched=sched,
                prefix_share=share)
    key = ("grp", 0) if share else None
    ref, _ = run_engine(m, params, EngineConfig(**base), prefix_key=key)
    got, _ = run_engine(m, params,
                        EngineConfig(**base, kernel_backend="pallas"),
                        prefix_key=key)
    assert_same(got, ref, ctx=f"{sched}/share={share}")


@pytest.mark.slow
@pytest.mark.parametrize("kv_dtype", [None, "int8"])
def test_pallas_disagg_matches_monolithic(kv_dtype):
    """Disaggregated prefill/decode under the pallas backend (and int8
    pools: the KV handle dequantizes through the scale-aware fetch)
    matches the monolithic engine of the same precision family."""
    from repro.serve.router import DisaggConfig, DisaggRouter
    m, params = get_model("internlm2-1.8b")
    mono, _ = run_engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=8, kv_dtype=kv_dtype,
        kernel_backend="pallas"))
    router = DisaggRouter(m, params, DisaggConfig(
        prefill_slots=1, decode_slots=2, max_seq_len=MAX_LEN,
        temperature=0.0, kv_layout="paged", kv_block_size=8,
        kv_dtype=kv_dtype, kernel_backend="pallas"))
    for r in make_requests(3):
        router.submit(r)
    outs = router.run()
    got = {o.rid: (o.tokens, np.asarray(o.logprobs)) for o in outs}
    # quantize->dequantize->requantize reproduces the same block payload,
    # so even int8 adoption stays bit-identical to the monolithic admit
    assert_same(got, mono, ctx=f"disagg/{kv_dtype}")


def test_pallas_rwkv6_falls_back_to_jnp():
    m, params = get_model("rwkv6-7b")
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         kernel_backend="pallas"))
    assert eng.kernel_backend == "jnp"          # silent: nothing to page
    assert eng.config.kernel_backend == "pallas"


def test_pallas_mla_rejects():
    m, params = get_model("deepseek-v2-236b")
    with pytest.raises(ValueError, match="does not support"):
        Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                       kernel_backend="pallas"))


def test_engine_config_validation():
    with pytest.raises(ValueError, match="kernel_backend"):
        EngineConfig(kernel_backend="cuda")
    with pytest.raises(ValueError, match="kv_dtype"):
        EngineConfig(kv_dtype="fp8")
    with pytest.raises(ValueError, match="paged"):
        EngineConfig(kv_dtype="int8", kv_layout="contiguous")


# ---------------------------------------------------------------------------
# int8 KV blocks
# ---------------------------------------------------------------------------
def test_int8_jnp_and_pallas_token_identical():
    """int8 legitimately drifts from fp32 (near-tie greedy flips allowed),
    but the two backends must agree with *each other* on the quantized
    pool — same tokens, logprobs within the write-order tolerance (the
    jnp step attends the current token's K/V pre-quantization, the kernel
    post-quantization)."""
    m, params = get_model("internlm2-1.8b")
    base = dict(num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
                kv_layout="paged", kv_block_size=8, kv_dtype="int8")
    a, ea = run_engine(m, params, EngineConfig(**base))
    b, eb = run_engine(m, params,
                       EngineConfig(**base, kernel_backend="pallas"))
    assert_same(b, a, logp_atol=2e-2, ctx="int8")
    # the quantized pool really is int8 + f32 scales
    for name in m.paged_cache_names():
        assert ea.slots.cache[name].dtype == jnp.int8
        assert eb.slots.cache[name].dtype == jnp.int8
    for name in m.scale_cache_names():
        assert ea.slots.cache[name].dtype == jnp.float32


def test_int8_logprobs_close_to_fp32():
    """Quantization error is bounded: int8 behaviour logprobs stay within
    a small absolute band of the fp32 engine on the same trace (tokens may
    differ at near-ties, so compare only the common prefix per request)."""
    m, params = get_model("internlm2-1.8b")
    base = dict(num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
                kv_layout="paged", kv_block_size=8)
    fp, _ = run_engine(m, params, EngineConfig(**base))
    i8, _ = run_engine(m, params, EngineConfig(**base, kv_dtype="int8"))
    for rid in fp:
        n = next((i for i, (x, y) in enumerate(zip(fp[rid][0], i8[rid][0]))
                  if x != y), min(len(fp[rid][0]), len(i8[rid][0])))
        if n:
            np.testing.assert_allclose(i8[rid][1][:n], fp[rid][1][:n],
                                       atol=5e-2)


def test_int8_pool_refcount_conservation():
    """Slot/block bookkeeping is dtype-blind: after an int8 run every
    invariant the slot manager checks (table/allocator agreement, refcount
    conservation) holds, and a reset leaves the pool leak-free."""
    m, params = get_model("internlm2-1.8b")
    _, eng = run_engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=8, kv_dtype="int8"))
    eng.slots.check()
    eng.reset(params)
    eng.slots.alloc.assert_clean(context="int8 test")


def test_quantize_roundtrip_bounds_and_idempotence(rng_key):
    from repro.models import kvcache
    x = jax.random.normal(rng_key, (4, 32, 2, 16)) * 3.0
    q, s = kvcache.quantize_kv(x, 2)
    assert q.dtype == jnp.int8 and s.shape == x.shape[:2]
    d = kvcache.dequantize_kv(q, s, jnp.float32)
    # per-position error bound: half a quantization step of that position
    step = np.asarray(s)[..., None, None]
    assert (np.abs(np.asarray(d - x)) <= 0.5 * step + 1e-7).all()
    # idempotence: re-quantizing a dequantized block reproduces it exactly
    # (the max-magnitude position sits at ±127, pinning the same scale)
    q2, s2 = kvcache.quantize_kv(d, 2)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s), rtol=1e-6)


# ---------------------------------------------------------------------------
# Kernel regressions: block-boundary lengths, fused sampling
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bs", [8, 16])
def test_paged_attention_block_boundary_sweep(bs, rng_key):
    """Null-block / ragged-tail masking regression: every length that
    straddles a block boundary ({bs-1, bs, bs+1, 2bs, 2bs+1}), in one
    batch so short rows and multi-block rows share the kernel grid."""
    from repro.kernels import ref
    from repro.kernels.decode_attention import paged_decode_attention
    lengths = np.asarray([bs - 1, bs, bs + 1, 2 * bs, 2 * bs + 1], np.int32)
    B, H, Hkv, D = len(lengths), 4, 2, 16
    MB = blocks_for(int(lengths.max()), bs) + 1
    NB = B * MB + 1
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k_pool = jax.random.normal(ks[1], (NB, bs, Hkv, D))
    v_pool = jax.random.normal(ks[2], (NB, bs, Hkv, D))
    tables = np.zeros((B, MB), np.int32)
    nxt = 1
    for b, n in enumerate(lengths):
        nb = blocks_for(int(n), bs)
        tables[b, :nb] = np.arange(nxt, nxt + nb)
        nxt += nb
    out = paged_decode_attention(q, k_pool, v_pool, tables, lengths)
    expect = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables,
                                            lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-5, atol=5e-5)


def test_greedy_sample_matches_oracle(rng_key):
    from repro.kernels import ref
    from repro.kernels.sampling import greedy_sample
    logits = jax.random.normal(rng_key, (5, 700))
    # plant exact ties to pin first-occurrence argmax semantics
    logits = logits.at[0, 13].set(50.0).at[0, 600].set(50.0)
    t, lp = greedy_sample(logits)
    rt, rlp = ref.greedy_sample_ref(logits)
    np.testing.assert_array_equal(np.asarray(t), np.asarray(rt))
    np.testing.assert_allclose(np.asarray(lp), np.asarray(rlp),
                               rtol=1e-6, atol=1e-6)
    assert int(t[0]) == 13


def test_topk_mask_matches_oracle(rng_key):
    from repro.kernels import ref
    from repro.kernels.sampling import topk_mask
    logits = jax.random.normal(rng_key, (3, 500))
    for k in (1, 7, 64):
        got = topk_mask(logits, k)
        exp = ref.topk_mask_ref(logits, k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))


# ---------------------------------------------------------------------------
# Scheduler bugfix: backend flips invalidate learned service time
# ---------------------------------------------------------------------------
def test_backend_flip_resets_slo_estimate():
    from repro.serve.sched import SLOPolicy
    m, params = get_model("internlm2-1.8b")
    pol = SLOPolicy(time_per_token=0.05)
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0, sched="slo"),
                 policy=pol)
    for r in make_requests(3):
        eng.submit(r)
    eng.run()
    assert pol._step_samples > 1            # estimate actually learned
    assert pol.time_per_token != 0.05
    eng.set_kernel_backend("pallas")
    assert eng.kernel_backend == "pallas"
    # learned estimate invalidated, compile discard re-armed
    assert pol.time_per_token == 0.05
    assert pol._step_samples == 0
    # flipping back is a real change again; same-value flip is a no-op
    eng.set_kernel_backend("pallas")
    assert pol._step_samples == 0
    # and the flipped engine still serves correctly
    ref, _ = run_engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, temperature=0.0))
    for r in make_requests(3):
        eng.submit(r)
    got = {o.rid: (o.tokens, np.asarray(o.logprobs)) for o in eng.run()}
    assert_same(got, ref, ctx="post-flip")


def test_backend_flip_refuses_live_engine():
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                                         temperature=0.0))
    eng.submit(make_requests(1)[0])
    with pytest.raises(RuntimeError, match="live engine"):
        eng.set_kernel_backend("pallas")
    eng.run()
    eng.set_kernel_backend("pallas")        # drained: allowed


# ---------------------------------------------------------------------------
# Lazy interpret resolution (ops bugfix)
# ---------------------------------------------------------------------------
def test_resolve_interpret_precedence(monkeypatch):
    from repro.kernels import ops
    monkeypatch.delenv(ops._ENV_VAR, raising=False)
    assert ops.resolve_interpret() == (jax.default_backend() != "tpu")
    monkeypatch.setenv(ops._ENV_VAR, "0")
    assert ops.resolve_interpret() is False
    monkeypatch.setenv(ops._ENV_VAR, "true")
    assert ops.resolve_interpret() is True
    ops.set_interpret(False)                # override beats env
    try:
        assert ops.resolve_interpret() is False
    finally:
        ops.set_interpret(None)
    assert ops.resolve_interpret() is True  # env visible again
