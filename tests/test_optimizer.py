"""AdamW + schedules + host-cache checkpointing."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import (AdamWConfig, adamw_init, adamw_update,
                         load_checkpoint, save_checkpoint, warmup_cosine)


def test_adamw_converges_quadratic():
    cfg = AdamWConfig(lr=0.1, grad_clip=0)
    params = {"x": jnp.asarray([5.0, -3.0])}
    opt = adamw_init(params, cfg)
    for _ in range(200):
        grads = {"x": 2 * params["x"]}
        params, opt, m = adamw_update(grads, opt, params, cfg)
    assert float(jnp.abs(params["x"]).max()) < 1e-2
    assert int(opt["step"]) == 200


def test_grad_clip():
    cfg = AdamWConfig(lr=0.0, grad_clip=1.0)
    params = {"x": jnp.zeros(3)}
    opt = adamw_init(params, cfg)
    _, _, m = adamw_update({"x": jnp.full(3, 100.0)}, opt, params, cfg)
    assert float(m["grad_norm"]) == pytest.approx(100.0 * np.sqrt(3), rel=1e-5)


def test_warmup_cosine():
    sched = warmup_cosine(1.0, warmup=10, total=100)
    assert float(sched(jnp.asarray(5))) == pytest.approx(0.5)
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, rel=1e-2)
    assert float(sched(jnp.asarray(100))) == pytest.approx(0.1, rel=1e-2)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(5), "b": {"c": jnp.ones((2, 2))}}
    p = str(tmp_path / "ck.pkl")
    save_checkpoint(p, tree)
    out = load_checkpoint(p)
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.ones((2, 2)))
