"""Streaming phase mux (``rl.stream`` / ``--mux stream``) equivalence and
contract suite.

The load-bearing guarantees:

  * ``stream`` with ``max_staleness=0``, instant rewards and the default
    full-batch trainer is *bit-exact* to ``pipeline(max_staleness=0)`` —
    and therefore to the sequential path: same per-step losses, same
    final params/optimizer state.  Streaming changes when things run,
    never what is computed.
  * the group-streaming rollout (``generate_continuous_stream``) yields
    every GRPO prompt group exactly once, with arrays that reassemble to
    ``generate_continuous``'s output bit for bit.
  * reward-pool permit interleaving never violates group isolation: each
    verifier call sees exactly one group's rows, whatever order groups
    finish or workers run in.
  * staleness > 1 is honoured (realized lag bounded by the guard) and
    every history record carries the clipped importance-ratio
    diagnostics next to it.
  * the third ("reward") permit pool is measured: timelines, PhaseProfile
    ``reward_s`` durations, and the simulator's reward phase consume it.
"""
import threading

import jax
import numpy as np
import pytest

from repro.core.job import RLJob
from repro.core.phase_control import PhaseProfile
from repro.core.simulator import simulate_profiles
from repro.models import build_model
from repro.rl.coexec import GRPOJob, run_pipelined, run_sequential
from repro.rl.rewards import (CompositeReward, ExternalVerifier,
                              format_reward, length_penalty_reward,
                              make_reward)
from repro.rl.rollout import (SamplerConfig, generate_continuous,
                              generate_continuous_stream)
from repro.rl.stream import run_streaming

_MODELS = {}


def get_model(arch="internlm2-1.8b"):
    if arch not in _MODELS:
        _MODELS[arch] = build_model(arch, reduced=True)
    return _MODELS[arch]


def toy_reward(completions, mask, answers):
    """Deterministic reward with intra-group variance (random-init models
    rarely earn the arithmetic reward, which would zero all advantages)."""
    c = np.asarray(completions, np.int64)
    m = np.asarray(mask)
    return ((c * m).sum(axis=1) % 5).astype(np.float32)


KW = dict(steps=3, batch=2, group=2, max_new=4, temperature=1.0)


def make_job(jid="job0", seed=0, **over):
    kw = {**KW, **over}
    reward_fn = kw.pop("reward_fn", toy_reward)
    return GRPOJob(jid, model=get_model(), seed=seed, reward_fn=reward_fn,
                   **kw)


def losses(history):
    return [r["loss"] for r in history]


def assert_trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Equivalence: streaming changes the schedule, not the math
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("rollout", ["static", "engine"])
def test_stream_sync_instant_rewards_bit_exact_to_pipeline(rollout):
    s_pipe, h_pipe, _ = run_pipelined(make_job(rollout=rollout),
                                      max_staleness=0)
    s_str, h_str, r_str = run_streaming(make_job(rollout=rollout),
                                        max_staleness=0)
    assert losses(h_pipe) == losses(h_str)
    assert [r["reward"] for r in h_pipe] == [r["reward"] for r in h_str]
    assert all(r["rollout_staleness"] == 0 for r in h_str)
    assert_trees_equal(s_pipe["params"], s_str["params"])
    assert_trees_equal(s_pipe["opt"], s_str["opt"])
    # ... and the sequential path closes the triangle
    s_off, h_off, _ = run_sequential(make_job(rollout=rollout))
    assert losses(h_off) == losses(h_str)
    assert_trees_equal(s_off["params"], s_str["params"])
    # the reward pool really ran: one permit per group per iteration
    assert len(r_str.timelines["reward"]) == KW["steps"] * KW["batch"]


def test_stream_slow_jittered_rewards_same_math():
    """Latency and permit interleaving must not leak into the numbers:
    a slow, jittered external verifier produces the same losses as the
    instant path (the verifier wraps the same row-wise reward)."""
    slow = ExternalVerifier(toy_reward, latency_s=0.02, jitter=0.5, seed=3)
    s_ref, h_ref, _ = run_streaming(make_job(rollout="engine"),
                                    max_staleness=0)
    s_slow, h_slow, rep = run_streaming(
        make_job(rollout="engine", reward_fn=slow), max_staleness=0,
        reward_workers=3)
    assert losses(h_ref) == losses(h_slow)
    assert_trees_equal(s_ref["params"], s_slow["params"])
    assert slow.calls == KW["steps"] * KW["batch"]
    # verification time really landed on the third pool
    prof = rep.profiles["job0"]
    assert len(prof.reward_s) == KW["steps"] * KW["batch"]
    assert rep.total_reward_s >= 0.02 * slow.calls * 0.5


# ---------------------------------------------------------------------------
# Group streaming: incremental yield reassembles the batch output
# ---------------------------------------------------------------------------
def test_generate_continuous_stream_matches_batch_executor():
    model = get_model()
    params = model.init(jax.random.PRNGKey(0))
    sampler = SamplerConfig(max_new_tokens=6, temperature=0.0)
    rng = jax.random.PRNGKey(1)
    # varying prompts => varying EOS timing => completion order != rid order
    from repro.data import ArithmeticTask
    b = ArithmeticTask(seed=5).sample_batch(3)
    prompts = np.repeat(b.prompts, 2, axis=0)           # 3 groups of 2
    ref = generate_continuous(model, params, prompts, rng, sampler,
                              num_slots=2)
    gouts = list(generate_continuous_stream(model, params, prompts, rng,
                                            sampler, group=2, num_slots=2))
    assert sorted(g["group_index"] for g in gouts) == [0, 1, 2]
    B, T = ref["completions"].shape
    comp = np.zeros((B, T), np.int32)
    logp = np.zeros((B, T), np.float32)
    mask = np.zeros((B, T), np.float32)
    for g in gouts:
        comp[g["rows"]] = g["completions"]
        logp[g["rows"]] = g["behavior_logp"]
        mask[g["rows"]] = g["mask"]
    np.testing.assert_array_equal(comp, np.asarray(ref["completions"]))
    np.testing.assert_array_equal(logp, np.asarray(ref["behavior_logp"]))
    np.testing.assert_array_equal(mask, np.asarray(ref["mask"]))


def test_engine_harvest_is_incremental_and_non_draining():
    from repro.data import tokenizer as tok
    from repro.serve import Engine, EngineConfig, Request

    m = get_model()
    params = m.init(jax.random.PRNGKey(0))
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=24,
                                         temperature=0.0))
    prompt = np.asarray(tok.encode("5+5=", bos=True), np.int32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=1))
    eng.submit(Request(rid=1, prompt=prompt, max_new_tokens=8))
    seen = []
    while not eng.idle:
        eng.step()
        seen.extend(o.rid for o in eng.harvest())
    assert seen == [0, 1]                   # short request harvested first,
    #                                         while rid 1 was still decoding
    assert sorted(eng.finished) == [0, 1]   # finished stays for batch users
    assert eng.harvest() == []              # nothing new since last harvest


# ---------------------------------------------------------------------------
# Reward pool: permit interleaving never violates group isolation
# ---------------------------------------------------------------------------
def test_reward_pool_group_isolation_under_interleaving():
    calls = []
    lock = threading.Lock()

    def recording_reward(completions, mask, answers):
        with lock:
            calls.append((np.asarray(completions).copy(), list(answers)))
        return toy_reward(completions, mask, answers)

    jittered = ExternalVerifier(recording_reward, latency_s=0.01,
                                jitter=0.9, seed=7)
    job = make_job(rollout="engine", batch=3, reward_fn=jittered)
    # forced sync so the sequential run below sees the same completions;
    # the jittered latencies still interleave the three reward workers
    _, hist, _ = run_streaming(job, max_staleness=0, reward_workers=3)
    assert len(calls) == KW["steps"] * 3
    g = KW["group"]
    for comp, answers in calls:
        # exactly one group's rows per verifier call...
        assert comp.shape[0] == g
        # ...all duplicating the same prompt's answer
        assert len(set(answers)) == 1
    # and the recorded rewards match an isolated sequential run
    job2 = make_job(rollout="engine", batch=3)
    _, hist2, _ = run_sequential(job2)
    assert [r["reward"] for r in hist] == [r["reward"] for r in hist2]


# ---------------------------------------------------------------------------
# Staleness > 1 + importance-ratio diagnostics
# ---------------------------------------------------------------------------
def test_stream_staleness_guard_bounds_lag_and_records_diagnostics():
    _, hist, _ = run_streaming(make_job(steps=6, rollout="engine"),
                               max_staleness=2)
    stale = [r["rollout_staleness"] for r in hist]
    assert all(0 <= s <= 2 for s in stale)
    for rec in hist:
        for key in ("clip_frac", "ratio_mean", "ratio_max", "micro_steps"):
            assert key in rec
        assert np.isfinite(rec["loss"])
        assert np.isfinite(rec["ratio_mean"])
        assert rec["ratio_max"] >= 0.0
        assert 0.0 <= rec["clip_frac"] <= 1.0


def test_stream_micro_batched_trainer_steps_per_group():
    job = make_job(rollout="engine", batch=4)
    _, hist, _ = run_streaming(job, max_staleness=1, micro_groups=2)
    assert all(r["micro_steps"] == 2 for r in hist)     # 4 groups / 2
    assert all(np.isfinite(r["loss"]) for r in hist)


# ---------------------------------------------------------------------------
# Third pool in PhaseProfile and the simulator
# ---------------------------------------------------------------------------
def test_phase_profile_reward_pool_flows_to_simulator():
    _, _, rep = run_streaming(make_job(rollout="engine",
                                       reward_fn=ExternalVerifier(
                                           toy_reward, latency_s=0.01)),
                              max_staleness=1)
    prof = rep.profiles["job0"]
    assert prof.t_reward > 0
    job = prof.to_job()
    assert job.t_reward == prof.t_reward
    assert job.t_solo == pytest.approx(job.t_roll + job.t_reward
                                       + job.t_train)
    res = simulate_profiles([prof])
    assert res.iter_time["job0"] > 0
    # a second, reward-free profile must keep simulating exactly as before
    p2 = PhaseProfile("p2", (1.0, 1.0), (0.5, 0.5))
    assert p2.to_job().t_reward == 0.0


def test_phase_profile_aggregates_multi_permit_phases_per_iteration():
    """The streaming executor takes one reward permit per group and one
    train permit per micro-step; the profile's worst-case durations must
    report the heaviest *iteration's* total, not the longest single
    permit — otherwise conservative admission under-reserves the pool."""
    # 2 iterations, 2 reward permits each: iteration totals 0.3 and 0.7
    p = PhaseProfile("j", rollout_s=(1.0, 1.0), train_s=(0.5, 0.5),
                     reward_s=(0.1, 0.2, 0.3, 0.4))
    assert p.iterations == 2
    assert p.t_reward == pytest.approx(0.7)
    assert p.to_job().t_reward == pytest.approx(0.7)
    # micro-batched training: 2 train permits per iteration
    pm = PhaseProfile("j", rollout_s=(1.0, 1.0),
                      train_s=(0.2, 0.3, 0.4, 0.1))
    assert pm.t_train == pytest.approx(0.5)
    # one permit per iteration keeps the old max-permit semantics
    p1 = PhaseProfile("j", rollout_s=(1.0, 2.0), train_s=(0.5, 0.8))
    assert p1.t_train == pytest.approx(0.8)
    assert p1.t_roll == pytest.approx(2.0)


def test_simulator_reward_phase_serializes_solo_job():
    """With one job and reward modeled, the strict round-robin iteration
    is the serial sum of the three phases (no co-member to overlap)."""
    from repro.core.group import CoExecutionGroup, Placement
    from repro.core.cluster import H20, Node

    g = CoExecutionGroup("g", [Node("r0", H20)], [Node("t0", H20)])
    g.add_job(RLJob("j", t_roll=2.0, t_train=1.0, t_reward=0.5),
              Placement(("r0",)))
    res = g.simulate(n_cycles=8, discard=2)
    assert res.iter_time["j"] == pytest.approx(3.5, rel=1e-6)
    # two jobs: reward pool overlaps with the other job's phases
    g.add_job(RLJob("j2", t_roll=2.0, t_train=1.0, t_reward=0.5),
              Placement(("r0",)))
    res2 = g.simulate(n_cycles=10, discard=2, work_conserving=True)
    assert set(res2.iter_time) == {"j", "j2"}


# ---------------------------------------------------------------------------
# Verifier zoo
# ---------------------------------------------------------------------------
def test_reward_verifiers_are_row_wise_and_sane():
    from repro.data import tokenizer as tok

    texts = ["12", "-7", "12x", ""]
    T = 6
    comp = np.full((4, T), tok.EOS, np.int32)
    mask = np.zeros((4, T), np.float32)
    for i, t in enumerate(texts):
        ids = tok.encode(t)
        comp[i, :len(ids)] = ids
        # engine semantics: the EOS that stops the row is still recorded
        mask[i, :min(len(ids) + 1, T)] = 1.0
    answers = ["12", "0", "12", "3"]
    fmt = format_reward(comp, mask, answers)
    assert fmt.tolist() == [1.0, 1.0, 0.0, 0.0]
    lp = length_penalty_reward(comp, mask, answers, target_tokens=1,
                               penalty_per_token=0.2)
    assert lp.shape == (4,)
    assert lp[0] <= 1.0                     # penalty applied beyond target
    comp_r = CompositeReward([(format_reward, 0.5)])(comp, mask, answers)
    np.testing.assert_allclose(comp_r, 0.5 * fmt)
    # row-wise contract: per-group slices concatenate to the batch result
    full = length_penalty_reward(comp, mask, answers)
    split = np.concatenate([
        length_penalty_reward(comp[:2], mask[:2], answers[:2]),
        length_penalty_reward(comp[2:], mask[2:], answers[2:])])
    np.testing.assert_array_equal(full, split)


def test_make_reward_factory():
    fn = make_reward("arith")
    assert fn.__name__ == "arithmetic_reward"
    slow = make_reward("format", latency_s=0.01)
    assert isinstance(slow, ExternalVerifier)
    with pytest.raises(ValueError):
        make_reward("nope")


# ---------------------------------------------------------------------------
# Engine-measured service time feeds SLO estimates (bugfix satellite)
# ---------------------------------------------------------------------------
def test_slo_estimate_fed_by_engine_step_accounting():
    from repro.data import tokenizer as tok
    from repro.serve import Engine, EngineConfig, Request
    from repro.serve.sched import SLOPolicy

    m = get_model()
    params = m.init(jax.random.PRNGKey(0))
    policy = SLOPolicy(slowdown=2.0, time_per_token=123.0)  # absurd prior
    eng = Engine(m, params, EngineConfig(num_slots=2, max_seq_len=24,
                                         temperature=0.0), policy=policy)
    prompt = np.asarray(tok.encode("5+5=", bos=True), np.int32)
    for i in range(4):
        eng.submit(Request(rid=i, prompt=prompt, max_new_tokens=6))
    eng.run()
    # the estimate now comes from measured decode service time, not the
    # absurd prior and not the finish-interval EMA
    assert policy._step_samples >= 2
    assert policy.time_per_token < 123.0
    assert policy.time_per_token == pytest.approx(
        eng.stats.time_per_token, rel=5.0)  # same order of magnitude
    # finish-heuristic refinement is retired once step measurements exist
    before = policy.time_per_token
    out = eng.finished[0]
    out.first_token_time, out.finish_time = 1.0, 500.0
    policy.observe_finish(out)
    assert policy.time_per_token == before
    assert eng.stats.decode_time_s > 0


def test_slo_finish_fallback_survives_single_discarded_step_sample():
    """The first step sample is discarded as compile noise; with exactly
    one dispatch ever seen, the finish-interval fallback must still
    refine the estimate (a lone discarded sample must not retire it)."""
    from repro.serve.request import RequestOutput
    from repro.serve.sched import SLOPolicy

    policy = SLOPolicy(slowdown=2.0, time_per_token=10.0)
    policy.observe_step(99.0, 4)        # compile-contaminated, discarded
    assert policy.time_per_token == 10.0
    out = RequestOutput(rid=0, prompt=np.zeros(2, np.int32),
                        tokens=[1, 2, 3], logprobs=[0.0] * 3)
    out.first_token_time, out.finish_time = 1.0, 1.2
    policy.observe_finish(out)
    assert policy.time_per_token < 10.0     # fallback still active
    policy.observe_step(0.4, 4)             # real sample: direct estimate
    assert policy.time_per_token == pytest.approx(0.1)
    before = policy.time_per_token
    policy.observe_finish(out)              # now retired
    assert policy.time_per_token == before
