"""Paged KV-cache layer: BlockAllocator / PagedSlotManager property
invariants, block-gated admission, and the block-table decode kernel.

The property sweeps (``tests/_hypothesis_compat``: real hypothesis when
installed, deterministic seeded draws otherwise) drive random
admit/grow/finish interleavings and assert after every operation that no
block is double-assigned, leaked, or double-freed and that the free-block
count is conserved.  The full-size interleaving sweeps are marked ``slow``
so the fast lane (``pytest -m "not slow"``) stays quick.

Engine-level greedy equivalence of the paged layout lives in
``tests/test_serve_engine.py``; here we cover the paged-only behaviours:
admission gated on block availability (not just free slots), rejection of
requests larger than the pool, the zero-block degenerate case (rwkv6 has
no ``cache_seq`` leaves), and ``paged_decode_attention`` vs its oracle.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st
from test_serve_engine import MAX_LEN, get_model, make_requests, reference

from repro.data import tokenizer as tok
from repro.serve import (BlockAllocator, Engine, EngineConfig,
                         PagedSlotManager, Request, blocks_for)


# ---------------------------------------------------------------------------
# BlockAllocator properties
# ---------------------------------------------------------------------------
def _drive_allocator(ops, num_blocks):
    """Replay (kind, value) ops; invariants checked after every op."""
    alloc = BlockAllocator(num_blocks, block_size=4)
    live, next_owner = [], 0
    for kind, val in ops:
        if kind == 0:                      # admit a new owner
            n = 1 + val % num_blocks
            if alloc.can_reserve(n):
                alloc.reserve(next_owner, n)
                live.append(next_owner)
                next_owner += 1
        elif kind == 1 and live:           # grow a random live owner
            o = live[val % len(live)]
            if alloc.quota[o] > 0:
                bid = alloc.allocate(o)
                assert 1 <= bid <= num_blocks
        elif kind == 2 and live:           # finish a random owner
            alloc.free_all(live.pop(val % len(live)))
        alloc.check()
    for o in live:                         # drain: everything comes back
        alloc.free_all(o)
    alloc.check()
    assert alloc.num_free == alloc.num_blocks
    assert not alloc.quota and not alloc.refcount


@settings(max_examples=25, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 63)),
                min_size=1, max_size=30),
       st.integers(1, 12))
def test_block_allocator_interleaving(ops, num_blocks):
    _drive_allocator(ops, num_blocks)


@pytest.mark.slow
@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 1023)),
                min_size=1, max_size=120),
       st.integers(1, 48))
def test_block_allocator_interleaving_sweep(ops, num_blocks):
    _drive_allocator(ops, num_blocks)


def test_block_allocator_rejects_bad_transitions():
    a = BlockAllocator(4, block_size=8)
    with pytest.raises(RuntimeError):
        a.reserve(0, 5)                    # beyond pool capacity
    a.reserve(0, 4)
    with pytest.raises(AssertionError):
        a.reserve(0, 1)                    # double reservation
    with pytest.raises(RuntimeError):
        a.reserve(1, 1)                    # pool fully committed
    bid = a.allocate(0)
    with pytest.raises(AssertionError):
        a.incref(bid + 1)                  # not a live block
    a.free_all(0)
    with pytest.raises(AssertionError):
        a.decref(bid)                      # double free
    with pytest.raises(AssertionError):
        a.free_all(0)                      # owner already gone
    a.check()
    assert a.num_free == 4


def test_block_allocator_refcount_pins_blocks():
    """incref'd blocks survive their owner's free_all until decref — the
    hook future prefix sharing builds on."""
    a = BlockAllocator(3, block_size=8)
    a.reserve(0, 2)
    b0 = a.allocate(0)
    a.incref(b0)
    a.free_all(0)
    assert b0 in a.refcount and a.num_free == 2   # still pinned
    a.decref(b0)
    a.check()
    assert a.num_free == 3


def test_blocks_for():
    assert blocks_for(1, 8) == 1
    assert blocks_for(8, 8) == 1
    assert blocks_for(9, 8) == 2
    assert blocks_for(48, 16) == 3


# ---------------------------------------------------------------------------
# PagedSlotManager properties (host bookkeeping over a real model cache)
# ---------------------------------------------------------------------------
def test_device_tables_upload_isolated_from_host_mutation():
    """``jnp.asarray`` may zero-copy *alias* a suitably aligned host
    buffer on the CPU backend, and ``tables`` mutates in place for the
    manager's whole life — ``device_tables`` must upload a snapshot.  An
    aliased upload lets asynchronously dispatched scatters read rows as
    mutated after dispatch: the disagg prefill engine releases its donor
    slot (zeroing the row) right after the scatter, which then lands the
    whole prompt in the null block nondeterministically.  The table here
    is sized past numpy's mmap threshold so the allocation is
    page-aligned and the zero-copy path is actually reachable."""
    model, _ = get_model("internlm2-1.8b")
    sm = PagedSlotManager(model, 512, 512, block_size=4, num_blocks=32)
    assert sm.tables.nbytes >= 1 << 18     # large enough for zero-copy
    for _ in range(8):                     # fresh upload per dirty cycle
        slot = sm.assign(0, prompt_len=8, total_budget=12)
        dev = sm.device_tables()
        assert not np.shares_memory(np.asarray(dev), sm.tables), (
            "device tables alias the live host table buffer; the upload "
            "must snapshot (tables.copy()) to stay immutable once "
            "dispatched")
        before = np.asarray(dev).copy()
        assert before[slot, :2].all()      # prompt blocks are mapped
        sm.release(slot)                   # zeroes the host row in place
        assert np.array_equal(np.asarray(dev), before)


def _drive_slot_manager(ops, sm: PagedSlotManager):
    live, rid = [], 0
    for kind, val in ops:
        if kind == 0:                      # admit
            plen = 1 + val % 10
            budget = plen + 1 + val % 12
            if sm.can_admit(budget):
                slot = sm.assign(rid, prompt_len=plen, total_budget=budget)
                live.append((slot, plen, budget))
                rid += 1
        elif kind == 1 and live:           # decode progress -> table growth
            slot, plen, budget = live[val % len(live)]
            sm.ensure(slot, min(plen + val % 8, budget - 1))
        elif kind == 2 and live:           # finish
            slot, _, _ = live.pop(val % len(live))
            sm.release(slot)
        sm.check()
    for slot, _, _ in live:
        sm.release(slot)
    sm.check()
    assert sm.blocks_in_use == 0 and sm.num_free == sm.num_slots


@settings(max_examples=8, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 63)),
                min_size=1, max_size=25))
def test_paged_slot_manager_interleaving(ops):
    m, _ = get_model("internlm2-1.8b")
    _drive_slot_manager(ops, PagedSlotManager(m, 3, MAX_LEN, block_size=8,
                                              num_blocks=10))


@pytest.mark.slow
@settings(max_examples=60, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 2), st.integers(0, 1023)),
                min_size=1, max_size=80),
       st.integers(1, 7),                  # block size
       st.integers(4, 24))                 # pool blocks
def test_paged_slot_manager_interleaving_sweep(ops, bs, nb):
    m, _ = get_model("internlm2-1.8b")
    _drive_slot_manager(ops, PagedSlotManager(m, 4, MAX_LEN, block_size=bs,
                                              num_blocks=nb))


def test_paged_slot_manager_no_seq_leaves_needs_no_blocks():
    """rwkv6 carries pure recurrent state — paged layout degenerates: a
    request reserves zero blocks and admission never gates on the pool."""
    m, _ = get_model("rwkv6-7b")
    sm = PagedSlotManager(m, 2, MAX_LEN, block_size=8, num_blocks=1)
    assert sm.paged_names == ()
    assert sm.blocks_required(MAX_LEN) == 0
    assert sm.can_admit(MAX_LEN)
    slot = sm.assign(0, prompt_len=6, total_budget=MAX_LEN)
    assert sm.blocks_in_use == 0
    sm.release(slot)
    sm.check()


# ---------------------------------------------------------------------------
# Engine: admission gated on blocks, not just slots
# ---------------------------------------------------------------------------
def test_paged_admission_gated_on_block_availability():
    """Pool sized for one request at a time: despite 3 free slots, requests
    are served one-by-one (FIFO), outputs still match the reference, and
    every block returns to the free list."""
    m, params = get_model("internlm2-1.8b")
    # near-max budgets: each request's reservation spans the whole pool
    reqs = make_requests(3, max_new=40)
    need = blocks_for(MAX_LEN, 16)
    eng = Engine(m, params, EngineConfig(
        num_slots=3, max_seq_len=MAX_LEN, temperature=0.0,
        kv_layout="paged", kv_block_size=16, num_kv_blocks=need))
    for r in reqs:
        eng.submit(r)
    outs = eng.run()
    assert eng.stats.peak_active == 1      # blocks, not slots, bound it
    admit_order = [rid for ev, rid, _ in eng.slots.events if ev == "assign"]
    assert admit_order == [0, 1, 2]        # FIFO preserved under gating
    for r, o in zip(reqs, outs):
        ref_t, ref_l = reference(m, params, r, max_new=40)
        assert o.tokens == ref_t
        np.testing.assert_allclose(o.logprobs, ref_l, atol=1e-5)
    eng.slots.check()
    assert eng.slots.blocks_in_use == 0


def test_paged_admits_more_than_contiguous_at_equal_memory():
    """The tentpole's point, in miniature: short-budget requests through a
    pool worth 2 contiguous stripes run >2-wide when paged."""
    m, params = get_model("internlm2-1.8b")
    prompt = np.asarray(tok.encode("5+5=", bos=True), np.int32)
    reqs = [Request(rid=i, prompt=prompt, max_new_tokens=4)
            for i in range(6)]
    stripes = 2
    blocks = stripes * blocks_for(MAX_LEN, 8)
    contig = Engine(m, params, EngineConfig(num_slots=stripes,
                                            max_seq_len=MAX_LEN))
    paged = Engine(m, params, EngineConfig(
        num_slots=6, max_seq_len=MAX_LEN, kv_layout="paged",
        kv_block_size=8, num_kv_blocks=blocks))
    for e in (contig, paged):
        for r in reqs:
            e.submit(Request(rid=r.rid, prompt=r.prompt,
                             max_new_tokens=r.max_new_tokens))
        e.run()
    assert contig.stats.peak_active == stripes
    assert paged.stats.peak_active > contig.stats.peak_active


def test_paged_submit_rejects_request_larger_than_pool():
    m, params = get_model("internlm2-1.8b")
    eng = Engine(m, params, EngineConfig(
        num_slots=2, max_seq_len=MAX_LEN, kv_layout="paged",
        kv_block_size=16, num_kv_blocks=2))      # 32 tokens of KV
    with pytest.raises(ValueError):
        eng.submit(Request(rid=0, prompt=np.zeros(10, np.int32),
                           max_new_tokens=30))   # needs 3 blocks, pool has 2


def test_paged_engine_rwkv6_degenerate_matches_contiguous():
    m, params = get_model("rwkv6-7b")
    reqs = make_requests(3)

    def run(cfg):
        eng = Engine(m, params, cfg)
        for r in reqs:
            eng.submit(r)
        return [o.tokens for o in eng.run()]

    a = run(EngineConfig(num_slots=2, max_seq_len=MAX_LEN))
    b = run(EngineConfig(num_slots=2, max_seq_len=MAX_LEN,
                         kv_layout="paged", kv_block_size=8))
    assert a == b


# ---------------------------------------------------------------------------
# Block-table decode attention kernel vs oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,Hkv,D,bs,lengths", [
    (3, 8, 2, 32, 16, (70, 16, 33)),       # ragged, multi-block
    (2, 4, 4, 64, 8, (1, 57)),             # single live token / long row
])
def test_paged_decode_attention_matches_oracle(B, H, Hkv, D, bs, lengths,
                                               rng_key):
    from repro.kernels import ref
    from repro.kernels.decode_attention import (decode_attention,
                                                paged_decode_attention)
    from repro.models.attention import gather_blocks
    MB = max(blocks_for(n, bs) for n in lengths) + 1
    NB = B * MB + 1                        # pool + null block
    ks = jax.random.split(rng_key, 3)
    q = jax.random.normal(ks[0], (B, H, D))
    k_pool = jax.random.normal(ks[1], (NB, bs, Hkv, D))
    v_pool = jax.random.normal(ks[2], (NB, bs, Hkv, D))
    rng = np.random.default_rng(0)
    perm = rng.permutation(np.arange(1, NB))
    tables = np.zeros((B, MB), np.int32)
    for b in range(B):                     # disjoint tables, zero tails
        nb = blocks_for(lengths[b], bs)
        tables[b, :nb] = perm[b * MB:b * MB + nb]
    lengths = np.asarray(lengths, np.int32)
    out = paged_decode_attention(q, k_pool, v_pool, tables, lengths)
    expect = ref.paged_decode_attention_ref(q, k_pool, v_pool, tables,
                                            lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=5e-5, atol=5e-5)
    # and against the contiguous kernel on the gathered view, row by row
    for b in range(B):
        kb = gather_blocks(k_pool, jnp.asarray(tables[b]), axis=0)[None]
        vb = gather_blocks(v_pool, jnp.asarray(tables[b]), axis=0)[None]
        o2 = decode_attention(q[b:b + 1], kb, vb, int(lengths[b]))
        np.testing.assert_allclose(np.asarray(out)[b], np.asarray(o2)[0],
                                   rtol=5e-5, atol=5e-5)
