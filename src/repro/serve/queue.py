"""FIFO admission queue for the rollout engine.

Requests wait here until a KV-cache slot frees up.  Admission order is
strictly first-in-first-out: the engine always prefills the head of the
queue into the lowest-numbered free slot, so under staggered arrivals no
late request can overtake an earlier one (the fairness property
``tests/test_serve_engine.py`` locks in).
"""
from __future__ import annotations

from collections import deque
from typing import Optional

from repro.serve.request import Request


class RequestQueue:
    """Bounded FIFO of waiting :class:`Request` objects."""

    def __init__(self, max_waiting: Optional[int] = None):
        self._q: deque[Request] = deque()
        self.max_waiting = max_waiting

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def push(self, req: Request) -> None:
        if self.max_waiting is not None and len(self._q) >= self.max_waiting:
            raise RuntimeError(
                f"queue full ({self.max_waiting} waiting); admit slower")
        self._q.append(req)

    def peek(self) -> Request:
        """Head of the queue without removing it (admission-gate check)."""
        return self._q[0]

    def pop(self) -> Request:
        return self._q.popleft()
