"""Admission queue for the rollout engine.

Requests wait here until the admission policy (``repro.serve.sched``)
picks them and a KV-cache slot frees up.  The queue itself stays a plain
arrival-ordered sequence — *which* waiting request is admitted next is the
policy's decision (``FIFOPolicy`` always takes the head, so under FIFO no
late request can overtake an earlier one: the fairness property
``tests/test_serve_engine.py`` locks in).  ``pop_at`` exists so
deadline/SLO policies can skip a blocked head for an admissible, more
urgent request further back.

``push`` is a backpressure signal, not an assertion: when ``max_waiting``
is reached it returns ``False`` and the request is NOT enqueued, so trace
drivers and the coexec loop can defer re-submission instead of crashing
mid-flight.
"""
from __future__ import annotations

from collections import deque
from typing import Iterator, Optional

from repro.serve.request import Request


class RequestQueue:
    """Bounded arrival-ordered queue of waiting :class:`Request` objects."""

    def __init__(self, max_waiting: Optional[int] = None):
        self._q: deque[Request] = deque()
        self.max_waiting = max_waiting
        self.rejected = 0                 # pushes refused for backpressure

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)

    def __iter__(self) -> Iterator[Request]:
        return iter(self._q)

    def __getitem__(self, i: int) -> Request:
        return self._q[i]

    @property
    def full(self) -> bool:
        return (self.max_waiting is not None
                and len(self._q) >= self.max_waiting)

    def push(self, req: Request) -> bool:
        """Enqueue ``req``; ``False`` = queue full (caller should defer and
        retry once the engine drains — nothing was enqueued)."""
        if self.full:
            self.rejected += 1
            return False
        self._q.append(req)
        return True

    def peek(self) -> Request:
        """Head of the queue without removing it (admission-gate check)."""
        return self._q[0]

    def pop(self) -> Request:
        return self._q.popleft()

    def pop_at(self, i: int) -> Request:
        """Remove and return the request at queue position ``i`` (policy
        head skipping; ``pop_at(0)`` is exactly ``pop``)."""
        if i == 0:
            return self._q.popleft()
        self._q.rotate(-i)
        req = self._q.popleft()
        self._q.rotate(i)
        return req
