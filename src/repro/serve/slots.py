"""Slot-based KV-cache manager for continuous batching.

The pool is one stacked decode cache (``models/kvcache.py`` layout, batch
axis = ``num_slots``) whose scalar ``index`` is widened to a per-slot
vector, so every slot advances through its own sequence independently.
Host-side bookkeeping tracks which request owns which slot; device-side,
:func:`insert_cache` (fused into the engine's jitted admit step) writes a
freshly prefilled single-request cache into a slot with one
``dynamic_update_slice`` per leaf (a full-slot overwrite, so recycled
slots can never leak a previous request's KV — and attention additionally
masks positions >= the slot's live ``index``).

Invariants (checked, and locked in by ``tests/test_serve_engine.py``):
  * a slot is owned by at most one live request at a time;
  * ``assign`` only takes free slots, ``release`` only live ones;
  * recycling happens exactly once per finished request (on EOS or budget
    exhaustion), after which the slot is immediately reusable.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def _batch_axis(name: str) -> int:
    """Pool batch axis per cache leaf: ``index`` is (num_slots,), every other
    leaf keeps the kvcache.py layout with batch at axis 1."""
    return 0 if name == "index" else 1


def insert_cache(pool: dict, one: dict, slot) -> dict:
    """Write a batch=1 cache pytree into ``pool`` at batch position ``slot``
    (pure function — the engine fuses it into its jitted admit step)."""
    out = {}
    for name, leaf in pool.items():
        upd = one[name]
        if name == "index":
            out[name] = leaf.at[slot].set(jnp.asarray(upd, leaf.dtype))
        else:
            start = (0, slot) + (0,) * (leaf.ndim - 2)
            out[name] = jax.lax.dynamic_update_slice(
                leaf, upd.astype(leaf.dtype), start)
    return out


class SlotManager:
    """Fixed pool of ``num_slots`` batch slots over one stacked KV cache."""

    def __init__(self, model, num_slots: int, max_seq_len: int):
        self.model = model
        self.num_slots = num_slots
        self.max_seq_len = max_seq_len
        cache = model.init_cache(num_slots, max_seq_len)
        cache["index"] = jnp.zeros((num_slots,), jnp.int32)
        self.cache = cache
        self.owner: list[Optional[int]] = [None] * num_slots  # rid per slot
        self.free: list[int] = list(range(num_slots - 1, -1, -1))  # LIFO, 0 on top
        self.events: list[tuple] = []     # ("assign"|"release", rid, slot)

    # ---- bookkeeping -------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free)

    def assign(self, rid: int) -> int:
        """Claim the lowest-numbered free slot for request ``rid``."""
        if not self.free:
            raise RuntimeError("no free slot")
        slot = self.free.pop()
        if self.owner[slot] is not None:   # invariant: never double-assign
            raise AssertionError(f"slot {slot} already owned by "
                                 f"{self.owner[slot]}")
        self.owner[slot] = rid
        self.events.append(("assign", rid, slot))
        return slot

    def release(self, slot: int) -> None:
        """Recycle a slot whose request finished (EOS or budget)."""
        rid = self.owner[slot]
        if rid is None:                    # invariant: release only live slots
            raise AssertionError(f"slot {slot} is already free")
        self.owner[slot] = None
        self.free.append(slot)
        self.events.append(("release", rid, slot))
