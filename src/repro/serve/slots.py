"""Slot-based KV-cache managers for continuous batching.

Two memory layouts back the same slot abstraction:

**Contiguous** (:class:`SlotManager`) — the pool is one stacked decode
cache (``models/kvcache.py`` layout, batch axis = ``num_slots``) whose
scalar ``index`` is widened to a per-slot vector, so every slot advances
through its own sequence independently; each slot owns a full
``max_seq_len`` sequence stripe.  Host-side bookkeeping tracks which
request owns which slot; device-side, :func:`insert_cache` (fused into the
engine's jitted admit step) writes a freshly prefilled single-request cache
into a slot with one ``dynamic_update_slice`` per leaf (a full-slot
overwrite, so recycled slots can never leak a previous request's KV — and
attention additionally masks positions >= the slot's live ``index``).

**Paged** (:class:`PagedSlotManager`) — ``cache_seq`` leaves live in a
shared pool of ``num_blocks`` fixed-size blocks (``kvcache.
init_paged_cache``); each live slot holds a *block table*, a row of
physical block ids whose concatenation is its logical sequence.  Blocks
are reserved at admit (worst case for the request's total budget, so
on-demand growth can never fail) but materialized lazily as the slot's
``index`` crosses block boundaries (:meth:`PagedSlotManager.ensure`).
Unassigned / recycled table entries point at the null block 0, so a dead
slot's in-flight decode writes land in garbage nothing reads.  Because a
request only commits blocks for *its own* budget rather than a
``max_seq_len`` stripe, heterogeneous long-tail lengths share the pool —
the same KV bytes admit strictly more concurrent requests.

Invariants (checked, and locked in by ``tests/test_serve_engine.py`` /
``tests/test_serve_paged.py``):
  * a slot is owned by at most one live request at a time;
  * ``assign`` only takes free slots, ``release`` only live ones;
  * recycling happens exactly once per finished request (on EOS or budget
    exhaustion), after which the slot is immediately reusable;
  * (paged) live slots' *owned* block-table entries are disjoint — only
    prefix-*shared* entries (``repro.serve.radix``: ref-counted pins on a
    donor's immutable full prompt blocks) may repeat across slots —
    released rows are zeroed, and no block leaks or is double-freed
    across interleavings.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.serve.blocks import BlockAllocator, blocks_for


def _batch_axis(name: str) -> int:
    """Pool batch axis per cache leaf: ``index`` is (num_slots,), every other
    leaf keeps the kvcache.py layout with batch at axis 1."""
    return 0 if name == "index" else 1


def insert_cache(pool: dict, one: dict, slot) -> dict:
    """Write a batch=1 cache pytree into ``pool`` at batch position ``slot``
    (pure function — the engine fuses it into its jitted admit step)."""
    out = {}
    for name, leaf in pool.items():
        upd = one[name]
        if name == "index":
            out[name] = leaf.at[slot].set(jnp.asarray(upd, leaf.dtype))
        else:
            start = (0, slot) + (0,) * (leaf.ndim - 2)
            out[name] = jax.lax.dynamic_update_slice(
                leaf, upd.astype(leaf.dtype), start)
    return out


class SlotManager:
    """Fixed pool of ``num_slots`` batch slots over one stacked KV cache."""

    def __init__(self, model, num_slots: int, max_seq_len: int):
        self.model = model
        self.num_slots = num_slots
        self.max_seq_len = max_seq_len
        cache = model.init_cache(num_slots, max_seq_len)
        cache["index"] = jnp.zeros((num_slots,), jnp.int32)
        self.cache = cache
        self.owner: list[Optional[int]] = [None] * num_slots  # rid per slot
        self.free: list[int] = list(range(num_slots - 1, -1, -1))  # LIFO, 0 on top
        self.events: list[tuple] = []     # ("assign"|"release", rid, slot)

    # ---- bookkeeping -------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free)

    def assign(self, rid: int) -> int:
        """Claim the lowest-numbered free slot for request ``rid``."""
        if not self.free:
            raise RuntimeError("no free slot")
        slot = self.free.pop()
        if self.owner[slot] is not None:   # invariant: never double-assign
            raise AssertionError(f"slot {slot} already owned by "
                                 f"{self.owner[slot]}")
        self.owner[slot] = rid
        self.events.append(("assign", rid, slot))
        return slot

    def release(self, slot: int) -> None:
        """Recycle a slot whose request finished (EOS or budget)."""
        rid = self.owner[slot]
        if rid is None:                    # invariant: release only live slots
            raise AssertionError(f"slot {slot} is already free")
        self.owner[slot] = None
        self.free.append(slot)
        self.events.append(("release", rid, slot))


class PagedSlotManager:
    """Slot pool whose ``cache_seq`` KV lives in shared fixed-size blocks.

    Slot bookkeeping (``assign``/``release``/``owner``/``events``) mirrors
    :class:`SlotManager`; on top of it each live slot carries a block table
    row and a :class:`~repro.serve.blocks.BlockAllocator` reservation sized
    for its request's total budget.  ``num_blocks`` defaults to the
    contiguous pool's footprint (``num_slots`` full stripes), in which case
    admission never gates on blocks — shrink it (or raise ``num_slots``)
    to share memory across heterogeneous lengths.
    """

    def __init__(self, model, num_slots: int, max_seq_len: int, *,
                 block_size: int = 16, num_blocks: Optional[int] = None,
                 kv_dtype: Optional[str] = None):
        self.model = model
        self.num_slots = num_slots
        self.max_seq_len = max_seq_len
        self.block_size = block_size
        self.kv_dtype = kv_dtype
        self.max_blocks = blocks_for(max_seq_len, block_size)  # per slot
        if num_blocks is None:
            num_blocks = num_slots * self.max_blocks
        self.paged_names = model.paged_cache_names()
        self.alloc = BlockAllocator(num_blocks, block_size)
        self.cache = model.init_paged_cache(
            num_slots, max_seq_len, block_size=block_size,
            num_blocks=num_blocks, kv_dtype=kv_dtype)
        self.owner: list[Optional[int]] = [None] * num_slots
        self.free: list[int] = list(range(num_slots - 1, -1, -1))
        self.events: list[tuple] = []
        self.tables = np.zeros((num_slots, self.max_blocks), np.int32)
        self.nblocks = [0] * num_slots     # materialized blocks per slot
        # slot -> leading table entries pinned via prefix sharing (each
        # incref'd on behalf of this slot; decref'd on release)
        self.shared: dict[int, list[int]] = {}
        self._tables_dev = jnp.asarray(self.tables.copy())
        self._dirty = False

    # ---- bookkeeping -------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free)

    @property
    def blocks_in_use(self) -> int:
        return self.alloc.num_live

    def blocks_required(self, total_budget: int) -> int:
        """Worst-case blocks a request with this prompt+decode budget can
        write (0 for families with no ``cache_seq`` leaves, e.g. rwkv6)."""
        if not self.paged_names:
            return 0
        return blocks_for(min(total_budget, self.max_seq_len),
                          self.block_size)

    def can_admit(self, total_budget: int, *, shared_blocks: int = 0) -> bool:
        """Admission gate: a free slot and enough *uncommitted* pool for the
        request's net-new blocks (worst-case budget minus the prompt-prefix
        blocks prefix sharing pins instead of allocating)."""
        need = max(self.blocks_required(total_budget) - shared_blocks, 0)
        return bool(self.free) and self.alloc.can_reserve(need)

    def assign(self, rid: int, *, prompt_len: int, total_budget: int) -> int:
        """Claim a slot + block reservation; materialize the prompt's blocks."""
        if not self.free:
            raise RuntimeError("no free slot")
        slot = self.free.pop()
        if self.owner[slot] is not None:
            raise AssertionError(f"slot {slot} already owned by "
                                 f"{self.owner[slot]}")
        self.alloc.reserve(rid, self.blocks_required(total_budget))
        self.owner[slot] = rid
        self.events.append(("assign", rid, slot))
        if self.paged_names and prompt_len:
            self.ensure(slot, prompt_len - 1)
        return slot

    def assign_shared(self, rid: int, *, prompt_len: int, total_budget: int,
                      shared_ids: list[int]) -> int:
        """Claim a slot whose leading table entries are *shared* prompt-prefix
        blocks (radix hit): each shared block is incref'd under this slot
        (pinned — it outlives any co-owner), only the net-new remainder of
        the worst-case budget is reserved, and the prompt's own tail block
        (copy-on-write at the first divergent block) plus decode growth
        materialize from that reservation via :meth:`ensure` as usual."""
        if not self.free:
            raise RuntimeError("no free slot")
        if len(shared_ids) > self.blocks_required(total_budget):
            raise AssertionError("shared prefix longer than the budget")
        slot = self.free.pop()
        if self.owner[slot] is not None:
            raise AssertionError(f"slot {slot} already owned by "
                                 f"{self.owner[slot]}")
        net_new = self.blocks_required(total_budget) - len(shared_ids)
        self.alloc.reserve(rid, net_new)
        for bid in shared_ids:
            self.alloc.incref(bid)
        self.owner[slot] = rid
        if shared_ids:
            self.shared[slot] = list(shared_ids)
            self.tables[slot, :len(shared_ids)] = shared_ids
            self.nblocks[slot] = len(shared_ids)
            self._dirty = True
        self.events.append(("assign", rid, slot))
        if self.paged_names and prompt_len:
            self.ensure(slot, prompt_len - 1)
        return slot

    def ensure(self, slot: int, upto_pos: int) -> None:
        """Materialize blocks so the slot's table covers sequence positions
        ``<= upto_pos``, clamped to the request's quota (writes past the
        budget fall through to the null block by design)."""
        if not self.paged_names:
            return
        rid = self.owner[slot]
        if rid is None:
            raise AssertionError(f"ensure on free slot {slot}")
        want = min(upto_pos // self.block_size + 1, self.max_blocks)
        while self.nblocks[slot] < want and self.alloc.quota.get(rid, 0) > 0:
            bid = self.alloc.allocate(rid)
            self.tables[slot, self.nblocks[slot]] = bid
            self.nblocks[slot] += 1
            self._dirty = True

    def pin_prefix(self, slot: int, n: int) -> list[int]:
        """Incref the slot's first ``n`` table entries — full blocks a
        decode step can never write again, whether prompt prefill or
        mid-generation KV — on behalf of an external pin holder (a KV
        transfer handle or a suspended request, mirroring the radix
        index's own pins) and return their ids.  The pins survive
        :meth:`release` of the slot: the blocks stay resident, un-copied,
        until the holder decrefs them."""
        rid = self.owner[slot]
        if rid is None:
            raise AssertionError(f"pin_prefix on free slot {slot}")
        if n > self.nblocks[slot]:
            raise AssertionError(
                f"pin_prefix: {n} blocks requested but slot {slot} has "
                f"only {self.nblocks[slot]} materialized")
        ids = [int(b) for b in self.tables[slot, :n]]
        for bid in ids:
            self.alloc.incref(bid)
        return ids

    def release(self, slot: int) -> None:
        """Recycle a finished slot: free its blocks (unpin shared ones),
        zero its table row."""
        rid = self.owner[slot]
        if rid is None:
            raise AssertionError(f"slot {slot} is already free")
        for bid in self.shared.pop(slot, []):
            self.alloc.decref(bid)         # unpin; co-owners keep it alive
        self.alloc.free_all(rid)
        self.tables[slot, :] = 0           # dead slot writes -> null block
        self.nblocks[slot] = 0
        self._dirty = True
        self.owner[slot] = None
        self.free.append(slot)
        self.events.append(("release", rid, slot))

    def device_tables(self) -> jax.Array:
        """Device copy of the block tables (re-uploaded only when changed).

        The upload snapshots ``self.tables`` (note the ``.copy()``):
        ``jnp.asarray`` may zero-copy *alias* a suitably aligned host
        buffer on the CPU backend, and ``tables`` keeps mutating in place
        — an aliased upload would let an asynchronously dispatched
        scatter/gather read rows as mutated *after* dispatch (e.g. the
        prefill donor row zeroed by its immediate slot release), turning
        prompt writes into null-block writes nondeterministically."""
        if self._dirty:
            self._tables_dev = jnp.asarray(self.tables.copy())
            self._dirty = False
        return self._tables_dev

    def check(self, extra_pins=()) -> None:
        """Cross-structure invariants (used by the property tests).

        ``extra_pins``: block ids held live by pins outside this manager —
        the radix prefix index's own increfs — so the liveness accounting
        stays exact when sharing is on.  A slot's *owned* (non-shared)
        entries must still be disjoint across slots; *shared* entries may
        legitimately repeat across slots and in ``extra_pins``."""
        self.alloc.check()
        owned_flat, shared_flat = [], []
        for s in range(self.num_slots):
            if self.owner[s] is None:
                assert not self.tables[s].any(), "released row not zeroed"
                assert s not in self.shared
                continue
            ns = len(self.shared.get(s, ()))
            row = self.tables[s]
            assert not row[self.nblocks[s]:].any()
            assert [int(b) for b in row[:ns]] == self.shared.get(s, []), \
                "shared prefix out of sync with table row"
            owned_flat += [int(b) for b in row[ns:self.nblocks[s]]]
            shared_flat += [int(b) for b in row[:ns]]
        flat = owned_flat + shared_flat
        assert 0 not in flat, "live table row points at the null block"
        # owned entries are uniquely allocated; shared entries may repeat
        # across slots AND coincide with the donor's still-owned entries
        assert len(set(owned_flat)) == len(owned_flat), \
            "owned block shared across slots"
        live = set(flat) | set(extra_pins)
        assert live == set(self.alloc.refcount), \
            "materialized blocks out of sync with tables/pins"
        for bid in shared_flat + list(extra_pins):
            assert self.alloc.refcount.get(bid, 0) >= 1
