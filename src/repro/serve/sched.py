"""Pluggable admission policies for the serving engine.

The engine's scheduler loop asks its policy one question per admission
attempt: *given the waiting queue and an admissibility oracle, which
request (by queue position) goes into the next free slot?*  Everything
else — slot assignment, prefill, block accounting — stays in the engine,
so policies are pure host-side decision logic and trivially unit-testable.

Three policies ship (``launch/serve.py --sched``, ``launch/train.py
--sched``):

* :class:`FIFOPolicy` — strict arrival order, the PR 3 behaviour: the head
  is admitted iff it fits, and is never skipped.  Greedy engine output is
  the baseline every other policy must match token-for-token (admission
  order can change *when* a request decodes, never *what* it decodes —
  per-slot decode is independent).
* :class:`DeadlinePolicy` — earliest-deadline-first with **bounded head
  skipping** and **per-job token budgets**.  When the EDF head does not
  fit (no slot / not enough KV blocks / job over budget) a later
  admissible request may overtake it, but each waiting request may be
  overtaken by *newer* arrivals at most ``max_skips`` times: after that it
  becomes a barrier — no younger request is admitted before it — so its
  remaining wait is bounded by the drain time of requests already ahead
  of it (the no-starvation property ``tests/test_serve_sched.py`` sweeps).
  ``token_budgets`` caps each job's in-flight decode tokens so one job's
  burst cannot monopolise the slot pool of a co-executed engine.
* :class:`SLOPolicy` — the deadline policy fed by the **inter-group SLO
  contract**: requests without an explicit deadline get one derived from
  the co-execution group's admitted slowdown bound
  (``CoExecutionGroup.slowdown_bound`` / ``InterGroupScheduler.
  slo_contract``): ``arrival + slowdown * est_solo_latency`` where the
  solo-latency estimate is the request's decode budget times a per-token
  service-time estimate.  The engine thereby *enforces* per-request what
  the planner *promised* per-job: co-executed rollout traffic stays
  inside its slowdown bound under contention.
"""
from __future__ import annotations

import math
from typing import Callable, Mapping, Optional, Sequence

from repro.serve.request import Request

_INF = math.inf


class SchedulerPolicy:
    """Admission-decision interface (host-side, stateful per engine).

    ``pick`` returns the queue position of the next request to admit, or
    ``None`` when nothing admissible should be admitted right now.  It is
    called repeatedly within one scheduler tick (the engine loops until it
    returns ``None``), with ``live_tokens`` reflecting admissions already
    made this tick.
    """

    name = "base"

    def pick(self, waiting: Sequence[Request],
             can_admit: Callable[[Request], bool], *,
             now: float = 0.0,
             live_tokens: Optional[Mapping[str, int]] = None
             ) -> Optional[int]:
        raise NotImplementedError

    def observe_finish(self, out) -> None:
        """Optional hook: a request finished (SLO policies fall back to
        refining their service-time estimate from it when no step
        measurements have been seen)."""

    def observe_step(self, service_s: float, tokens: int) -> None:
        """Optional hook the engine calls after every decode dispatch:
        ``tokens`` decode steps (one token per live slot each) took
        ``service_s`` of wall time, measured around the device call.  SLO
        policies feed this straight into their per-token estimate — the
        engine's own ``step()`` accounting, not a finish-time heuristic."""

    def on_reset(self) -> None:
        """Optional hook ``Engine.reset`` calls between request batches.
        Policies drop *per-request* bookkeeping here (rids repeat across
        GRPO iterations on a persistent engine) but must keep measured
        *hardware* state: the engine keeps its jit cache across resets, so
        anything calibrated against compilation — the SLO policy's
        first-sample discard — must not re-trigger."""

    def on_backend_change(self) -> None:
        """Optional hook ``Engine.set_kernel_backend`` calls when the decode
        kernel backend flips on an (idle) engine.  Unlike ``on_reset``,
        measured *hardware* state is exactly what is now stale: per-token
        service times learned against one backend's kernels say nothing
        about the other's, and the new backend's first step re-compiles."""


class FIFOPolicy(SchedulerPolicy):
    """Strict arrival order; the head is never skipped (PR 3 semantics)."""

    name = "fifo"

    def pick(self, waiting, can_admit, *, now=0.0, live_tokens=None):
        if waiting and can_admit(waiting[0]):
            return 0
        return None


class DeadlinePolicy(SchedulerPolicy):
    """EDF admission with bounded head skipping and per-job token budgets.

    Ordering key: ``(expired?, deadline (None = +inf), -priority, arrival
    seq)`` — already-expired requests are served best-effort *last* (EDF
    under overload would otherwise spend every slot on doomed work, since
    missed deadlines sort earliest).  A request whose admission is refused
    while a *newer* request is admitted counts one skip; at ``max_skips``
    it becomes a barrier (only requests that arrived before it may still
    be admitted), which bounds every request's wait — see the module
    docstring.
    """

    name = "deadline"

    def __init__(self, *, max_skips: int = 4,
                 token_budgets: Optional[Mapping[str, int]] = None):
        if max_skips < 0:
            raise ValueError("max_skips must be >= 0")
        self.max_skips = max_skips
        self.token_budgets = dict(token_budgets or {})
        self._seq: dict[int, int] = {}      # rid -> arrival sequence number
        self._skips: dict[int, int] = {}    # rid -> times overtaken by newer
        self._owner: dict[int, int] = {}    # rid -> queue identity
        self._next_seq = 0

    # -- bookkeeping --------------------------------------------------------
    def _note(self, waiting: Sequence[Request]) -> None:
        # One policy object may drive several queues (the disagg router
        # shares it across all prefill engines so per-job budgets and the
        # SLO service-time estimate are global).  Rids are pruned per
        # *queue* — keyed on the queue object's identity — so a pick on
        # engine A never drops the arrival seqs / skip counts of requests
        # still waiting on engine B.
        qid = id(waiting)
        for r in waiting:
            if r.rid not in self._seq:
                self._seq[r.rid] = self._next_seq
                self._next_seq += 1
            self._owner[r.rid] = qid
        live = {r.rid for r in waiting}
        for rid in [rid for rid, owner in self._owner.items()
                    if owner == qid and rid not in live]:
            self._seq.pop(rid, None)
            self._skips.pop(rid, None)
            self._owner.pop(rid, None)

    def effective_deadline(self, req: Request, now: float) -> float:
        return _INF if req.deadline is None else req.deadline

    def _within_budget(self, req: Request,
                       live_tokens: Mapping[str, int]) -> bool:
        if req.job_id is None or req.job_id not in self.token_budgets:
            return True
        budget = self.token_budgets[req.job_id]
        return live_tokens.get(req.job_id, 0) + req.max_new_tokens <= budget

    # -- decision -----------------------------------------------------------
    def pick(self, waiting, can_admit, *, now=0.0, live_tokens=None):
        if not waiting:
            return None
        live_tokens = live_tokens or {}
        self._note(waiting)

        def key(i):
            r = waiting[i]
            dl = self.effective_deadline(r, now)
            # EDF is only optimal while the queue is feasible: under
            # overload, already-expired requests carry the *earliest*
            # deadlines and would hog every slot while still-feasible work
            # misses too.  Expired requests are served, but last
            # (best-effort), which keeps attainment from collapsing.
            # EXCEPT once a request has hit max_skips: demoting a starving
            # request for being expired would re-open the starvation window
            # the barrier exists to close — it blocks younger work (below)
            # yet would itself wait behind *all* other work, wedging the
            # queue under expired-heavy overload.  A starving request keeps
            # its EDF position regardless of expiry.
            starving = self._skips.get(r.rid, 0) >= self.max_skips
            return (dl < now and not starving, dl, -r.priority,
                    self._seq[r.rid])

        order = sorted(range(len(waiting)), key=key)
        # starvation barrier: once any request has been overtaken max_skips
        # times, only requests at least as old as the oldest such request
        # may still be admitted (its wait is then bounded by the drain of
        # already-admitted + strictly-older work).
        blocked = [self._seq[r.rid] for r in waiting
                   if self._skips.get(r.rid, 0) >= self.max_skips]
        barrier = min(blocked) if blocked else None
        for i in order:
            req = waiting[i]
            if barrier is not None and self._seq[req.rid] > barrier:
                continue
            if not self._within_budget(req, live_tokens):
                continue
            if not can_admit(req):
                continue
            chosen_seq = self._seq[req.rid]
            for r in waiting:
                if r.rid != req.rid and self._seq[r.rid] < chosen_seq:
                    self._skips[r.rid] = self._skips.get(r.rid, 0) + 1
            return i
        return None

    def on_reset(self) -> None:
        """Drop per-request state between batches.  ``_note`` prunes rids
        that leave the queue, but on a persistent engine the *last* batch's
        rids repeat in the next one (GRPO rows are always 0..B-1): a stale
        entry would hand a fresh request an ancient arrival seq — and any
        stale skip count could make it an instant barrier."""
        self._seq.clear()
        self._skips.clear()
        self._owner.clear()


class SLOPolicy(DeadlinePolicy):
    """Deadline admission driven by the co-execution group's SLO contract.

    ``slowdown`` is the admitted slowdown bound exported by the inter-group
    scheduler (``InterGroupScheduler.slo_contract()[job_id]`` — worst-case
    iteration time at most ``slowdown`` x solo).  A request without an
    explicit deadline gets ``arrival + slowdown * est_solo_latency``, with
    ``est_solo_latency = time_per_token * max_new_tokens`` (decode
    dominates rollout serving).

    The per-token estimate comes from the engine's own ``step()``
    accounting: every decode dispatch reports its measured service time
    via :meth:`observe_step` and the estimate tracks it directly (light
    EMA to smooth scheduler-tick jitter; the first sample — which carries
    jit compilation — only seeds it).  ``observe_finish`` remains as a
    fallback for drivers that never run a real engine (policy unit tests,
    simulators): it refines from finished requests, but only until the
    first step measurement arrives — engine-measured service time always
    wins over the finish-interval heuristic.
    """

    name = "slo"

    def __init__(self, *, slowdown: float = 2.0,
                 time_per_token: float = 0.05, ema: float = 0.2,
                 max_skips: int = 4,
                 token_budgets: Optional[Mapping[str, int]] = None):
        super().__init__(max_skips=max_skips, token_budgets=token_budgets)
        if slowdown < 1.0:
            raise ValueError("slowdown bound must be >= 1 (x solo latency)")
        self.slowdown = slowdown
        self.time_per_token = time_per_token
        self._initial_time_per_token = time_per_token
        self.ema = ema
        self._step_samples = 0      # engine step() measurements consumed

    @classmethod
    def from_contract(cls, contract: Mapping[str, float], job_id: str,
                      **kw) -> "SLOPolicy":
        """Build the policy a job's engine enforces from the inter-group
        scheduler's exported contract (``slo_contract()``)."""
        return cls(slowdown=contract[job_id], **kw)

    def effective_deadline(self, req: Request, now: float) -> float:
        if req.deadline is not None:
            return req.deadline
        est_solo = self.time_per_token * req.max_new_tokens
        return req.arrival_time + self.slowdown * est_solo

    def on_reset(self) -> None:
        # Per-request bookkeeping goes (rids repeat across batches); the
        # measured service-time state — ``time_per_token`` and the
        # ``_step_samples`` counter — stays.  ``Engine.reset`` keeps the
        # jit cache, so the next batch's first decode step is NOT
        # compile-contaminated: re-triggering the first-sample discard
        # would throw away a clean measurement and leave low-sample
        # estimates skewed toward whatever the previous batch ended on.
        super().on_reset()

    def on_backend_change(self) -> None:
        # The learned per-token estimate was measured against the *old*
        # backend's kernels; carrying it across the flip would admit (or
        # reject) against fiction.  Fall back to the configured prior and
        # re-arm the first-sample discard: the new backend's first decode
        # step pays a fresh jit compile.
        self.time_per_token = self._initial_time_per_token
        self._step_samples = 0

    def observe_step(self, service_s: float, tokens: int) -> None:
        # The engine's own decode accounting: ``tokens`` decode steps took
        # ``service_s`` measured around the device dispatch + host sync.
        # The very first sample per engine shape carries jit compilation
        # and is discarded; the next one initializes the estimate directly
        # and later samples converge fast (EMA over steps, not finishes —
        # every tick contributes, so the estimate tracks load changes
        # within one batch of requests).
        # ``tokens < 1`` guards the zero-decode-steps path (a tick that
        # admitted but ran no decode): dividing by it would poison the
        # estimate with inf/NaN, which every later EMA step inherits.
        if tokens < 1 or service_s < 0:
            return
        self._step_samples += 1
        if self._step_samples == 1:
            return                      # compile-contaminated; discard
        per_tok = service_s / tokens
        if self._step_samples == 2:
            self.time_per_token = per_tok
        else:
            a = max(self.ema, 0.3)      # steps are plentiful; track fast
            self.time_per_token = ((1 - a) * self.time_per_token
                                   + a * per_tok)

    def observe_finish(self, out) -> None:
        # Fallback only: once the engine has consumed a real step()
        # measurement (sample 2+ — sample 1 is discarded as compile
        # noise, so it must not retire the fallback alone), the
        # finish-interval heuristic is dropped — it under-measures
        # whenever a request's budget fits one fused decode block and it
        # never sees prefill-era service time at all.
        if self._step_samples > 1:
            return
        # Refine from *service* time (first token -> finish), never total
        # latency: latency includes queueing delay, and folding that into
        # the estimate would loosen deadlines exactly under the contention
        # the contract is supposed to bound.  Requests whose whole budget
        # fits one fused decode block land with finish == first_token
        # (zero observable service interval) and are skipped.
        if out.num_tokens >= 2 and out.finish_time > out.first_token_time > 0:
            per_tok = ((out.finish_time - out.first_token_time)
                       / (out.num_tokens - 1))
            self.time_per_token = ((1 - self.ema) * self.time_per_token
                                   + self.ema * per_tok)


def make_policy(name: str, **kwargs) -> SchedulerPolicy:
    """Policy factory behind the ``--sched fifo|deadline|slo`` flags."""
    policies = {"fifo": FIFOPolicy, "deadline": DeadlinePolicy,
                "slo": SLOPolicy}
    if name not in policies:
        raise ValueError(f"unknown scheduler policy {name!r} "
                         f"(choose from {sorted(policies)})")
    return policies[name](**kwargs)
