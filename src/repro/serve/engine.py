"""Continuous-batching rollout engine (in-flight batching over a slot pool).

The engine services generation requests the way a rollout pool must under
heavy traffic: a :class:`~repro.serve.queue.RequestQueue` feeds a fixed
pool of KV-cache slots (:class:`~repro.serve.slots.SlotManager`) in the
order a pluggable admission policy picks (:mod:`repro.serve.sched`:
``fifo`` strict arrival order, ``deadline`` EDF with bounded head
skipping and per-job token budgets, ``slo`` deadlines derived from the
inter-group SLO contract); each scheduler iteration first *prefills*
picked requests into free slots, then runs one (or ``block_size`` fused)
*decode* step(s) for every live slot at once.  Requests therefore join
and leave the decode batch mid-flight: a slot is recycled the moment its
request hits EOS or its per-request decode budget, and the next queued
request prefills into it — no static-batch barrier, no head-of-line
blocking on long generations.

Per-slot sequence positions are independent (the pool cache carries a
per-slot ``index`` vector); decode is the model's own single-token step
``vmap``-ped over slots, so engine output is mathematically the per-request
``rl.rollout.generate`` computation, token for token and logprob for
logprob (the equivalence ``tests/test_serve_engine.py`` asserts).

``block_size > 1`` fuses K decode steps into one jitted ``lax.scan`` to
amortise per-step dispatch (scheduling decisions then happen every K
tokens); ``block_size=1`` is exact per-token continuous batching.

Two KV layouts (``EngineConfig.kv_layout``): **contiguous** gives every
slot a full ``max_seq_len`` stripe; **paged** stores ``cache_seq`` leaves
in a shared pool of ``kv_block_size``-token blocks
(:class:`~repro.serve.slots.PagedSlotManager`).  Paged admission gates on
*block* availability as well as slots (a request reserves only what its
own budget can touch), block tables grow on demand as ``index`` crosses
block boundaries, and decode runs the same model step over a gathered
per-slot view of the block table — a pure permutation-copy, so paged
output is token/logprob-identical to contiguous (locked in by
``tests/test_serve_paged.py``).

``EngineConfig.prefix_share`` (paged only) adds radix prompt-prefix KV
sharing (:mod:`repro.serve.radix`): a content-addressed radix tree over
full token blocks, so *any* two requests agreeing on a block-aligned
token prefix — GRPO's ``group``-way duplicated prompts, a shared system
preamble across tenants, or a multi-turn episode replaying its own
history — share exactly those blocks, no tag required
(``prefix_key`` is now just an optional isolation namespace).  An exact
repeat of a registered prompt admits with zero model compute from the
boundary snapshot; partial overlaps pin the matching full blocks
(ref-counted, several slot owners per block) and prefill into a
write-masked row plus a private copy-on-write tail.  Admission then
gates on *net new* blocks, which is where the extra concurrency at
equal KV memory comes from.  Output stays bit-identical to the unshared
engine
(the shared blocks hold exactly the donor's prefill, and gathers are
permutation-copies).

Compilation notes: jitted prefill / admit / decode-block functions are
cached per (model, max_seq_len, temperature, eos_id) — engines with the
same serving shape share compilations (cheap to construct per trace), and
prefill additionally specialises on prompt length, so drivers should
bucket prompt lengths (the benchmark uses a handful of buckets).
"""
from __future__ import annotations

import copy
import functools
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.telemetry import MetricsSnapshot, warn_legacy_once
from repro.data import tokenizer as tok
from repro.models.attention import gather_blocks
from repro.serve.blocks import blocks_for
from repro.serve.queue import RequestQueue
from repro.serve.radix import RadixPrefixIndex
from repro.serve.request import Request, RequestOutput
from repro.serve.sched import make_policy
from repro.serve.slots import (PagedSlotManager, SlotManager, _batch_axis,
                               insert_cache)


@dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 8
    max_seq_len: int = 256
    eos_id: int = tok.EOS
    temperature: float = 0.0          # 0 => greedy
    block_size: int = 1               # decode steps fused per scheduler tick
    max_waiting: Optional[int] = None
    kv_layout: str = "contiguous"     # "contiguous" | "paged"
    kv_block_size: int = 16           # tokens per KV block (paged only)
    num_kv_blocks: Optional[int] = None   # paged pool size (default: same
                                          # memory as contiguous num_slots)
    sched: str = "fifo"               # admission policy (serve.sched):
                                      # "fifo" | "deadline" | "slo" — or pass
                                      # a policy object to Engine(policy=...)
    prefix_share: bool = False        # radix prompt-prefix KV sharing
                                      # (paged layout only)
    kernel_backend: str = "jnp"       # decode-step backend: "jnp" (vmapped
                                      # model step) | "pallas" (batched
                                      # decode-attention kernels + fused
                                      # sampling epilogue)
    kv_dtype: Optional[str] = None    # paged KV storage: None/"auto" keeps
                                      # the model dtype, "int8" quantizes
                                      # blocks with per-position scales

    def __post_init__(self):
        if self.num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        if self.max_seq_len < 2:
            raise ValueError("max_seq_len must cover prompt + decode")
        if self.kv_layout not in ("contiguous", "paged"):
            raise ValueError(f"unknown kv_layout {self.kv_layout!r}")
        if self.kv_block_size < 1:
            raise ValueError("kv_block_size must be >= 1")
        if self.sched not in ("fifo", "deadline", "slo"):
            raise ValueError(f"unknown sched policy {self.sched!r}")
        if self.prefix_share and self.kv_layout != "paged":
            raise ValueError("prefix_share requires kv_layout='paged' "
                             "(sharing is block-granular)")
        if self.kernel_backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown kernel_backend "
                             f"{self.kernel_backend!r}")
        if self.kv_dtype not in (None, "auto", "int8"):
            raise ValueError(f"unknown kv_dtype {self.kv_dtype!r}")
        if self.kv_dtype == "int8" and self.kv_layout != "paged":
            raise ValueError("kv_dtype='int8' requires kv_layout='paged' "
                             "(quantization is per KV block)")


# Engine.stats legacy-shim warn-once flag (mutable so tests can reset it;
# same pattern as rl.rollout's RolloutSpec kwargs migration shim).
_warned_legacy = [False]


@dataclass
class EngineStats:
    steps: int = 0                    # decode steps executed (all slots)
    blocks: int = 0                   # scheduler ticks that ran a decode
    prefills: int = 0
    recorded_tokens: int = 0          # useful (mask=1) tokens produced
    slot_steps: int = 0               # num_slots * steps (capacity offered)
    peak_active: int = 0              # max concurrently live requests
    peak_kv_blocks: int = 0           # max KV blocks in use (paged only)
    prefix_hits: int = 0              # admits that skipped prefill entirely
    prefix_partial_hits: int = 0      # admits that shared blocks but prefilled
    blocks_saved: int = 0             # KV blocks pinned instead of allocated
    decode_time_s: float = 0.0        # wall time inside decode dispatch+sync
    adoptions: int = 0                # admits fed by a KV transfer handle
    #                                   (disaggregated prefill, serve.disagg)
    suspends: int = 0                 # requests suspended (tool boundary or
    #                                   carry_live weight sync)
    resumes: int = 0                  # suspended requests re-admitted

    @property
    def slot_utilization(self) -> float:
        return self.recorded_tokens / max(self.slot_steps, 1)

    @property
    def time_per_token(self) -> float:
        """Measured service time of one decode step (all live slots decode
        one token each in that time) — the engine-side estimate SLO
        admission consumes (``SchedulerPolicy.observe_step``)."""
        return self.decode_time_s / max(self.steps, 1)


def _make_sampler(temperature: float, kernel_backend: str, interpret: bool):
    """(logits, key) -> (next_token (N,), token_logprob (N,)).

    The pallas backend fuses the greedy argmax + logprob epilogue into one
    kernel pass over the vocabulary (``kernels.sampling.greedy_sample``);
    sampled decoding keeps ``jax.random.categorical`` (the draw itself
    needs the full distribution either way)."""
    def sample_logp(logits, key):
        if temperature == 0:
            if kernel_backend == "pallas":
                from repro.kernels.sampling import greedy_sample
                return greedy_sample(logits, interpret=interpret)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        else:
            nxt = jax.random.categorical(
                key, logits / temperature, axis=-1).astype(jnp.int32)
        logp = jax.nn.log_softmax(logits, -1)
        return nxt, jnp.take_along_axis(logp, nxt[:, None], -1)[:, 0]
    return sample_logp


@functools.lru_cache(maxsize=32)
def _engine_fns(model, max_seq_len: int, temperature: float, eos_id: int,
                kernel_backend: str = "jnp", interpret: bool = True):
    """Jitted prefill / admit / decode-block shared by all engines with the
    same serving shape (keyed on the hashable frozen ``Model``).

    ``kernel_backend="pallas"`` swaps the decode block's vmapped model step
    for the batched Pallas path (``Model.kernel_decode_step``: one call per
    step over the whole slot pool, decode attention in a kernel) plus the
    fused greedy sampling epilogue; admission/prefill stay shared."""

    def prefill_fn(params, prompt, frontend):
        cache = model.init_cache(1, max_seq_len)
        logits, cache = model.prefill(params, prompt, cache,
                                      frontend=frontend)
        return logits[0], cache

    def scatter_fn(logits, one, pool, slot, last_logits, alive, remaining,
                   budget):
        """Splice a prefilled batch=1 cache into slot ``slot`` plus the
        logits/alive/budget row updates — the insert half of ``admit``,
        standalone so a disaggregated prefill result (KV transfer handle)
        can be adopted without re-running the model."""
        return (insert_cache(pool, one, slot),
                last_logits.at[slot].set(logits),
                alive.at[slot].set(True),
                remaining.at[slot].set(budget))

    def admit_fn(params, prompt, frontend, pool, slot, last_logits, alive,
                 remaining, budget):
        """Prefill one request and splice it into slot ``slot`` — a single
        dispatch covering cache insert + logits/alive/budget row updates."""
        logits, one = prefill_fn(params, prompt, frontend)
        return scatter_fn(logits, one, pool, slot, last_logits, alive,
                          remaining, budget)

    cache_axes = {k: _batch_axis(k) for k in model.cache_logical_specs()}

    def decode_one(params, token, cache):
        # re-grow the batch=1 axis the vmap stripped, run the model's own
        # decode step, then strip it again for out_axes
        cache_b = {k: (v if k == "index" else v[:, None])
                   for k, v in cache.items()}
        logits, cache_b = model.decode_step(
            params, jnp.reshape(token, (1, 1)), cache_b)
        cache_o = {k: (v if k == "index" else v[:, 0])
                   for k, v in cache_b.items()}
        return logits[0], cache_o

    pool_decode = jax.vmap(decode_one, in_axes=(None, 0, cache_axes),
                           out_axes=(0, cache_axes))
    sample_logp = _make_sampler(temperature, kernel_backend, interpret)

    def block_fn(params, last_logits, cache, alive, remaining, keys):
        def step(carry, key):
            logits, cache, alive, remaining = carry
            nxt, tok_logp = sample_logp(logits, key)        # (N,), (N,)
            rec = alive & (remaining > 0)
            if kernel_backend == "pallas":
                logits, cache = model.kernel_decode_step(
                    params, nxt[:, None], cache, interpret=interpret)
            else:
                logits, cache = pool_decode(params, nxt, cache)
            alive = alive & (nxt != eos_id)
            remaining = remaining - rec.astype(jnp.int32)
            return (logits, cache, alive, remaining), (nxt, tok_logp, rec)

        carry, out = jax.lax.scan(
            step, (last_logits, cache, alive, remaining), keys)
        return carry, out                   # out: (toks, logps, recs) (K,N)

    def extract_fn(pool, slot):
        """Gather slot ``slot``'s full cache stripe into a batch=1 pytree —
        the inverse of ``insert_cache`` (suspension capture)."""
        out = {}
        for name, leaf in pool.items():
            if name == "index":
                out[name] = leaf[slot]
            else:
                start = (0, slot) + (0,) * (leaf.ndim - 2)
                sizes = (leaf.shape[0], 1) + leaf.shape[2:]
                out[name] = jax.lax.dynamic_slice(leaf, start, sizes)
        return out

    def inject_fn(params, tokens, one):
        """Advance a batch=1 cache view through forced tokens (a tool
        result) with the model's own decode step; the returned logits
        predict the first post-injection token.  Specialises on the token
        count, like prefill does on prompt length."""
        def step(one, t):
            logits, one = model.decode_step(
                params, jnp.reshape(t, (1, 1)), one)
            return one, logits
        one, logits = jax.lax.scan(step, one, tokens)
        return logits[-1, 0], one

    return {"admit": jax.jit(admit_fn), "block": jax.jit(block_fn),
            "prefill": jax.jit(prefill_fn), "scatter": jax.jit(scatter_fn),
            "extract": jax.jit(extract_fn), "inject": jax.jit(inject_fn)}


@functools.lru_cache(maxsize=32)
def _paged_engine_fns(model, max_seq_len: int, kv_block_size: int,
                      temperature: float, eos_id: int,
                      kernel_backend: str = "jnp",
                      kv_dtype: Optional[str] = None,
                      interpret: bool = True):
    """Jitted admit / decode-block for the paged KV layout.

    Admission scatters a prefilled contiguous cache into the slot's block
    table; decode gathers each live slot's blocks into a contiguous view,
    runs the model's own single-token step on it (value-identical to the
    contiguous path — the gather is a permutation-copy), then scatters back
    only the block that step wrote.  Dead / over-budget slots carry
    all-zero table rows, so their writes land in the null block 0.

    ``kernel_backend="pallas"`` replaces the gather/vmap/scatter decode
    with one batched ``Model.kernel_decode_step`` per step: the block
    tables are scalar-prefetched into the decode-attention kernel, so the
    contiguous view is never materialized.

    ``kv_dtype="int8"`` stores paged pools quantized with per-position
    scales (``models.kvcache.quantize_kv``): admission quantizes on the
    block write, the jnp decode path dequantizes the gathered view (and
    writes back only the one freshly written position, keeping stored
    blocks stable), and the pallas path dequantizes inside the kernel's
    block loop.  Radix snapshots stay float — sharing quantizes on the
    tail-block write like any other write.

    Besides the fused ``admit`` (prefill + scatter, the non-sharing fast
    path), the prefix-sharing engine uses the split pieces: ``prefill``
    runs the model once, ``scatter`` writes a given prefill result through
    a (possibly write-masked) table row, ``snapshot`` extracts the radix
    entry (partial tail block + slot-resident rows), and ``share_admit``
    admits a radix hit with *no* model compute — cached logits, cached
    slot rows, and a copy-on-write tail block seeded from the snapshot.
    """
    from repro.models import kvcache
    SUF = kvcache.SCALE_SUFFIX
    paged = frozenset(model.paged_cache_names())
    quant = kv_dtype == "int8"
    view_dtype = jnp.dtype(model.cfg.dtype)       # gathered-view dtype
    MB = blocks_for(max_seq_len, kv_block_size)   # table entries per slot
    S_view = MB * kv_block_size                   # gathered view length

    def prefill_fn(params, prompt, frontend):
        cache = model.init_cache(1, max_seq_len)
        logits, cache = model.prefill(params, prompt, cache,
                                      frontend=frontend)
        return logits[0], cache

    def _blockify(u):
        """(L, S, *rest) -> (L, MB, kv_block_size, *rest), zero-padded."""
        pad = [(0, 0)] * u.ndim
        pad[1] = (0, S_view - u.shape[1])
        u = jnp.pad(u, pad)
        return u.reshape(u.shape[0], MB, kv_block_size, *u.shape[2:])

    def scatter_fn(logits, one, pool, table_row, slot,
                   last_logits, alive, remaining, budget):
        """Write one prefilled batch=1 cache into the pool through
        ``table_row`` (write-masked rows send shared-prefix blocks to the
        null block) plus the logits/alive/budget row updates."""
        out = {}
        for name, leaf in pool.items():
            if name.endswith(SUF):
                continue                  # written beside the parent leaf
            upd = one[name]
            if name == "index":
                out[name] = leaf.at[slot].set(jnp.asarray(upd, leaf.dtype))
            elif name in paged:
                u = _blockify(upd[:, 0])                    # (L, MB, bs, ...)
                # unassigned / masked table entries are 0: their blocks
                # fall through to the null block
                if quant:
                    q, s = kvcache.quantize_kv(u, 3)
                    out[name] = leaf.at[:, table_row].set(q)
                    out[name + SUF] = pool[name + SUF].at[:, table_row].set(s)
                else:
                    out[name] = leaf.at[:, table_row].set(u.astype(leaf.dtype))
            else:
                start = (0, slot) + (0,) * (leaf.ndim - 2)
                out[name] = jax.lax.dynamic_update_slice(
                    leaf, upd.astype(leaf.dtype), start)
        return (out, last_logits.at[slot].set(logits),
                alive.at[slot].set(True), remaining.at[slot].set(budget))

    def admit_fn(params, prompt, frontend, pool, table_row, slot,
                 last_logits, alive, remaining, budget):
        """Prefill one request and scatter it into its block table (plus the
        slot-resident leaf rows) in a single dispatch."""
        logits, one = prefill_fn(params, prompt, frontend)
        return scatter_fn(logits, one, pool, table_row, slot,
                          last_logits, alive, remaining, budget)

    def snapshot_fn(one, *, tail_block):
        """Radix-entry extraction from a prefill result: the partial tail
        block of every paged leaf (``tail_block`` is its static table
        position, or None when the prompt ends on a block boundary) and the
        full batch=1 rows of every slot-resident leaf."""
        tail = {}
        if tail_block is not None:
            for name in sorted(paged):
                tail[name] = _blockify(one[name][:, 0])[:, tail_block]
        slot_leaves = {name: v for name, v in one.items()
                       if name != "index" and name not in paged}
        return tail, slot_leaves

    def share_admit_fn(pool, tail, slot_leaves, logits, tail_pid, slot,
                       last_logits, alive, remaining, budget, index_val):
        """Admit an exact radix hit with zero model compute: seed the
        private copy-on-write tail block and the slot-resident rows from
        the donor's snapshot, and restore the cached post-prompt logits."""
        out = {}
        for name, leaf in pool.items():
            if name.endswith(SUF):
                continue                  # written beside the parent leaf
            if name == "index":
                out[name] = leaf.at[slot].set(
                    jnp.asarray(index_val, leaf.dtype))
            elif name in paged:
                if name in tail:
                    if quant:             # snapshots are float: quantize
                        q, s = kvcache.quantize_kv(tail[name], 2)
                        out[name] = leaf.at[:, tail_pid].set(q)
                        out[name + SUF] = \
                            pool[name + SUF].at[:, tail_pid].set(s)
                    else:
                        out[name] = leaf.at[:, tail_pid].set(
                            tail[name].astype(leaf.dtype))
                else:           # prompt ends on a block boundary: no tail
                    out[name] = leaf
                    if quant:
                        out[name + SUF] = pool[name + SUF]
            else:
                upd = slot_leaves[name]
                start = (0, slot) + (0,) * (leaf.ndim - 2)
                out[name] = jax.lax.dynamic_update_slice(
                    leaf, upd.astype(leaf.dtype), start)
        return (out, last_logits.at[slot].set(logits),
                alive.at[slot].set(True), remaining.at[slot].set(budget))

    cache_keys = tuple(model.cache_logical_specs()) + \
        (model.scale_cache_names() if quant else ())
    cache_axes = {k: (0 if k == "index" else
                      (None if k in paged or k.endswith(SUF) else 1))
                  for k in cache_keys}
    slot_axes = {k: ax for k, ax in cache_axes.items()
                 if k not in paged and not k.endswith(SUF)}

    def decode_one(params, token, cache, table_row):
        # gather this slot's blocks into a contiguous (batch=1) view, run
        # the model's own decode step, and hand back the written block
        # (int8: dequantize the view, hand back only the written *row* so
        # already-stored positions are never re-quantized)
        old_idx = cache["index"]
        cache_b = {}
        for k, v in cache.items():
            if k == "index":
                cache_b[k] = v
            elif k.endswith(SUF):
                continue
            elif k in paged:
                # (L, S_view, *rest) with the batch=1 axis re-grown
                g = gather_blocks(v, table_row, axis=1)
                if quant:
                    s = gather_blocks(cache[k + SUF], table_row, axis=1)
                    g = kvcache.dequantize_kv(g, s, view_dtype)
                cache_b[k] = g[:, None]
            else:
                cache_b[k] = v[:, None]
        logits, cache_b = model.decode_step(
            params, jnp.reshape(token, (1, 1)), cache_b)
        b = jnp.minimum(old_idx // kv_block_size, MB - 1)
        pid = jnp.take(table_row, b)        # 0 (null) if not materialized
        out, written = {}, {}
        for k, v in cache_b.items():
            if k == "index":
                out[k] = v
            elif k in paged:
                if quant:
                    written[k] = jax.lax.dynamic_slice_in_dim(
                        v[:, 0], jnp.minimum(old_idx, S_view - 1), 1,
                        axis=1)[:, 0]       # just the new row (L, ...)
                else:
                    written[k] = jax.lax.dynamic_slice_in_dim(
                        v[:, 0], b * kv_block_size, kv_block_size, axis=1)
            else:
                out[k] = v[:, 0]
        return logits[0], out, written, pid, old_idx % kv_block_size

    pool_decode = jax.vmap(
        decode_one, in_axes=(None, 0, cache_axes, 0),
        out_axes=(0, slot_axes, {k: 0 for k in paged}, 0, 0))
    sample_logp = _make_sampler(temperature, kernel_backend, interpret)

    def jnp_decode(params, nxt, cache, tables):
        logits, slot_cache, written, pids, offs = pool_decode(
            params, nxt, cache, tables)
        new_cache = dict(cache) | dict(slot_cache)
        for k in paged:
            # distinct live slots own distinct blocks, so pids collide
            # only at the null block 0 (dead slots) — a don't-care write
            if quant:
                rows = jnp.moveaxis(written[k], 0, 1)       # (L, N, ...)
                q, s = kvcache.quantize_kv(rows, 2)
                new_cache[k] = cache[k].at[:, pids, offs].set(q)
                new_cache[k + SUF] = cache[k + SUF].at[:, pids, offs].set(s)
            else:
                blk = jnp.moveaxis(written[k], 0, 1)        # (L, N, bs, ...)
                new_cache[k] = cache[k].at[:, pids].set(blk)
        return logits, new_cache

    def block_fn(params, last_logits, cache, tables, alive, remaining, keys):
        def step(carry, key):
            logits, cache, alive, remaining = carry
            nxt, tok_logp = sample_logp(logits, key)        # (N,), (N,)
            rec = alive & (remaining > 0)
            if kernel_backend == "pallas":
                logits, cache = model.kernel_decode_step(
                    params, nxt[:, None], cache, tables=tables,
                    interpret=interpret)
            else:
                logits, cache = jnp_decode(params, nxt, cache, tables)
            alive = alive & (nxt != eos_id)
            remaining = remaining - rec.astype(jnp.int32)
            return (logits, cache, alive, remaining), (nxt, tok_logp, rec)

        carry, out = jax.lax.scan(
            step, (last_logits, cache, alive, remaining), keys)
        return carry, out                   # out: (toks, logps, recs) (K,N)

    def suspend_fn(pool, slot, tail_pid):
        """Capture a live slot's mid-generation state for suspension: the
        (dequantized) partial tail block of every paged leaf plus the
        batch=1 rows of every slot-resident leaf — the same snapshot shape
        a radix entry / KV transfer handle carries, taken from the *pool*
        instead of a prefill result.  The full blocks travel as allocator
        pins, not copies."""
        tail = {}
        for name in sorted(paged):
            t = pool[name][:, tail_pid]
            if quant:
                t = kvcache.dequantize_kv(t, pool[name + SUF][:, tail_pid],
                                          view_dtype)
            tail[name] = t
        slot_leaves = {}
        for name, leaf in pool.items():
            if name == "index" or name in paged or name.endswith(SUF):
                continue
            start = (0, slot) + (0,) * (leaf.ndim - 2)
            sizes = (leaf.shape[0], 1) + leaf.shape[2:]
            slot_leaves[name] = jax.lax.dynamic_slice(leaf, start, sizes)
        return tail, slot_leaves

    def inject_fn(params, tokens, one):
        """Advance a batch=1 contiguous cache view through forced tokens
        (a tool result) with the model's own decode step; the returned
        logits predict the first post-injection token."""
        def step(one, t):
            logits, one = model.decode_step(
                params, jnp.reshape(t, (1, 1)), one)
            return one, logits
        one, logits = jax.lax.scan(step, one, tokens)
        return logits[-1, 0], one

    return {"admit": jax.jit(admit_fn), "block": jax.jit(block_fn),
            "prefill": jax.jit(prefill_fn),
            "scatter": jax.jit(scatter_fn),
            "snapshot": jax.jit(snapshot_fn,
                                static_argnames=("tail_block",)),
            "share_admit": jax.jit(share_admit_fn),
            "suspend": jax.jit(suspend_fn), "inject": jax.jit(inject_fn)}


class SuspendedRequest:
    """A live generation exported out of its slot at a tool/stop boundary
    (or a weight sync), waiting to be resumed.

    The handle is the mid-generation generalization of
    :class:`~repro.serve.disagg.KVTransferHandle`: paged engines pin the
    sequence's *full* KV blocks in the source pool (one ``incref`` each —
    zero copies) and carry a small device snapshot (dequantized partial
    tail block, slot-resident rows, the slot's last logits); contiguous
    engines carry the whole batch=1 cache stripe in ``one``.  The slot
    itself is released at suspension — capacity is immediately reusable.

    ``history`` is the full token sequence behind ``index`` (prompt +
    tokens generated so far): a resume re-admits through the same
    ``admit_prefilled`` adoption path disaggregated prefill uses, with
    ``history`` (+ tool tokens) as the synthetic prompt, so it works on
    monolithic and disagg engines alike and across engines of the same
    serving shape.

    ``logits`` is the boundary logits row and is only usable
    (``logits_valid``) when the stop token was the last token the fused
    decode block produced — a suspension truncated out of a ``block_size
    > 1`` overrun recomputes the boundary logits at resume (tool-token
    injection, or a one-token replay of the final history token).

    :meth:`release` drops the pins exactly once (idempotent), mirroring
    ``KVTransferHandle.release`` — a handle dropped mid-flight must
    restore the allocator's conservation invariant.
    """

    __slots__ = ("req", "out", "history", "index", "remaining", "logits",
                 "logits_valid", "block_ids", "tail", "slot_leaves", "one",
                 "source", "weight_version", "released")

    def __init__(self, req: Request, out: RequestOutput, history, index: int,
                 remaining: int, logits, *, source, logits_valid: bool = True,
                 block_ids=(), tail=None, slot_leaves=None, one=None,
                 weight_version: int = 0):
        self.req = req
        self.out = out
        self.history = np.asarray(history, np.int32).reshape(-1)
        self.index = int(index)
        self.remaining = int(remaining)
        self.logits = logits
        self.logits_valid = logits_valid
        self.block_ids = tuple(int(b) for b in block_ids)
        self.tail = tail if tail is not None else {}
        self.slot_leaves = slot_leaves if slot_leaves is not None else {}
        self.one = one                      # contiguous: full batch=1 cache
        self.source = source                # the Engine holding the pins
        self.weight_version = weight_version
        self.released = False

    def release(self) -> None:
        """Drop this handle's pins in the source pool (idempotent)."""
        if self.released:
            return
        self.released = True
        self.source._release_suspended(self)
        self.one = None
        self.tail = {}
        self.slot_leaves = {}
        self.logits = None


class Engine:
    """Continuous-batching generation engine over a fixed slot pool."""

    def __init__(self, model, params, config: EngineConfig,
                 rng: Optional[jax.Array] = None, policy=None):
        self.model = model
        self.params = params
        self.config = config
        self.queue = RequestQueue(config.max_waiting)
        # admission policy (serve.sched): a policy object wins over the
        # config's policy name (SLO policies carry per-group parameters)
        self.policy = policy if policy is not None else \
            make_policy(config.sched)
        self.paged = config.kv_layout == "paged"
        self.kernel_backend = self._resolve_backend(config.kernel_backend)
        self._build_fns()
        self.radix = (RadixPrefixIndex(self.slots.alloc)
                      if config.prefix_share else None)
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        N = config.num_slots
        self._last_logits = jnp.zeros((N, model.cfg.vocab_size), jnp.float32)
        self._alive = jnp.zeros((N,), bool)
        self._remaining = jnp.zeros((N,), jnp.int32)
        self._zero_keys = jnp.zeros((config.block_size, 2), jnp.uint32)
        self._host_index = [0] * N    # per-slot sequence position (host view)
        self._active: dict[int, tuple[Request, RequestOutput]] = {}
        self.finished: dict[int, RequestOutput] = {}
        self._unharvested: list[RequestOutput] = []
        # ---- suspend/resume + partial-rollout bookkeeping ----
        # weights swapped via reset(params=...) bump weight_version; each
        # slot remembers the version that produced its current last-logits
        # row, so per-token provenance is exact across carry_live resets
        self.weight_version = 0
        self._slot_version = [0] * N
        # carry-resumed outputs arrive pre-seeded with earlier tokens;
        # _seed_tokens[slot] marks how many, so sequence-position math
        # (index = prompt_len + generated-this-lifetime) stays right
        self._seed_tokens: dict[int, int] = {}
        self.suspended: dict[int, SuspendedRequest] = {}    # by rid
        self._newly_suspended: list[SuspendedRequest] = []
        # stop-token rollback is only safe when every non-index cache leaf
        # is sequence-shaped (attention masks positions >= index); recurrent
        # state (ssm/hybrid) cannot rewind, so those families must suspend
        # at block_size=1 (no overrun to truncate)
        paged_names = set(model.paged_cache_names())
        self._rollback_safe = all(
            k == "index" or k in paged_names
            for k in model.cache_logical_specs())
        self._stats = EngineStats()
        self.clock = None             # optional wall-clock for trace drivers

    def _resolve_backend(self, backend: str) -> str:
        """Effective decode backend for this model: recurrent families
        (rwkv6: no sequence-shaped KV for the kernel to touch) silently
        fall back from pallas to jnp; families the kernel path cannot
        serve faithfully (MLA/hybrid/audio) refuse loudly."""
        if backend != "pallas" or self.model.kernel_supported():
            return backend
        if self.model.cfg.family == "ssm":
            return "jnp"            # pure recurrent state: nothing to page
        raise ValueError(
            f"kernel_backend='pallas' does not support family "
            f"{self.model.cfg.family!r} / attention "
            f"{self.model.cfg.attention!r}")

    def _build_fns(self) -> None:
        """(Re)build the jitted fns + slot pool for the current config and
        effective backend (cached per shape, so flips are cheap)."""
        from repro.kernels.ops import resolve_interpret
        model, config = self.model, self.config
        # interpret mode resolved once per engine (at call time relative to
        # the lazy env/flag override) and baked into the jitted fns
        interp = (resolve_interpret()
                  if self.kernel_backend == "pallas" else True)
        kv_dtype = None if config.kv_dtype == "auto" else config.kv_dtype
        if self.paged:
            if not hasattr(self, "slots"):
                self.slots = PagedSlotManager(
                    model, config.num_slots, config.max_seq_len,
                    block_size=config.kv_block_size,
                    num_blocks=config.num_kv_blocks,
                    kv_dtype=kv_dtype)
            self._fns = _paged_engine_fns(
                model, config.max_seq_len, config.kv_block_size,
                config.temperature, config.eos_id,
                kernel_backend=self.kernel_backend, kv_dtype=kv_dtype,
                interpret=interp)
        else:
            if kv_dtype is not None:
                raise ValueError("kv_dtype requires kv_layout='paged'")
            if not hasattr(self, "slots"):
                self.slots = SlotManager(model, config.num_slots,
                                         config.max_seq_len)
            self._fns = _engine_fns(
                model, config.max_seq_len, config.temperature, config.eos_id,
                kernel_backend=self.kernel_backend, interpret=interp)
        self._admit_fn, self._block = self._fns["admit"], self._fns["block"]

    def set_kernel_backend(self, backend: str) -> None:
        """Switch the decode backend on a drained engine.

        The jitted decode block is rebuilt (cached per shape, so A/B flips
        re-use earlier compilations) and the admission policy is told via
        ``on_backend_change()``: a backend flip invalidates any learned
        per-token service-time estimate — the SLO policy re-arms its
        first-sample compile discard and falls back to its initial
        estimate rather than steering deadlines with the old backend's
        timings."""
        if backend not in ("jnp", "pallas"):
            raise ValueError(f"unknown kernel_backend {backend!r}")
        if backend == self.config.kernel_backend:
            return
        if not self.idle:
            raise RuntimeError("set_kernel_backend() on a live engine; "
                               "drain or export_state() first")
        import dataclasses
        self.config = dataclasses.replace(self.config,
                                          kernel_backend=backend)
        self.kernel_backend = self._resolve_backend(backend)
        self._build_fns()
        self.policy.on_backend_change()

    # ---- submission --------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Enqueue a request.  Malformed requests (too big for the engine)
        raise; a full queue returns ``False`` — a backpressure signal the
        caller should honour by deferring and retrying after the engine
        drains (``run_trace`` and ``generate_continuous`` do)."""
        if req.total_budget > self.config.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + budget "
                f"{req.max_new_tokens} exceeds max_seq_len "
                f"{self.config.max_seq_len}")
        if self.paged:
            need = self.slots.blocks_required(req.total_budget)
            if need > self.slots.alloc.num_blocks:
                raise ValueError(
                    f"request {req.rid}: needs {need} KV blocks but the "
                    f"pool has {self.slots.alloc.num_blocks}")
        self._validate_stop_tokens(req)
        return self.queue.push(req)

    def _validate_stop_tokens(self, req: Request) -> None:
        if not req.stop_tokens:
            return
        if self.config.eos_id in req.stop_tokens:
            raise ValueError(
                f"request {req.rid}: stop_tokens contain eos_id "
                f"{self.config.eos_id} — EOS finishes, it cannot suspend")
        if self.config.block_size > 1 and not self._rollback_safe:
            raise ValueError(
                f"request {req.rid}: stop-token suspension on family "
                f"{self.model.cfg.family!r} needs block_size=1 — its "
                f"recurrent cache state cannot be rolled back past a "
                f"mid-block stop boundary")

    @property
    def num_active(self) -> int:
        return len(self._active)

    @property
    def idle(self) -> bool:
        return not self.queue and not self._active

    # ---- telemetry ---------------------------------------------------------
    @property
    def stats(self) -> EngineStats:
        """Deprecated raw counter record — use :meth:`metrics` (the unified
        ``core.telemetry.MetricsSnapshot`` API).  Still served (warn-once)
        so pre-telemetry callers keep working."""
        warn_legacy_once(
            _warned_legacy,
            "Engine.stats is deprecated; read the unified telemetry via "
            "Engine.metrics() (core.telemetry.MetricsSnapshot)")
        return self._stats

    @stats.setter
    def stats(self, value: EngineStats) -> None:
        warn_legacy_once(
            _warned_legacy,
            "Engine.stats is deprecated; read the unified telemetry via "
            "Engine.metrics() (core.telemetry.MetricsSnapshot)")
        self._stats = value

    def metrics(self) -> MetricsSnapshot:
        """One merged :class:`~repro.core.telemetry.MetricsSnapshot` of this
        engine: queue/slot occupancy gauges, decode counters, KV block
        occupancy, prefix-sharing counters, suspend/resume traffic.  The
        elastic controller and the benchmarks consume only this."""
        s = self._stats
        snap = MetricsSnapshot(
            source="engine",
            queue_depth=len(self.queue),
            rejected_submits=self.queue.rejected,
            num_slots=self.config.num_slots,
            num_active=len(self._active),
            peak_active=s.peak_active,
            slot_steps=s.slot_steps,
            steps=s.steps,
            decode_time_s=s.decode_time_s,
            prefills=s.prefills,
            recorded_tokens=s.recorded_tokens,
            generated_tokens=s.recorded_tokens,
            peak_kv_blocks=s.peak_kv_blocks,
            prefix_hits=s.prefix_hits,
            prefix_partial_hits=s.prefix_partial_hits,
            blocks_saved=s.blocks_saved,
            adoptions=s.adoptions,
            suspends=s.suspends,
            resumes=s.resumes,
            suspended=len(self.suspended),
            weight_version=self.weight_version)
        if self.paged:
            snap.kv_blocks_total = self.slots.alloc.num_blocks
            snap.kv_blocks_in_use = self.slots.blocks_in_use
        if self.radix is not None:
            rs = self.radix.stats
            snap.prefix_misses = rs["misses"]
            snap.prefix_evictions = rs["evictions"]
            snap.pinned_blocks = rs["pinned_blocks"]
            snap.prefix_snapshots = rs["snapshots"]
            snap.snapshot_demotions = rs["snapshot_demotions"]
        return snap

    # ---- scheduler ---------------------------------------------------------
    def _match(self, req: Request, *, count: bool = False):
        """Radix lookup for ``req`` (``None`` with sharing off or no match).

        Requests carrying frontend embeddings never share: the prompt
        tokens alone don't identify their KV (prefill conditions on the
        frontend), so a token-verified hit could still serve another
        request's image/audio-conditioned cache.  ``count=True`` marks
        the admission lookup — the radix index owns all hit/partial/miss
        counters and bumps exactly one per counted call."""
        if self.radix is None or req.frontend is not None:
            return None
        return self.radix.match(req, count=count)

    def _can_admit(self, req: Request) -> bool:
        """Admission gate the policy consults per candidate: a free slot,
        and (paged) enough uncommitted KV blocks for the candidate's
        worst-case budget **net of prefix-shared blocks**.  Under block
        pressure the radix index LRU-evicts unused entries (never the one
        this candidate would share from) before giving up."""
        if not self.paged:
            return bool(self.slots.num_free)
        if not self.slots.num_free:
            return False
        m = self._match(req)
        n_shared = m.n_shared if m is not None else 0
        if self.slots.can_admit(req.total_budget, shared_blocks=n_shared):
            return True
        if self.radix is not None and len(self.radix):
            need = max(self.slots.blocks_required(req.total_budget)
                       - n_shared, 0)
            if self.radix.evict_for(
                    need, protect=m.node_ids if m is not None else ()):
                return True
            # last resort: the path this request would share from is
            # itself pinning the pool — drop it too and admit unshared
            return self.radix.evict_for(
                self.slots.blocks_required(req.total_budget))
        return False

    def _admit(self) -> None:
        """Admit waiting requests into free slots, in the order the policy
        picks them (FIFO preserves strict arrival order; deadline/SLO may
        skip a blocked head — boundedly)."""
        live_tokens: dict[str, int] = {}
        for r, _ in self._active.values():
            if r.job_id is not None:
                live_tokens[r.job_id] = (live_tokens.get(r.job_id, 0)
                                         + r.max_new_tokens)
        now = self.clock() if self.clock is not None else 0.0
        while self.queue:
            idx = self.policy.pick(self.queue, self._can_admit, now=now,
                                   live_tokens=live_tokens)
            if idx is None:
                break
            req = self.queue.pop_at(idx)
            self._admit_one(req)
            if req.job_id is not None:
                live_tokens[req.job_id] = (live_tokens.get(req.job_id, 0)
                                           + req.max_new_tokens)
        self._stats.peak_active = max(self._stats.peak_active,
                                     len(self._active))
        if self.paged:
            self._stats.peak_kv_blocks = max(self._stats.peak_kv_blocks,
                                            self.slots.blocks_in_use)

    def _admit_one(self, req: Request) -> None:
        """Prefill (or share) one picked request into a free slot."""
        prompt_dev = jnp.asarray(req.prompt)[None]
        budget = jnp.asarray(req.max_new_tokens, jnp.int32)
        shared_blocks = 0
        if not self.paged:
            slot = self.slots.assign(req.rid)
            (self.slots.cache, self._last_logits, self._alive,
             self._remaining) = self._admit_fn(
                self.params, prompt_dev, req.frontend,
                self.slots.cache, jnp.asarray(slot, jnp.int32),
                self._last_logits, self._alive, self._remaining, budget)
        else:
            m = self._match(req, count=True)
            if m is not None and m.exact:
                slot = self._admit_shared_exact(req, m, budget)
                shared_blocks = m.n_shared
            elif m is not None and m.n_shared > 0:
                slot = self._admit_shared_prefix(req, m, prompt_dev, budget)
                shared_blocks = m.n_shared
            else:
                slot = self.slots.assign(req.rid, prompt_len=req.prompt_len,
                                         total_budget=req.total_budget)
                row = self.slots.device_tables()[slot]
                if self.radix is not None and req.frontend is None:
                    # donor path: split prefill + scatter so the radix
                    # path (blocks + tail/slot-row snapshot) can register
                    logits, one = self._fns["prefill"](
                        self.params, prompt_dev, req.frontend)
                    (self.slots.cache, self._last_logits, self._alive,
                     self._remaining) = self._fns["scatter"](
                        logits, one, self.slots.cache, row,
                        jnp.asarray(slot, jnp.int32), self._last_logits,
                        self._alive, self._remaining, budget)
                    self._register_prefix(req, slot, logits, one)
                else:
                    (self.slots.cache, self._last_logits, self._alive,
                     self._remaining) = self._admit_fn(
                        self.params, prompt_dev, req.frontend,
                        self.slots.cache, row, jnp.asarray(slot, jnp.int32),
                        self._last_logits, self._alive, self._remaining,
                        budget)
        self._host_index[slot] = req.prompt_len
        self._slot_version[slot] = self.weight_version
        self._seed_tokens[slot] = 0
        out = RequestOutput(rid=req.rid, prompt=req.prompt,
                            prefill_step=self._stats.steps,
                            arrival_time=req.arrival_time,
                            priority=req.priority, deadline=req.deadline,
                            job_id=req.job_id,
                            prefix_shared_blocks=shared_blocks)
        self._active[slot] = (req, out)
        self._stats.prefills += 1
        self._stats.blocks_saved += shared_blocks

    def _register_prefix(self, req: Request, slot: int, logits, one) -> None:
        """Record the donor's full prompt blocks + admit snapshot."""
        bs = self.config.kv_block_size
        n_full = req.prompt_len // bs
        if self.slots.paged_names:
            block_ids = [int(b) for b in self.slots.tables[slot, :n_full]]
        else:
            block_ids = []          # nothing paged (e.g. rwkv6): share the
            #                         snapshot (prefill-once), not blocks
        tail_block = n_full if req.prompt_len % bs else None
        tail, slot_leaves = self._fns["snapshot"](one, tail_block=tail_block)
        if not self.slots.paged_names:
            tail = {}
        self.radix.register(req, block_ids, logits=logits, tail=tail,
                            slot_leaves=slot_leaves)

    def _admit_shared_exact(self, req: Request, m, budget) -> int:
        """Radix exact hit: no model compute.  Pin the shared full blocks
        under this slot, materialize a private copy-on-write tail from the
        boundary snapshot, restore cached logits / slot-resident rows."""
        self.radix.touch(m)
        snap = m.snapshot
        slot = self.slots.assign_shared(
            req.rid, prompt_len=req.prompt_len,
            total_budget=req.total_budget,
            shared_ids=m.block_ids)
        tail_pid = (int(self.slots.tables[slot, m.n_shared])
                    if snap.tail else 0)
        (self.slots.cache, self._last_logits, self._alive,
         self._remaining) = self._fns["share_admit"](
            self.slots.cache, snap.tail, snap.slot_leaves, snap.logits,
            jnp.asarray(tail_pid, jnp.int32), jnp.asarray(slot, jnp.int32),
            self._last_logits, self._alive, self._remaining, budget,
            jnp.asarray(req.prompt_len, jnp.int32))
        self._stats.prefix_hits += 1
        return slot

    def _admit_shared_prefix(self, req: Request, m, prompt_dev,
                             budget) -> int:
        """Block-granular prefix hit (prompt extends / diverges from every
        registered path): prefill runs — compute is not shareable — but
        the matching full blocks are pinned instead of allocated, and the
        scatter goes through a write-masked row so shared blocks are never
        written.  The extension blocks then register in turn, so the tree
        deepens along whatever prefixes the workload actually repeats."""
        self.radix.touch(m)
        slot = self.slots.assign_shared(
            req.rid, prompt_len=req.prompt_len,
            total_budget=req.total_budget,
            shared_ids=m.block_ids)
        masked = self.slots.tables[slot].copy()
        masked[:m.n_shared] = 0             # shared blocks -> null (no write)
        logits, one = self._fns["prefill"](self.params, prompt_dev,
                                           req.frontend)
        (self.slots.cache, self._last_logits, self._alive,
         self._remaining) = self._fns["scatter"](
            logits, one, self.slots.cache, jnp.asarray(masked),
            jnp.asarray(slot, jnp.int32), self._last_logits, self._alive,
            self._remaining, budget)
        self._register_prefix(req, slot, logits, one)
        self._stats.prefix_partial_hits += 1
        return slot

    # ---- disaggregated-prefill adoption ------------------------------------
    def can_admit_prefilled(self, req: Request) -> bool:
        """Adoption gate for a KV transfer handle (``serve.disagg``): a free
        slot, and (paged) enough uncommitted blocks for the request's
        worst-case decode budget.  No radix *matching* — the handle's
        prompt KV arrives prefilled; sharing happened on the prefill
        side — though :meth:`admit_prefilled` does register the adopted
        prompt so later requests can share it."""
        if not self.slots.num_free:
            return False
        if not self.paged:
            return True
        if self.slots.can_admit(req.total_budget):
            return True
        if self.radix is not None and len(self.radix):
            return self.radix.evict_for(
                self.slots.blocks_required(req.total_budget))
        return False

    def admit_prefilled(self, req: Request, logits, one) -> int:
        """Adopt an externally prefilled request into a fresh slot.

        ``one`` is a batch=1 cache pytree holding exactly the prompt's
        prefill state (``index`` = prompt length) and ``logits`` the
        post-prompt logits — a ``prefill_fn`` result, whether produced
        in-process or materialized from a
        :class:`~repro.serve.disagg.KVTransferHandle`.  The splice is the
        same jitted ``scatter`` the monolithic admit path uses, so decode
        from an adopted slot is bit-identical to a monolithic admit.
        Returns the slot.  Callers must gate on
        :meth:`can_admit_prefilled` — like ``SlotManager.assign``, this
        raises rather than queues when the pool is full."""
        self._validate_stop_tokens(req)
        budget = jnp.asarray(req.max_new_tokens, jnp.int32)
        if not self.paged:
            slot = self.slots.assign(req.rid)
            (self.slots.cache, self._last_logits, self._alive,
             self._remaining) = self._fns["scatter"](
                logits, one, self.slots.cache, jnp.asarray(slot, jnp.int32),
                self._last_logits, self._alive, self._remaining, budget)
        else:
            slot = self.slots.assign(req.rid, prompt_len=req.prompt_len,
                                     total_budget=req.total_budget)
            row = self.slots.device_tables()[slot]
            (self.slots.cache, self._last_logits, self._alive,
             self._remaining) = self._fns["scatter"](
                logits, one, self.slots.cache, row,
                jnp.asarray(slot, jnp.int32), self._last_logits,
                self._alive, self._remaining, budget)
            if self.radix is not None and req.frontend is None:
                # register the adopted prompt — for multi-turn resume()
                # this is the episode's whole history, so sibling
                # rollouts and turn k+1 match turn k's blocks
                self._register_prefix(req, slot, logits, one)
            self._stats.peak_kv_blocks = max(self._stats.peak_kv_blocks,
                                            self.slots.blocks_in_use)
        self._host_index[slot] = req.prompt_len
        self._slot_version[slot] = self.weight_version
        self._seed_tokens[slot] = 0
        out = RequestOutput(rid=req.rid, prompt=req.prompt,
                            prefill_step=self._stats.steps,
                            arrival_time=req.arrival_time,
                            priority=req.priority, deadline=req.deadline,
                            job_id=req.job_id)
        self._active[slot] = (req, out)
        self._stats.prefills += 1
        self._stats.adoptions += 1
        self._stats.peak_active = max(self._stats.peak_active,
                                     len(self._active))
        return slot

    def _finalize(self, slot: int) -> None:
        req, out = self._active[slot]
        out.finish_reason = ("eos" if out.tokens and
                             out.tokens[-1] == self.config.eos_id else "length")
        out.finish_step = self._stats.steps
        if self.clock is not None:
            out.finish_time = self.clock()
        self.finished[req.rid] = out
        self._unharvested.append(out)
        del self._active[slot]
        self._seed_tokens.pop(slot, None)
        self.slots.release(slot)
        self.policy.observe_finish(out)     # fallback service-time estimate

    def harvest(self) -> list[RequestOutput]:
        """Pop the requests that finished since the last harvest, *without*
        draining the engine: queued and live requests keep decoding.  This
        is the partial-harvest contract the streaming mux uses to hand
        completed GRPO prompt groups to reward verification while the
        engine is still serving the stragglers.  Outputs also stay in
        :attr:`finished`, so batch drivers that collect everything at the
        end are unaffected."""
        out, self._unharvested = self._unharvested, []
        return out

    def step(self) -> int:
        """One scheduler iteration: admit waiting requests, then run
        ``block_size`` decode steps for all slots.  Returns the number of
        decode steps executed — ``0`` means *no work* (nothing admissible
        queued and no live slot), so drivers waiting on late submissions
        can sleep instead of spinning (see :func:`run_trace`)."""
        self._admit()
        if not self._active:
            if self.queue:
                # nothing live, requests waiting, nothing admissible — and
                # with an empty engine nothing will ever change that: the
                # admission gate depends only on engine state.  A per-job
                # token budget smaller than a single request's decode
                # budget is the one way to get here; fail loud over
                # spinning forever.
                raise RuntimeError(
                    f"admission stalled: {len(self.queue)} waiting, 0 "
                    f"active — check policy token budgets / pool sizing")
            return 0
        if self.config.temperature == 0:
            keys = self._zero_keys          # unused by greedy sampling
        else:
            self._rng, sub = jax.random.split(self._rng)
            keys = jax.random.split(sub, self.config.block_size)
        K = self.config.block_size
        t_decode = time.perf_counter()
        if self.paged:
            # materialize blocks this decode block will write into
            # (allocation stays within each request's admit-time reservation)
            for slot in self._active:
                self.slots.ensure(slot, self._host_index[slot] + K - 1)
            self._stats.peak_kv_blocks = max(self._stats.peak_kv_blocks,
                                            self.slots.blocks_in_use)
            (self._last_logits, self.slots.cache, self._alive,
             self._remaining), out = self._block(
                self.params, self._last_logits, self.slots.cache,
                self.slots.device_tables(), self._alive, self._remaining,
                keys)
        else:
            (self._last_logits, self.slots.cache, self._alive,
             self._remaining), out = self._block(
                self.params, self._last_logits, self.slots.cache,
                self._alive, self._remaining, keys)
        for slot in self._active:
            self._host_index[slot] += K
        toks, logps, recs, alive, remaining = jax.device_get(
            (*out, self._alive, self._remaining))
        t_decode = time.perf_counter() - t_decode
        self._stats.decode_time_s += t_decode
        # engine-measured service time straight into the admission policy:
        # K decode steps just took t_decode (every live slot advanced one
        # token per step), so SLO deadline estimates track the hardware
        # actually serving — no finish-time heuristics involved
        self.policy.observe_step(t_decode, K)
        self._stats.steps += K
        self._stats.blocks += 1
        self._stats.slot_steps += K * self.config.num_slots
        for slot in list(self._active):
            req, o = self._active[slot]
            rec_col = recs[:, slot]
            n_rec = int(rec_col.sum())
            stop_at = None                  # position of a stop trigger
            if n_rec:
                if not o.tokens and self.clock is not None:
                    o.first_token_time = self.clock()   # first token on host
                new_toks = [int(t) for t in toks[rec_col, slot]]
                if req.stop_tokens:
                    for j, t in enumerate(new_toks):
                        if t in req.stop_tokens:
                            stop_at = j
                            break
                # a stop trigger is recorded like EOS; anything the fused
                # block over-ran past it is truncated (the stale KV sits
                # beyond the rolled-back index, which attention masks)
                keep = n_rec if stop_at is None else stop_at + 1
                o.tokens.extend(new_toks[:keep])
                o.logprobs.extend(
                    float(x) for x in logps[rec_col, slot][:keep])
                # token 1 of the block was sampled from last_logits (the
                # slot's remembered version — stale across a carry resume);
                # later tokens from logits this block just produced
                o.token_versions.extend(
                    [self._slot_version[slot]]
                    + [self.weight_version] * (keep - 1))
                self._slot_version[slot] = self.weight_version
                self._stats.recorded_tokens += keep
            if stop_at is not None:
                # tool boundary before EOS/budget: suspend, free the slot.
                # Boundary logits are only live when the trigger was the
                # block's final step (no truncation).
                o.finish_reason = "stop"
                sreq = self._suspend_slot(
                    slot, logits_valid=(stop_at + 1 == K))
                self._newly_suspended.append(sreq)
            elif (not alive[slot]) or remaining[slot] <= 0:
                self._finalize(slot)
        return K

    def run(self, *, max_ticks: Optional[int] = None,
            should_yield=None) -> list[RequestOutput]:
        """Drive the engine until queue and slots are empty; outputs by rid.

        ``max_ticks`` bounds the number of scheduler iterations and
        ``should_yield()`` (checked between ticks) lets a driver preempt a
        live engine cooperatively — in both cases ``run`` returns with work
        possibly still in flight (``idle`` is False); call ``run`` again, or
        :meth:`export_state` to checkpoint the live slots, to continue.
        """
        ticks = 0
        while not self.idle:
            if max_ticks is not None and ticks >= max_ticks:
                break
            if should_yield is not None and ticks and should_yield():
                break
            self.step()
            ticks += 1
        return [self.finished[r] for r in sorted(self.finished)]

    # ---- suspend / resume --------------------------------------------------
    def harvest_suspended(self) -> list[SuspendedRequest]:
        """Pop the requests that hit a stop-token boundary since the last
        call — the agentic driver's pickup point (the partial-harvest twin
        of :meth:`harvest`).  Handles stay registered in :attr:`suspended`
        until resumed or released."""
        out, self._newly_suspended = self._newly_suspended, []
        return out

    def suspend(self, rid: int) -> SuspendedRequest:
        """Suspend a live request by rid (manual / carry-side suspension, at
        a fused-block boundary so the captured logits stay valid), freeing
        its slot.  Returns the pinned handle; also registered in
        :attr:`suspended` until resumed or released."""
        for slot, (req, _) in self._active.items():
            if req.rid == rid:
                return self._suspend_slot(slot)
        raise KeyError(f"rid {rid} is not live")

    def _suspend_slot(self, slot: int, *,
                      logits_valid: bool = True) -> SuspendedRequest:
        """Export slot ``slot``'s generation into a SuspendedRequest and
        release the slot.  Paged: pin the sequence's full blocks (zero
        copy), snapshot the partial tail + slot rows.  Contiguous: extract
        the batch=1 stripe."""
        req, out = self._active.pop(slot)
        seed = self._seed_tokens.pop(slot, 0)
        produced = len(out.tokens) - seed   # tokens this slot lifetime
        idx = req.prompt_len + produced     # rolled-back sequence position
        self._host_index[slot] = idx
        history = np.concatenate(
            [req.prompt, np.asarray(out.tokens[seed:], np.int32)])
        kwargs = dict(source=self, logits_valid=logits_valid,
                      weight_version=self._slot_version[slot])
        logits = self._last_logits[slot]
        if not self.paged:
            one = dict(self._fns["extract"](
                self.slots.cache, jnp.asarray(slot, jnp.int32)))
            one["index"] = jnp.asarray(idx, jnp.int32)
            sreq = SuspendedRequest(
                req, out, history, idx, req.max_new_tokens - produced,
                logits, one=one, **kwargs)
        else:
            bs = self.config.kv_block_size
            has_paged = bool(self.slots.paged_names)
            n_full = (idx // bs) if has_paged else 0
            pinned = self.slots.pin_prefix(slot, n_full)
            has_tail = has_paged and idx % bs != 0
            tail_pid = (int(self.slots.tables[slot, n_full])
                        if has_tail else 0)
            tail, slot_leaves = self._fns["suspend"](
                self.slots.cache, jnp.asarray(slot, jnp.int32),
                jnp.asarray(tail_pid, jnp.int32))
            if not has_tail:
                tail = {}
            sreq = SuspendedRequest(
                req, out, history, idx, req.max_new_tokens - produced,
                logits, block_ids=pinned, tail=tail,
                slot_leaves=dict(slot_leaves), **kwargs)
        self.slots.release(slot)
        self.suspended[req.rid] = sreq
        self._stats.suspends += 1
        return sreq

    def _materialize(self, sreq: SuspendedRequest) -> dict:
        """Batch=1 contiguous cache view of a handle suspended from *this*
        engine's pool — the resume-side twin of
        ``PrefillEngine.export_cache`` (same jitted fetch: gather the
        pinned blocks through a padded table row, splice the tail
        snapshot, dequantizing int8 on the way out)."""
        if sreq.released:
            raise RuntimeError(
                f"suspended rid {sreq.req.rid} was already released")
        if not self.paged:
            one = dict(sreq.one)
            one["index"] = jnp.asarray(sreq.index, jnp.int32)
            return one
        one = dict(sreq.slot_leaves)
        one["index"] = jnp.asarray(sreq.index, jnp.int32)
        if self.slots.paged_names:
            from repro.serve.disagg import _transfer_fns
            kv_dtype = (None if self.config.kv_dtype == "auto"
                        else self.config.kv_dtype)
            xfer = _transfer_fns(self.model, self.config.max_seq_len,
                                 self.config.kv_block_size,
                                 kv_dtype=kv_dtype)
            row = np.zeros((self.slots.max_blocks,), np.int32)
            row[:len(sreq.block_ids)] = sreq.block_ids
            src = {name: self.slots.cache[name]
                   for name in self.slots.paged_names}
            if kv_dtype == "int8":
                src.update({name: self.slots.cache[name]
                            for name in self.model.scale_cache_names()})
            one.update(xfer["fetch"](
                src, jnp.asarray(row), sreq.tail,
                jnp.asarray(len(sreq.block_ids), jnp.int32)))
        return one

    def _release_suspended(self, sreq: SuspendedRequest) -> None:
        if self.paged:
            for bid in sreq.block_ids:
                self.slots.alloc.decref(bid)
        if self.suspended.get(sreq.req.rid) is sreq:
            del self.suspended[sreq.req.rid]

    def can_resume(self, sreq: SuspendedRequest, tool_tokens=(), *,
                   max_new_tokens: Optional[int] = None) -> bool:
        """Re-admission gate for a suspended handle: a free slot and
        (paged) blocks for the continued sequence's worst-case budget —
        the same gate :meth:`can_admit_prefilled` applies to transfer
        handles."""
        if not self.slots.num_free:
            return False
        if not self.paged:
            return True
        budget = (max_new_tokens if max_new_tokens is not None
                  else max(sreq.remaining, 1))
        total = sreq.index + len(tool_tokens) + budget
        if self.slots.can_admit(total):
            return True
        if self.radix is not None and len(self.radix):
            return self.radix.evict_for(self.slots.blocks_required(total))
        return False

    def resume(self, sreq: SuspendedRequest, tool_tokens=(), *,
               max_new_tokens: Optional[int] = None,
               rid: Optional[int] = None,
               stop_tokens: Optional[tuple] = None,
               continue_output: bool = False) -> int:
        """Re-adopt a suspended generation into a fresh slot, optionally
        feeding ``tool_tokens`` (the environment's reply) through the
        model first so decoding continues past them.

        The adoption itself is :meth:`admit_prefilled` on a synthetic
        request whose prompt is the handle's token history (+ tool
        tokens) — the same path disaggregated prefill handles take, so a
        handle suspended on one engine resumes on any engine with the
        same serving shape (``sreq.source`` keeps the pins until the view
        is materialized here).  Greedy continuation is bit-identical to
        never having suspended on float pools: every array decode
        restarts from is moved by pure copies, and injection uses the
        model's own decode step.  int8 pools requantize to the same int8
        payload but the recomputed per-position scale can drift one float
        ulp (``(amax/127)*127``), so logprobs match to float tolerance —
        the same contract the disaggregated int8 transfer carries.

        ``continue_output=True`` (partial-rollout continuation) carries
        the suspended :class:`RequestOutput` forward — tokens, behaviour
        logprobs and per-token weight versions accumulate across the
        suspension instead of starting a fresh per-turn output.
        ``max_new_tokens`` grants a fresh per-turn budget (default: the
        handle's remaining budget) and ``stop_tokens`` replaces the
        request's boundary set (``()`` on the final turn lets the episode
        run to EOS instead of re-suspending; ``None`` inherits).  Returns
        the slot."""
        if sreq.released:
            raise RuntimeError(
                f"suspended rid {sreq.req.rid} was already released")
        tool = np.asarray(tool_tokens, np.int32).reshape(-1)
        if tool.size == 0 and not sreq.logits_valid \
                and not self._rollback_safe:
            raise RuntimeError(
                f"rid {sreq.req.rid} was truncated out of a fused decode "
                f"block and family {self.model.cfg.family!r} cannot replay "
                f"past recurrent state; resume with tool tokens")
        budget = (max_new_tokens if max_new_tokens is not None
                  else max(sreq.remaining, 1))
        prompt = (np.concatenate([sreq.history, tool])
                  if tool.size else sreq.history)
        src = sreq.req
        req = Request(rid=src.rid if rid is None else rid, prompt=prompt,
                      max_new_tokens=budget, arrival_time=src.arrival_time,
                      frontend=src.frontend, priority=src.priority,
                      deadline=src.deadline, job_id=src.job_id,
                      stop_tokens=(src.stop_tokens if stop_tokens is None
                                   else stop_tokens))
        if req.total_budget > self.config.max_seq_len:
            raise ValueError(
                f"resume of rid {req.rid}: history {sreq.index} + tool "
                f"{tool.size} + budget {budget} exceeds max_seq_len "
                f"{self.config.max_seq_len}")
        one = sreq.source._materialize(sreq)
        if tool.size:
            logits, one = self._fns["inject"](
                self.params, jnp.asarray(tool), one)
            version = self.weight_version
        elif not sreq.logits_valid:
            # the boundary logits were truncated out of a fused decode
            # block: replay the final history token one position back — a
            # pure KV overwrite on the materialized copy (attention masks
            # by index; recurrent families never get here, their stop
            # requests are gated to block_size=1)
            one["index"] = jnp.asarray(sreq.index - 1, jnp.int32)
            logits, one = self._fns["inject"](
                self.params, jnp.asarray(sreq.history[-1:]), one)
            version = self.weight_version
        else:
            # first resumed token samples from the captured boundary row —
            # across a carry_live weight sync that row is *stale*, which is
            # exactly the behaviour-provenance the version tracks
            logits = sreq.logits
            version = sreq.weight_version
        slot = self.admit_prefilled(req, logits, one)
        self._slot_version[slot] = version
        if continue_output:
            prev = sreq.out
            _, out = self._active[slot]
            out.prompt = prev.prompt
            out.tokens = list(prev.tokens)
            out.logprobs = list(prev.logprobs)
            out.token_versions = list(prev.token_versions)
            out.finish_reason = ""
            out.prefill_step = prev.prefill_step
            out.arrival_time = prev.arrival_time
            out.first_token_time = prev.first_token_time
            out.prefix_shared_blocks = prev.prefix_shared_blocks
            self._seed_tokens[slot] = len(out.tokens)
        sreq.release()
        self._stats.resumes += 1
        return slot

    def reset(self, params=None, rng: Optional[jax.Array] = None, *,
              carry_live: bool = False) -> None:
        """Prepare a drained engine for its next batch of requests: swap in
        freshly synced weights and a new key stream, and drop the previous
        batch's outputs.  This is how the mux trainer reuses one engine
        (and its jit cache) across GRPO iterations.

        ``carry_live=True`` is partial-rollout continuation: instead of
        requiring a drained engine, every live generation is suspended,
        the reset (weight swap, radix flush, policy reset) runs, and the
        suspended generations are resumed under the new weights with
        their outputs carried forward (mixed per-token weight versions —
        the clipped importance-ratio machinery sees the stale prefix).
        Queued-but-unadmitted requests simply stay queued; harvest
        completed outputs *before* the reset, they are dropped like any
        other reset."""
        carried: list[SuspendedRequest] = []
        if carry_live:
            for slot in sorted(self._active):
                carried.append(self._suspend_slot(slot))
        if self._active or (self.queue and not carry_live):
            raise RuntimeError("reset() on a live engine; drain or "
                               "export_state() first")
        if self.suspended and not carry_live:
            raise RuntimeError(
                f"reset() with {len(self.suspended)} suspended request(s) "
                f"still pinning the pool (rids "
                f"{sorted(self.suspended)!r}); resume or release them, or "
                f"reset(carry_live=True)")
        if params is not None:
            self.params = params
            self.weight_version += 1
        if rng is not None:
            self._rng = rng
        if self.radix is not None:
            # new weights invalidate every cached prefill (logits + KV)
            self.radix.flush()
        # the policy keeps its measured service-time state (the jit cache
        # is kept, so the compile-discard must NOT re-trigger) but drops
        # per-request bookkeeping: rids repeat across GRPO iterations, and
        # stale arrival seqs / skip counts would poison the next batch
        self.policy.on_reset()
        if self.paged:
            pins = [b for s in self.suspended.values() for b in s.block_ids]
            if pins:
                # suspended handles legitimately hold blocks: check exact
                # conservation against those pins instead of emptiness
                self.slots.check(extra_pins=pins)
            else:
                # an idle engine with a flushed radix must hold zero
                # blocks — any dangling refcount here is a leak that would
                # compound across iterations of a persistent engine
                self.slots.alloc.assert_clean(context="Engine.reset")
        self.finished.clear()
        self._unharvested.clear()
        for sreq in carried:
            self.resume(sreq, continue_output=True)

    def export_state(self) -> dict:
        """Checkpoint the live serving state mid-flight (drain of live
        slots): ``{"device": <array pytree>, "host": <bookkeeping>}``.

        The device part is a pure array pytree — exactly what a host-DRAM
        actor cache (``train.checkpoints.HostStateCache``) offloads when a
        co-executing job suspends between run permits.  The host part is a
        deep copy, so the snapshot stays valid however the engine runs on
        afterwards.  :meth:`import_state` on any engine with the same model
        and config resumes token-for-token.
        """
        device = {"cache": self.slots.cache,
                  "last_logits": self._last_logits,
                  "alive": self._alive,
                  "remaining": self._remaining,
                  "rng": self._rng}
        if self.suspended:
            # suspended handles split like radix entries: array pytrees in
            # the device section, metadata (deep-copied) in the host part;
            # the allocator pins they hold are already in the alloc state
            device["suspended"] = {
                rid: {"logits": s.logits, "one": s.one, "tail": s.tail,
                      "slot_leaves": s.slot_leaves}
                for rid, s in self.suspended.items()}
        slots: dict = {"owner": list(self.slots.owner),
                       "free": list(self.slots.free),
                       "events": list(self.slots.events)}
        if self.paged:
            a = self.slots.alloc
            slots.update(
                tables=self.slots.tables.copy(),
                nblocks=list(self.slots.nblocks),
                shared={s: list(v) for s, v in self.slots.shared.items()},
                alloc={"free": list(a.free),
                       "refcount": dict(a.refcount),
                       "quota": dict(a.quota),
                       "owned": {k: list(v) for k, v in a.owned.items()},
                       "events": list(a.events)})
        host = copy.deepcopy({
            "host_index": list(self._host_index),
            "active": dict(self._active),
            "queue": list(self.queue._q),
            "finished": dict(self.finished),
            "unharvested_rids": [o.rid for o in self._unharvested],
            "stats": self._stats,
            "slots": slots,
            "weight_version": self.weight_version,
            "slot_version": list(self._slot_version),
            "seed_tokens": dict(self._seed_tokens),
            "suspended": {
                rid: {"req": s.req, "out": s.out, "history": s.history,
                      "index": s.index, "remaining": s.remaining,
                      "block_ids": s.block_ids,
                      "weight_version": s.weight_version,
                      "logits_valid": s.logits_valid}
                for rid, s in self.suspended.items()},
            "newly_suspended": [s.req.rid for s in self._newly_suspended],
        })
        if self.radix is not None:
            # snapshot pytrees (logits/tail/slot rows) are device arrays:
            # they travel in the device section; the allocator pins the
            # tree nodes stand behind are already in the exported alloc
            # state, and the tree structure (parent links, tokens,
            # counters) is host data
            device["radix"] = self.radix.export_device_state()
            host["radix"] = self.radix.export_host_state()
        return {"device": device, "host": host}

    def import_state(self, state: dict) -> None:
        """Restore a :meth:`export_state` snapshot (device leaves may come
        back as host numpy arrays from an actor cache — they are re-put)."""
        dev = state["device"]
        self.slots.cache = jax.tree.map(jnp.asarray, dev["cache"])
        self._last_logits = jnp.asarray(dev["last_logits"])
        self._alive = jnp.asarray(dev["alive"])
        self._remaining = jnp.asarray(dev["remaining"])
        self._rng = jnp.asarray(dev["rng"])
        host = copy.deepcopy(state["host"])
        self._host_index = list(host["host_index"])
        self._active = dict(host["active"])
        self.queue._q.clear()
        self.queue._q.extend(host["queue"])
        self.finished = dict(host["finished"])
        self._unharvested = [self.finished[r]
                             for r in host.get("unharvested_rids", ())
                             if r in self.finished]
        self._stats = host["stats"]
        self.weight_version = host.get("weight_version", 0)
        self._slot_version = list(host.get(
            "slot_version", [0] * self.config.num_slots))
        self._seed_tokens = {int(k): int(v)
                             for k, v in host.get("seed_tokens", {}).items()}
        dev_susp = dev.get("suspended", {})
        self.suspended = {}
        for rid, m in host.get("suspended", {}).items():
            d = dev_susp[rid]
            self.suspended[int(rid)] = SuspendedRequest(
                m["req"], m["out"], m["history"], m["index"],
                m["remaining"], jnp.asarray(d["logits"]), source=self,
                logits_valid=m["logits_valid"], block_ids=m["block_ids"],
                tail=jax.tree.map(jnp.asarray, d["tail"]),
                slot_leaves=jax.tree.map(jnp.asarray, d["slot_leaves"]),
                one=(None if d["one"] is None
                     else jax.tree.map(jnp.asarray, d["one"])),
                weight_version=m["weight_version"])
        self._newly_suspended = [
            self.suspended[r] for r in host.get("newly_suspended", ())
            if r in self.suspended]
        sl = host["slots"]
        self.slots.owner = list(sl["owner"])
        self.slots.free = list(sl["free"])
        self.slots.events = list(sl["events"])
        if self.paged:
            self.slots.tables = sl["tables"].copy()
            self.slots.nblocks = list(sl["nblocks"])
            self.slots.shared = {int(s): list(v)
                                 for s, v in sl.get("shared", {}).items()}
            self.slots._dirty = True
            a = self.slots.alloc
            a.free = list(sl["alloc"]["free"])
            a.refcount = dict(sl["alloc"]["refcount"])
            a.quota = dict(sl["alloc"]["quota"])
            a.owned = {k: list(v) for k, v in sl["alloc"]["owned"].items()}
            a.events = list(sl["alloc"]["events"])
        if self.radix is not None:
            self.radix.import_state(
                host.get("radix"),
                jax.tree.map(jnp.asarray, state["device"].get("radix", {})))


def run_trace(engine: Engine, requests: list[Request],
              *, realtime: bool = True, controller=None) -> dict:
    """Replay a timed arrival trace through ``engine`` against the wall
    clock: each request is submitted once ``arrival_time`` (seconds from
    trace start) has elapsed, and per-request first-token / finish
    timestamps are recorded.  ``realtime=False`` fast-forwards idle gaps
    instead of sleeping through them: when the engine runs dry the next
    pending request is submitted immediately and its ``arrival_time`` is
    rebased to the current clock so latency/TTFT stay well-defined.
    Returns a report with latency, throughput and slot-utilization
    aggregates (the benchmark's raw material).

    ``controller`` (a ``serve.elastic.ElasticController``) closes the
    capacity loop: every arrival passes through its admission gate (which
    may shed it or clamp its decode budget), and between steps the
    controller may replace the engine with a resized one (live work
    carried over).  The returned report then carries an ``"elastic"``
    section — capacity-seconds, sheds/degrades, resize history."""
    pending = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
    t0 = time.perf_counter()
    engine.clock = lambda: time.perf_counter() - t0
    if controller is not None:
        controller.attach(engine, engine.clock())
    while pending or not engine.idle:
        now = engine.clock()
        while pending and pending[0].arrival_time <= now:
            req = pending[0]
            if controller is not None:
                verdict, req = controller.admit(req, now, engine)
                if verdict == "shed":
                    pending.pop(0)          # recorded by the controller —
                    continue                # shed, never silently dropped
            if not engine.submit(req):
                break                       # queue full: defer, retry after
            pending.pop(0)                  # the engine drains a bit
        if controller is not None:
            engine = controller.maybe_resize(engine, engine.clock())
        progressed = engine.step()
        if not progressed and pending:
            if realtime:
                # engine reported "no work": sleep the idle gap away in one
                # go — the next event is the head arrival, nothing else can
                # wake a single-threaded trace replay (no busy spin)
                wait = pending[0].arrival_time - engine.clock()
                if wait > 0:
                    time.sleep(wait)
            else:
                nxt = pending[0]
                nxt.arrival_time = engine.clock()
                if controller is not None:
                    verdict, nxt = controller.admit(nxt, engine.clock(),
                                                    engine)
                    if verdict == "shed":
                        pending.pop(0)
                        continue
                if engine.submit(nxt):
                    pending.pop(0)
    makespan = engine.clock()
    engine.clock = None
    outs = [engine.finished[r] for r in sorted(engine.finished)]
    lat = np.array([o.finish_time - o.arrival_time for o in outs])
    ttft = np.array([o.first_token_time - o.arrival_time for o in outs])
    n_tok = sum(o.num_tokens for o in outs)
    report = {
        "outputs": outs,
        "makespan_s": makespan,
        "tokens": n_tok,
        "tok_per_s": n_tok / max(makespan, 1e-9),
        "latency_mean_s": float(lat.mean()) if len(lat) else 0.0,
        "latency_p95_s": float(np.quantile(lat, 0.95)) if len(lat) else 0.0,
        "ttft_mean_s": float(ttft.mean()) if len(ttft) else 0.0,
        "slot_utilization": engine._stats.slot_utilization,
        "peak_active": engine._stats.peak_active,
        "rejected_submits": engine.queue.rejected,
    }
    with_dl = [o for o in outs if o.deadline is not None]
    if with_dl:
        met = sum(o.finish_time <= o.deadline for o in with_dl)
        report["deadline_total"] = len(with_dl)
        report["deadline_met"] = int(met)
        report["deadline_attainment"] = met / len(with_dl)
    if engine.paged:
        total = engine.slots.alloc.num_blocks
        report["kv_blocks_total"] = total
        report["peak_kv_blocks"] = engine._stats.peak_kv_blocks
        report["kv_block_utilization"] = (
            engine._stats.peak_kv_blocks / max(total, 1))
    if engine.radix is not None:
        report["prefix"] = dict(engine.radix.stats,
                                blocks_saved=engine._stats.blocks_saved,
                                hit_admits=engine._stats.prefix_hits)
    if controller is not None:
        report["elastic"] = controller.summary(makespan)
    return report
