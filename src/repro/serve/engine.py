"""Continuous-batching rollout engine (in-flight batching over a slot pool).

The engine services generation requests the way a rollout pool must under
heavy traffic: a FIFO :class:`~repro.serve.queue.RequestQueue` feeds a
fixed pool of KV-cache slots (:class:`~repro.serve.slots.SlotManager`);
each scheduler iteration first *prefills* waiting requests into free slots,
then runs one (or ``block_size`` fused) *decode* step(s) for every live
slot at once.  Requests therefore join and leave the decode batch
mid-flight: a slot is recycled the moment its request hits EOS or its
per-request decode budget, and the next queued request prefills into it —
no static-batch barrier, no head-of-line blocking on long generations.

Per-slot sequence positions are independent (the pool cache carries a
per-slot ``index`` vector); decode is the model's own single-token step
``vmap``-ped over slots, so engine output is mathematically the per-request
``rl.rollout.generate`` computation, token for token and logprob for
logprob (the equivalence ``tests/test_serve_engine.py`` asserts).

``block_size > 1`` fuses K decode steps into one jitted ``lax.scan`` to
amortise per-step dispatch (scheduling decisions then happen every K
tokens); ``block_size=1`` is exact per-token continuous batching.

Compilation notes: jitted prefill / admit / decode-block functions are
cached per (model, max_seq_len, temperature, eos_id) — engines with the
same serving shape share compilations (cheap to construct per trace), and
prefill additionally specialises on prompt length, so drivers should
bucket prompt lengths (the benchmark uses a handful of buckets).
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import tokenizer as tok
from repro.serve.queue import RequestQueue
from repro.serve.request import Request, RequestOutput
from repro.serve.slots import SlotManager, _batch_axis, insert_cache


@dataclass(frozen=True)
class EngineConfig:
    num_slots: int = 8
    max_seq_len: int = 256
    eos_id: int = tok.EOS
    temperature: float = 0.0          # 0 => greedy
    block_size: int = 1               # decode steps fused per scheduler tick
    max_waiting: Optional[int] = None

    def __post_init__(self):
        if self.num_slots < 1:
            raise ValueError("num_slots must be >= 1")
        if self.block_size < 1:
            raise ValueError("block_size must be >= 1")
        if self.max_seq_len < 2:
            raise ValueError("max_seq_len must cover prompt + decode")


@dataclass
class EngineStats:
    steps: int = 0                    # decode steps executed (all slots)
    blocks: int = 0                   # scheduler ticks that ran a decode
    prefills: int = 0
    recorded_tokens: int = 0          # useful (mask=1) tokens produced
    slot_steps: int = 0               # num_slots * steps (capacity offered)

    @property
    def slot_utilization(self) -> float:
        return self.recorded_tokens / max(self.slot_steps, 1)


@functools.lru_cache(maxsize=32)
def _engine_fns(model, max_seq_len: int, temperature: float, eos_id: int):
    """Jitted prefill / admit / decode-block shared by all engines with the
    same serving shape (keyed on the hashable frozen ``Model``)."""

    def prefill_fn(params, prompt, frontend):
        cache = model.init_cache(1, max_seq_len)
        logits, cache = model.prefill(params, prompt, cache,
                                      frontend=frontend)
        return logits[0], cache

    def admit_fn(params, prompt, frontend, pool, slot, last_logits, alive,
                 remaining, budget):
        """Prefill one request and splice it into slot ``slot`` — a single
        dispatch covering cache insert + logits/alive/budget row updates."""
        logits, one = prefill_fn(params, prompt, frontend)
        return (insert_cache(pool, one, slot),
                last_logits.at[slot].set(logits),
                alive.at[slot].set(True),
                remaining.at[slot].set(budget))

    cache_axes = {k: _batch_axis(k) for k in model.cache_logical_specs()}

    def decode_one(params, token, cache):
        # re-grow the batch=1 axis the vmap stripped, run the model's own
        # decode step, then strip it again for out_axes
        cache_b = {k: (v if k == "index" else v[:, None])
                   for k, v in cache.items()}
        logits, cache_b = model.decode_step(
            params, jnp.reshape(token, (1, 1)), cache_b)
        cache_o = {k: (v if k == "index" else v[:, 0])
                   for k, v in cache_b.items()}
        return logits[0], cache_o

    pool_decode = jax.vmap(decode_one, in_axes=(None, 0, cache_axes),
                           out_axes=(0, cache_axes))

    def sample(logits, key):
        if temperature == 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / temperature, axis=-1).astype(jnp.int32)

    def block_fn(params, last_logits, cache, alive, remaining, keys):
        def step(carry, key):
            logits, cache, alive, remaining = carry
            nxt = sample(logits, key)                       # (N,)
            logp = jax.nn.log_softmax(logits, -1)
            tok_logp = jnp.take_along_axis(logp, nxt[:, None], -1)[:, 0]
            rec = alive & (remaining > 0)
            logits, cache = pool_decode(params, nxt, cache)
            alive = alive & (nxt != eos_id)
            remaining = remaining - rec.astype(jnp.int32)
            return (logits, cache, alive, remaining), (nxt, tok_logp, rec)

        carry, out = jax.lax.scan(
            step, (last_logits, cache, alive, remaining), keys)
        return carry, out                   # out: (toks, logps, recs) (K,N)

    return jax.jit(admit_fn), jax.jit(block_fn)


class Engine:
    """Continuous-batching generation engine over a fixed slot pool."""

    def __init__(self, model, params, config: EngineConfig,
                 rng: Optional[jax.Array] = None):
        self.model = model
        self.params = params
        self.config = config
        self.queue = RequestQueue(config.max_waiting)
        self.slots = SlotManager(model, config.num_slots, config.max_seq_len)
        self._rng = rng if rng is not None else jax.random.PRNGKey(0)
        N = config.num_slots
        self._last_logits = jnp.zeros((N, model.cfg.vocab_size), jnp.float32)
        self._alive = jnp.zeros((N,), bool)
        self._remaining = jnp.zeros((N,), jnp.int32)
        self._zero_keys = jnp.zeros((config.block_size, 2), jnp.uint32)
        self._active: dict[int, tuple[Request, RequestOutput]] = {}
        self.finished: dict[int, RequestOutput] = {}
        self.stats = EngineStats()
        self.clock = None             # optional wall-clock for trace drivers
        self._admit_fn, self._block = _engine_fns(
            model, config.max_seq_len, config.temperature, config.eos_id)

    # ---- submission --------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.total_budget > self.config.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + budget "
                f"{req.max_new_tokens} exceeds max_seq_len "
                f"{self.config.max_seq_len}")
        self.queue.push(req)

    @property
    def num_active(self) -> int:
        return len(self._active)

    @property
    def idle(self) -> bool:
        return not self.queue and not self._active

    # ---- scheduler ---------------------------------------------------------
    def _admit(self) -> None:
        """Prefill queued requests into free slots (FIFO, lowest slot first)."""
        while self.queue and self.slots.num_free:
            req = self.queue.pop()
            slot = self.slots.assign(req.rid)
            (self.slots.cache, self._last_logits, self._alive,
             self._remaining) = self._admit_fn(
                self.params, jnp.asarray(req.prompt)[None], req.frontend,
                self.slots.cache, jnp.asarray(slot, jnp.int32),
                self._last_logits, self._alive, self._remaining,
                jnp.asarray(req.max_new_tokens, jnp.int32))
            out = RequestOutput(rid=req.rid, prompt=req.prompt,
                                prefill_step=self.stats.steps,
                                arrival_time=req.arrival_time)
            self._active[slot] = (req, out)
            self.stats.prefills += 1

    def _finalize(self, slot: int) -> None:
        req, out = self._active[slot]
        out.finish_reason = ("eos" if out.tokens and
                             out.tokens[-1] == self.config.eos_id else "length")
        out.finish_step = self.stats.steps
        if self.clock is not None:
            out.finish_time = self.clock()
        self.finished[req.rid] = out
        del self._active[slot]
        self.slots.release(slot)

    def step(self) -> bool:
        """One scheduler iteration: admit waiting requests, then run
        ``block_size`` decode steps for all slots.  Returns False if there
        was nothing to do (idle)."""
        self._admit()
        if not self._active:
            return False
        if self.config.temperature == 0:
            keys = self._zero_keys          # unused by greedy sampling
        else:
            self._rng, sub = jax.random.split(self._rng)
            keys = jax.random.split(sub, self.config.block_size)
        (self._last_logits, self.slots.cache, self._alive, self._remaining), \
            out = self._block(self.params, self._last_logits,
                              self.slots.cache, self._alive,
                              self._remaining, keys)
        toks, logps, recs, alive, remaining = jax.device_get(
            (*out, self._alive, self._remaining))
        K = self.config.block_size
        self.stats.steps += K
        self.stats.blocks += 1
        self.stats.slot_steps += K * self.config.num_slots
        for slot in list(self._active):
            _, o = self._active[slot]
            rec_col = recs[:, slot]
            n_rec = int(rec_col.sum())
            if n_rec:
                if not o.tokens and self.clock is not None:
                    o.first_token_time = self.clock()   # first token on host
                o.tokens.extend(int(t) for t in toks[rec_col, slot])
                o.logprobs.extend(float(l) for l in logps[rec_col, slot])
                self.stats.recorded_tokens += n_rec
            if (not alive[slot]) or remaining[slot] <= 0:
                self._finalize(slot)
        return True

    def run(self) -> list[RequestOutput]:
        """Drive the engine until queue and slots are empty; outputs by rid."""
        while not self.idle:
            self.step()
        return [self.finished[r] for r in sorted(self.finished)]


def run_trace(engine: Engine, requests: list[Request],
              *, realtime: bool = True) -> dict:
    """Replay a timed arrival trace through ``engine`` against the wall
    clock: each request is submitted once ``arrival_time`` (seconds from
    trace start) has elapsed, and per-request first-token / finish
    timestamps are recorded.  ``realtime=False`` fast-forwards idle gaps
    instead of sleeping through them: when the engine runs dry the next
    pending request is submitted immediately and its ``arrival_time`` is
    rebased to the current clock so latency/TTFT stay well-defined.
    Returns a report with latency, throughput and slot-utilization
    aggregates (the benchmark's raw material)."""
    pending = sorted(requests, key=lambda r: (r.arrival_time, r.rid))
    t0 = time.perf_counter()
    engine.clock = lambda: time.perf_counter() - t0
    while pending or not engine.idle:
        now = engine.clock()
        while pending and pending[0].arrival_time <= now:
            engine.submit(pending.pop(0))
        progressed = engine.step()
        if not progressed and pending:
            if realtime:
                wait = pending[0].arrival_time - engine.clock()
                if wait > 0:
                    time.sleep(min(wait, 0.01))
            else:
                nxt = pending.pop(0)
                nxt.arrival_time = engine.clock()
                engine.submit(nxt)
    makespan = engine.clock()
    engine.clock = None
    outs = [engine.finished[r] for r in sorted(engine.finished)]
    lat = np.array([o.finish_time - o.arrival_time for o in outs])
    ttft = np.array([o.first_token_time - o.arrival_time for o in outs])
    n_tok = sum(o.num_tokens for o in outs)
    return {
        "outputs": outs,
        "makespan_s": makespan,
        "tokens": n_tok,
        "tok_per_s": n_tok / max(makespan, 1e-9),
        "latency_mean_s": float(lat.mean()) if len(lat) else 0.0,
        "latency_p95_s": float(np.quantile(lat, 0.95)) if len(lat) else 0.0,
        "ttft_mean_s": float(ttft.mean()) if len(ttft) else 0.0,
        "slot_utilization": engine.stats.slot_utilization,
    }
