"""Request/response records for the continuous-batching rollout engine.

A :class:`Request` is one generation job: a token prompt plus per-request
decode budget (and optional sampling key / modality frontend embeddings).
The engine turns it into a :class:`RequestOutput` whose per-token behaviour
logprobs follow exactly the semantics of ``rl.rollout.generate`` — the
token that triggers EOS is still recorded (mask 1), everything after it is
dropped — so GRPO training consumes engine output unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np


@dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token sequence (already BOS'd / padded however
    the caller likes — the engine treats it verbatim, like ``generate`` does
    a batch row).  ``max_new_tokens`` is this request's decode budget;
    generation stops at the first EOS or when the budget is exhausted,
    whichever comes first.  ``arrival_time`` is only meaningful to trace
    drivers (see ``engine.run_trace``); the engine itself is clock-free.

    The admission-policy fields (``repro.serve.sched``) are all optional
    and ignored by ``FIFOPolicy``: ``priority`` breaks deadline ties
    (higher = more urgent), ``deadline`` is an absolute driver-clock time
    the request should finish by (``DeadlinePolicy`` orders admission by
    it; ``SLOPolicy`` derives one from the group's slowdown bound when
    unset), and ``job_id`` names the submitting job for per-job token
    budgets.  ``prefix_key`` is an optional prefix-sharing *isolation
    namespace*: the paged engine's radix tree (``repro.serve.radix``)
    shares prompt-prefix KV by token content, so requests share
    automatically when their prompts agree on a block-aligned prefix —
    set ``prefix_key`` only to wall a tenant off into its own tree
    (``None`` = the global namespace; equal keys share, distinct keys
    never do).

    ``stop_tokens`` turns the request multi-turn: sampling any of these
    ids does not *finish* the request — the engine records the trigger
    token (like EOS), **suspends** the request into a pinned
    ``SuspendedRequest`` handle and frees the slot for other work.  The
    agentic driver (``rl.agentic``) resumes it with the tool-result
    tokens appended.
    """
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_time: float = 0.0
    frontend: Optional[Any] = None       # (1, F, d) modality embeddings
    priority: int = 0                    # higher = more urgent (sched tiebreak)
    deadline: Optional[float] = None     # absolute driver-clock finish target
    prefix_key: Optional[Any] = None     # radix isolation namespace
    #                                      (None = global content sharing)
    job_id: Optional[str] = None         # submitting job (per-job budgets)
    stop_tokens: tuple = ()              # tool-boundary ids -> suspend, not
    #                                      finish (serve.engine suspend API)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")
        self.stop_tokens = tuple(int(t) for t in self.stop_tokens)

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_budget(self) -> int:
        return self.prompt_len + self.max_new_tokens


@dataclass
class RequestOutput:
    """Completed request: generated tokens + per-token behaviour logprobs.

    ``token_versions`` records, per generated token, the engine weight
    version whose logits the token was sampled from — the provenance
    partial-rollout continuation needs: a generation carried across a
    weight sync (``Engine.reset(carry_live=True)``) mixes versions, and
    the clipped importance-ratio diagnostics / ``--mux-staleness`` guard
    read the spread.  Single-sync generations have one version
    throughout."""
    rid: int
    prompt: np.ndarray
    tokens: list[int] = field(default_factory=list)
    logprobs: list[float] = field(default_factory=list)
    token_versions: list[int] = field(default_factory=list)
    finish_reason: str = ""              # "eos" | "length" ("stop" while
    #                                      suspended at a tool boundary)
    # trace timestamps (engine step counts and/or driver clock)
    prefill_step: int = -1
    finish_step: int = -1
    arrival_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0
    # admission metadata copied from the Request (trace/report material)
    priority: int = 0
    deadline: Optional[float] = None
    job_id: Optional[str] = None
    prefix_shared_blocks: int = 0        # KV blocks admitted via radix sharing

    @property
    def num_tokens(self) -> int:
        return len(self.tokens)
