"""Request/response records for the continuous-batching rollout engine.

A :class:`Request` is one generation job: a token prompt plus per-request
decode budget (and optional sampling key / modality frontend embeddings).
The engine turns it into a :class:`RequestOutput` whose per-token behaviour
logprobs follow exactly the semantics of ``rl.rollout.generate`` — the
token that triggers EOS is still recorded (mask 1), everything after it is
dropped — so GRPO training consumes engine output unchanged.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np


@dataclass
class Request:
    """One generation request.

    ``prompt`` is a 1-D int32 token sequence (already BOS'd / padded however
    the caller likes — the engine treats it verbatim, like ``generate`` does
    a batch row).  ``max_new_tokens`` is this request's decode budget;
    generation stops at the first EOS or when the budget is exhausted,
    whichever comes first.  ``arrival_time`` is only meaningful to trace
    drivers (see ``engine.run_trace``); the engine itself is clock-free.
    """
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    arrival_time: float = 0.0
    frontend: Optional[Any] = None       # (1, F, d) modality embeddings

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32).reshape(-1)
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def total_budget(self) -> int:
        return self.prompt_len + self.max_new_tokens


@dataclass
class RequestOutput:
    """Completed request: generated tokens + per-token behaviour logprobs."""
    rid: int
    prompt: np.ndarray
    tokens: list[int] = field(default_factory=list)
    logprobs: list[float] = field(default_factory=list)
    finish_reason: str = ""              # "eos" | "length"
    # trace timestamps (engine step counts and/or driver clock)
    prefill_step: int = -1
    finish_step: int = -1
    arrival_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0

    @property
    def num_tokens(self) -> int:
        return len(self.tokens)
