"""Disaggregated prefill: a dedicated prefill engine that hands finished
prompts to a decode engine as ref-counted KV transfer handles.

The paper's premise is phase disaggregation onto purpose-built pools;
this module applies it *within* the serving path (the vLLM/SGLang
production shape): prompt prefill is compute-bound and burst-shaped,
decode is memory-bandwidth-bound and steady, so each gets its own engine
with an independently sized slot + block pool.  In-process to start —
the router (:mod:`repro.serve.router`) moves handles between two pools
on one device — but the handle protocol is exactly what a multi-host
split needs: everything the decode side requires travels in the handle.

The zero-copy trick rides the paged layout's ref-counting
(:class:`~repro.serve.blocks.BlockAllocator`):

* the prefill engine admits a request (admission policy still applies),
  prefills into a transient slot of its *own* pool, and snapshots the
  admit state exactly like radix registration does — partial tail block
  + slot-resident rows + post-prompt logits;
* the prompt's **full** blocks are then pinned (``incref``) under a
  :class:`KVTransferHandle` and the donor slot is released immediately —
  the slot (and the tail block, whose content lives in the snapshot) is
  recycled for the next prefill while the full blocks stay resident in
  the prefill pool, un-copied, until the decode engine adopts or drops
  the handle.  Un-adopted handles are therefore the prefill pool's
  natural backpressure: admission gates on uncommitted blocks, so a slow
  decode side throttles prefill by occupancy, not by a side channel;
* adoption (:meth:`PrefillEngine.export_cache` +
  ``Engine.admit_prefilled``) gathers the pinned blocks through a padded
  table row — a permutation copy, the same ``gather_blocks`` decode
  itself uses — splices the tail snapshot back in, and scatters the
  result into a fresh slot of the *decode* pool; the handle's pins are
  then dropped.  Greedy tokens/logprobs are bit-identical to the
  monolithic engine: every array the decode side starts from is the
  prefill output moved by pure copies, and the decode computation is the
  same jitted code.

With ``prefix_share`` the prefill engine keeps a content-addressed
radix tree over its own pool: the first request with a given prompt
prefills and registers, an exact repeat becomes a handle *without any
model compute* from the boundary snapshot, and a request sharing only a
block-aligned prefix (same system preamble, longer conversation) pins
the matching blocks and prefills just its extension through a
write-masked row — no tag required; ``prefix_key`` is an optional
isolation namespace.  The
contiguous layout disaggregates too, with the handle carrying the whole
batch=1 prefill cache (there is no block pool to pin, so "transfer" is
an array hand-over; slots bound how many un-adopted handles may be
resident).  Families with no paged leaves (rwkv6) degenerate the same
way: state rides entirely in the slot-leaf snapshot.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import gather_blocks
from repro.serve.engine import (EngineConfig, EngineStats, _engine_fns,
                                _paged_engine_fns)
from repro.serve.queue import RequestQueue
from repro.serve.radix import RadixPrefixIndex
from repro.serve.request import Request
from repro.serve.sched import make_policy
from repro.serve.slots import PagedSlotManager


class KVTransferHandle:
    """Everything the decode engine needs to adopt one finished prompt.

    Paged: ``block_ids`` are the prompt's full blocks, resident in the
    *prefill* pool and pinned (one ``incref`` each) on behalf of this
    handle; ``tail``/``slot_leaves``/``logits`` are the same device
    snapshot a radix entry carries.  Contiguous: ``one`` is the whole
    batch=1 prefill cache and ``block_ids`` is empty.

    :meth:`release` drops the pins exactly once — it is idempotent, so a
    handle dropped mid-flight (decode side gone, reset, rebalance) can be
    released by whoever notices without double-decref risk.  The block
    conservation invariant (``free + live == num_blocks``, no dangling
    refcounts) must hold again once every handle is released; the prefill
    engine's ``reset`` asserts it.
    """

    __slots__ = ("req", "logits", "block_ids", "tail", "slot_leaves",
                 "one", "source", "prefill_time_s", "from_prefix_hit",
                 "released")

    def __init__(self, req: Request, logits, block_ids, tail, slot_leaves,
                 *, source, one=None, prefill_time_s: float = 0.0,
                 from_prefix_hit: bool = False):
        self.req = req
        self.logits = logits
        self.block_ids = tuple(int(b) for b in block_ids)
        self.tail = tail
        self.slot_leaves = slot_leaves
        self.one = one                      # contiguous: full batch=1 cache
        self.source = source                # the PrefillEngine holding pins
        self.prefill_time_s = prefill_time_s
        self.from_prefix_hit = from_prefix_hit
        self.released = False

    def release(self) -> None:
        """Drop this handle's pins in the prefill pool (idempotent)."""
        if self.released:
            return
        self.released = True
        self.source._release_handle(self)
        # drop the array refs so the snapshot memory can be collected
        self.one = None
        self.tail = {}
        self.slot_leaves = {}


@functools.lru_cache(maxsize=32)
def _transfer_fns(model, max_seq_len: int, kv_block_size: int,
                  kv_dtype=None):
    """Jitted handle-adoption gather, shared per serving shape.

    ``fetch`` materializes a batch=1 prefill-shaped cache view from the
    prefill pool: gather the pinned full blocks through a null-padded
    table row into a contiguous sequence, then splice the tail snapshot
    over the first partial block.  Positions beyond the prompt gather
    whatever the null block holds — junk by design, exactly like a dead
    slot's writes: decode never reads a position before writing it, so
    the adopted slot is value-identical to a monolithic prefill
    everywhere it matters.  Pure copies, no arithmetic — bit-exact.

    ``kv_dtype="int8"`` is the one exception to "no arithmetic": the
    handle's pinned blocks live quantized in the prefill pool, so fetch
    gathers their per-position scales too and dequantizes — the
    interchange format stays a float prefill-shaped cache either way,
    and the decode engine's scatter re-quantizes on the block write.
    Quantizing an already-dequantized block reproduces the same int8
    payload and scale (the max-magnitude position pins the scale), so
    adopted blocks in the decode pool are still bit-identical to a
    monolithic int8 admit.
    """
    from repro.models import kvcache
    SUF = kvcache.SCALE_SUFFIX
    quant = kv_dtype == "int8"
    view_dtype = jnp.dtype(model.cfg.dtype)

    def fetch_fn(src_leaves, table_row, tails, n_full):
        out = {}
        for name, pool in src_leaves.items():
            if name.endswith(SUF):
                continue                    # consumed beside the parent leaf
            # (L, max_blocks * block_size, *rest) contiguous sequence view
            seq = gather_blocks(pool, table_row, axis=1)
            if quant:
                s = gather_blocks(src_leaves[name + SUF], table_row, axis=1)
                seq = kvcache.dequantize_kv(seq, s, view_dtype)
            if name in tails:
                # tail snapshots are float (taken from the prefill output
                # before any block write), so the splice happens in float
                seq = jax.lax.dynamic_update_slice_in_dim(
                    seq, tails[name].astype(seq.dtype),
                    n_full * kv_block_size, axis=1)
            out[name] = seq[:, None]        # re-grow the batch=1 axis
        return out

    return {"fetch": jax.jit(fetch_fn)}


class PrefillEngine:
    """Prompt-only engine: admits requests under a scheduler policy,
    prefills them into its own pool, and emits :class:`KVTransferHandle`\\ s.

    ``config.num_slots`` bounds prefills per scheduler tick (paged — the
    donor slot is transient) or resident un-adopted handles (contiguous —
    each handle holds a full cache stripe).  ``config.num_kv_blocks``
    sizes the paged pool that un-adopted handles and the radix index
    occupy: the independent knob the router's pool-ratio sweep turns.
    """

    def __init__(self, model, params, config: EngineConfig, policy=None):
        self.model = model
        self.params = params
        self.config = config
        self.queue = RequestQueue(config.max_waiting)
        self.policy = policy if policy is not None else \
            make_policy(config.sched)
        self.paged = config.kv_layout == "paged"
        # mirror Engine's backend resolution so the fns cache entry is
        # shared with the decode engine it feeds; prefill itself never
        # decodes, so unsupported families just fall back quietly here
        kb = config.kernel_backend
        if kb == "pallas" and not model.kernel_supported():
            kb = "jnp"
        self.kernel_backend = kb
        self._kv_dtype = (None if config.kv_dtype == "auto"
                          else config.kv_dtype)
        if kb == "pallas":
            from repro.kernels.ops import resolve_interpret
            interp = resolve_interpret()
        else:
            interp = True
        if self.paged:
            self.slots = PagedSlotManager(
                model, config.num_slots, config.max_seq_len,
                block_size=config.kv_block_size,
                num_blocks=config.num_kv_blocks,
                kv_dtype=self._kv_dtype)
            self._fns = _paged_engine_fns(
                model, config.max_seq_len, config.kv_block_size,
                config.temperature, config.eos_id,
                kernel_backend=kb, kv_dtype=self._kv_dtype,
                interpret=interp)
            self._xfer = _transfer_fns(model, config.max_seq_len,
                                       config.kv_block_size,
                                       kv_dtype=self._kv_dtype)
            N = config.num_slots
            # dummy per-slot rows the shared scatter fn updates; the
            # prefill engine never decodes, so they are write-only
            self._last_logits = jnp.zeros((N, model.cfg.vocab_size),
                                          jnp.float32)
            self._alive = jnp.zeros((N,), bool)
            self._remaining = jnp.zeros((N,), jnp.int32)
        else:
            # contiguous: prefill produces a self-contained batch=1 cache,
            # so there is no donor pool — capacity is resident handles
            self.slots = None
            self._fns = _engine_fns(
                model, config.max_seq_len, config.temperature, config.eos_id,
                kernel_backend=kb, interpret=interp)
        self.radix = (RadixPrefixIndex(self.slots.alloc)
                      if config.prefix_share else None)
        self.ready: list[KVTransferHandle] = []
        self.resident = 0                   # handles created, not released
        self.stats = EngineStats()
        self.clock = None

    # ---- submission --------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Enqueue; ``False`` = queue full (backpressure, same contract as
        ``Engine.submit``).  Only prompt-side limits are validated here —
        the router checks the decode side before delegating."""
        if req.prompt_len > self.config.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} exceeds "
                f"prefill max_seq_len {self.config.max_seq_len}")
        if self.paged:
            need = self.slots.blocks_required(req.prompt_len)
            if need > self.slots.alloc.num_blocks:
                raise ValueError(
                    f"request {req.rid}: prompt needs {need} KV blocks but "
                    f"the prefill pool has {self.slots.alloc.num_blocks}")
        return self.queue.push(req)

    @property
    def idle(self) -> bool:
        return not self.queue

    # ---- admission ---------------------------------------------------------
    def _match(self, req: Request, *, count: bool = False):
        """Radix lookup (``None`` with sharing off, frontend-conditioned
        requests, or no match).  ``count=True`` marks the admission
        lookup — the index owns all hit/partial/miss counters."""
        if self.radix is None or req.frontend is not None:
            return None
        return self.radix.match(req, count=count)

    def _can_admit(self, req: Request) -> bool:
        """Prefill-side admission gate: enough uncommitted blocks for the
        *prompt* (the decode budget is the decode pool's problem), net of
        prefix-shared blocks.  Exact radix hits cost no compute and no
        new blocks, so they are always admissible.  Under pressure —
        pinned handles waiting for adoption plus tree pins — the index
        LRU-evicts (sparing this request's own match path) before giving
        up."""
        m = self._match(req)
        if m is not None and m.exact:
            return True
        if not self.paged:
            return self.resident < self.config.num_slots
        if not self.slots.num_free:
            return False
        n_shared = m.n_shared if m is not None else 0
        if self.slots.can_admit(req.prompt_len, shared_blocks=n_shared):
            return True
        if self.radix is not None and len(self.radix):
            need = max(self.slots.blocks_required(req.prompt_len)
                       - n_shared, 0)
            if self.radix.evict_for(
                    need, protect=m.node_ids if m is not None else ()):
                return True
            # last resort: drop the match path too and admit unshared
            return self.radix.evict_for(
                self.slots.blocks_required(req.prompt_len))
        return False

    def step(self) -> int:
        """One prefill tick: admit and prefill up to ``num_slots`` picked
        requests, appending a handle per prompt to :attr:`ready`.  Returns
        the number of handles produced (0 = nothing admissible)."""
        made = 0
        now = self.clock() if self.clock is not None else 0.0
        while self.queue and made < self.config.num_slots:
            idx = self.policy.pick(self.queue, self._can_admit, now=now,
                                   live_tokens={})
            if idx is None:
                break
            req = self.queue.pop_at(idx)
            self.ready.append(self._prefill_one(req))
            made += 1
        return made

    def pop_ready(self) -> list[KVTransferHandle]:
        out, self.ready = self.ready, []
        return out

    def _prefill_one(self, req: Request) -> KVTransferHandle:
        t0 = time.perf_counter()
        m = self._match(req, count=True)
        if m is not None and m.exact:
            # zero-compute handle straight from the boundary snapshot: pin
            # the path's blocks under the handle (the tree keeps its own
            # pin per node)
            self.radix.touch(m)
            snap = m.snapshot
            block_ids = tuple(m.block_ids)
            for bid in block_ids:
                self.slots.alloc.incref(bid)
            self.stats.prefix_hits += 1
            self.stats.blocks_saved += len(block_ids)
            handle = KVTransferHandle(
                req, snap.logits, block_ids, dict(snap.tail),
                dict(snap.slot_leaves), source=self,
                prefill_time_s=time.perf_counter() - t0,
                from_prefix_hit=True)
        elif not self.paged:
            prompt_dev = jnp.asarray(req.prompt)[None]
            logits, one = self._fns["prefill"](self.params, prompt_dev,
                                               req.frontend)
            handle = KVTransferHandle(req, logits, (), {}, {}, source=self,
                                      one=one,
                                      prefill_time_s=time.perf_counter() - t0)
        else:
            handle = self._prefill_paged(req, t0, m)
        self.resident += 1
        self.stats.prefills += 1
        if self.paged:
            self.stats.peak_kv_blocks = max(self.stats.peak_kv_blocks,
                                            self.slots.blocks_in_use)
        return handle

    def _prefill_paged(self, req: Request, t0: float,
                       m=None) -> KVTransferHandle:
        """Donor / partial-sharing path: prefill into a transient slot,
        snapshot, pin the full blocks under the handle, and recycle the
        slot without copying.  With a partial radix match the matching
        full blocks are pinned instead of allocated and the scatter runs
        through a write-masked row, so only the extension is computed
        into fresh blocks."""
        prompt_dev = jnp.asarray(req.prompt)[None]
        n_shared = m.n_shared if m is not None else 0
        if n_shared:
            self.radix.touch(m)
            slot = self.slots.assign_shared(
                req.rid, prompt_len=req.prompt_len,
                total_budget=req.prompt_len, shared_ids=m.block_ids)
            masked = self.slots.tables[slot].copy()
            masked[:n_shared] = 0       # shared blocks -> null (no write)
            row = jnp.asarray(masked)
            self.stats.prefix_partial_hits += 1
            self.stats.blocks_saved += n_shared
        else:
            slot = self.slots.assign(req.rid, prompt_len=req.prompt_len,
                                     total_budget=req.prompt_len)
            row = self.slots.device_tables()[slot]
        logits, one = self._fns["prefill"](self.params, prompt_dev,
                                           req.frontend)
        (self.slots.cache, self._last_logits, self._alive,
         self._remaining) = self._fns["scatter"](
            logits, one, self.slots.cache, row, jnp.asarray(slot, jnp.int32),
            self._last_logits, self._alive, self._remaining,
            jnp.asarray(0, jnp.int32))
        bs = self.config.kv_block_size
        n_full = (req.prompt_len // bs) if self.slots.paged_names else 0
        tail_block = n_full if req.prompt_len % bs else None
        tail, slot_leaves = self._fns["snapshot"](one, tail_block=tail_block)
        if not self.slots.paged_names:
            tail = {}
        if self.radix is not None and req.frontend is None:
            self.radix.register(
                req, [int(b) for b in self.slots.tables[slot, :n_full]],
                logits=logits, tail=tail, slot_leaves=slot_leaves)
        pinned = self.slots.pin_prefix(slot, n_full)
        self.slots.release(slot)        # tail block freed: it lives in `tail`
        return KVTransferHandle(req, logits, pinned, tail, slot_leaves,
                                source=self,
                                prefill_time_s=time.perf_counter() - t0)

    # ---- adoption / release ------------------------------------------------
    def export_cache(self, handle: KVTransferHandle) -> dict:
        """Materialize the batch=1 cache pytree the decode engine's scatter
        consumes — the transfer itself.  Paged: gather the pinned blocks
        from this pool + splice the tail snapshot (a jitted permutation
        copy).  Contiguous: the handle already carries the cache."""
        if handle.released:
            raise RuntimeError(
                f"handle for rid {handle.req.rid} was already released")
        if not self.paged:
            return handle.one
        one = dict(handle.slot_leaves)
        one["index"] = jnp.asarray(handle.req.prompt_len, jnp.int32)
        if self.slots.paged_names:
            row = np.zeros((self.slots.max_blocks,), np.int32)
            row[:len(handle.block_ids)] = handle.block_ids
            src = {name: self.slots.cache[name]
                   for name in self.slots.paged_names}
            if self._kv_dtype == "int8":
                src.update({name: self.slots.cache[name]
                            for name in self.model.scale_cache_names()})
            n_full = handle.req.prompt_len // self.config.kv_block_size
            one.update(self._xfer["fetch"](
                src, jnp.asarray(row), handle.tail,
                jnp.asarray(n_full, jnp.int32)))
        return one

    def _release_handle(self, handle: KVTransferHandle) -> None:
        for bid in handle.block_ids:
            self.slots.alloc.decref(bid)
        self.resident -= 1

    # ---- suspend / resume --------------------------------------------------
    def reset(self, params=None) -> None:
        """Swap weights between batches.  Requires the queue drained and
        every handle released (adopted or dropped); asserts the block pool
        is leak-free afterwards — the same conservation invariant
        ``Engine.reset`` enforces, extended over handle pins."""
        if self.queue or self.ready:
            raise RuntimeError("reset() on a live prefill engine; drain or "
                               "drop pending handles first")
        if self.resident:
            raise RuntimeError(
                f"reset() with {self.resident} un-released transfer "
                f"handle(s) still pinning the prefill pool")
        if params is not None:
            self.params = params
        if self.radix is not None:
            self.radix.flush()
        self.policy.on_reset()
        if self.paged:
            self.slots.alloc.assert_clean(context="PrefillEngine.reset")
