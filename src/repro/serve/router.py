"""Prefill/decode disaggregation router.

:class:`DisaggRouter` composes a :class:`~repro.serve.disagg.PrefillEngine`
and a decode :class:`~repro.serve.engine.Engine` over *independently sized*
slot + block pools (:class:`DisaggConfig`) and moves
:class:`~repro.serve.disagg.KVTransferHandle`\\ s between them:

::

    submit ──> [queue] ──policy──> PrefillEngine ──handle──> (transfer
               ^                    pool P slots,             queue)
               |                    P blocks                     |
               └── backpressure ◄── pinned blocks                v
                                                   Engine.admit_prefilled
                                                    decode pool D slots,
                                                    D blocks ──> finished

Each scheduler tick runs prefill admission (the configured policy picks
from the shared waiting queue), adopts as many ready handles as the
decode pool can admit (FIFO in completion order — admission order never
changes *what* a request decodes, only when, so output stays bit-exact
for every policy), then one decode tick.  Un-adopted handles pin prefill
blocks, which throttles prefill admission when decode falls behind — the
two pool sizes are the only knobs, exactly the heterogeneous-pool shape
the paper gives rollout vs training.

The router duck-types the ``Engine`` surface that ``run_trace``,
``generate_continuous`` and the streaming executor drive (``submit`` /
``step`` / ``idle`` / ``harvest`` / ``finished`` / ``stats`` / ``reset``),
so every existing driver works unchanged with ``disagg=...``.

The KV transfer is **planner-visible**: pass a
:class:`~repro.core.phase_control.RollMuxRuntime` and each adoption runs
under a ``runtime.permit("transfer", "<job>:transfer")`` scope, so the
co-execution DES sees transfer occupancy as a phase timeline alongside
rollout/train/reward (``phase_profiles(transfer_pool="transfer")`` folds
it into the job's rollout-side critical path).
"""
from __future__ import annotations

import contextlib
import copy
import time
from collections import deque
from dataclasses import dataclass
from typing import Optional

from repro.core.telemetry import MetricsSnapshot, warn_legacy_once
from repro.data import tokenizer as tok
from repro.serve.disagg import KVTransferHandle, PrefillEngine
from repro.serve.engine import Engine, EngineConfig
from repro.serve.sched import make_policy

# DisaggRouter.stats legacy-shim warn-once flag (mutable so tests reset it)
_warned_legacy = [False]


@dataclass(frozen=True)
class DisaggConfig:
    """Two-pool serving shape: one ``max_seq_len``/layout/sampler contract,
    independently sized prefill and decode pools.

    ``prefill_slots`` bounds prefills per tick (paged) or resident
    un-adopted handles (contiguous); ``prefill_kv_blocks`` sizes the pool
    those handles pin.  ``decode_slots``/``decode_kv_blocks`` size the
    decode engine exactly like a monolithic ``EngineConfig`` would.  The
    admission policy (``sched``) runs on the prefill side — that is where
    requests wait; ``prefix_share`` builds the content-addressed radix
    tree over each prefill pool (exact repeats become zero-compute
    handles, block-aligned prefix overlaps prefill only their
    extension).

    ``prefill_engines`` scales the prefill side out: each engine gets its
    own full-size slot/block pools *and its own radix tree*, and the
    router steers each request with ``kv_routing`` — ``"kv_aware"``
    (default) scores every engine by how many prompt blocks its tree
    already holds and sends the request to the longest prefix
    (production-stack's ``kvaware_routing``), falling back to the least
    loaded; ``"queue"`` ignores KV residency and balances purely on
    queue depth + resident handles."""
    prefill_slots: int = 2
    decode_slots: int = 8
    max_seq_len: int = 256
    eos_id: int = tok.EOS
    temperature: float = 0.0
    block_size: int = 1
    max_waiting: Optional[int] = None
    kv_layout: str = "contiguous"
    kv_block_size: int = 16
    prefill_kv_blocks: Optional[int] = None
    decode_kv_blocks: Optional[int] = None
    sched: str = "fifo"
    prefix_share: bool = False
    prefill_engines: int = 1        # parallel prefill pools (each full-size)
    kv_routing: str = "kv_aware"    # "kv_aware" | "queue" steering between
    #                                 prefill engines (moot with one engine)
    kernel_backend: str = "jnp"     # decode-step backend for BOTH pools
    kv_dtype: Optional[str] = None  # paged KV storage dtype for BOTH pools
    #                                 (the handle interchange stays float)

    def prefill_config(self) -> EngineConfig:
        return EngineConfig(
            num_slots=self.prefill_slots, max_seq_len=self.max_seq_len,
            eos_id=self.eos_id, temperature=self.temperature,
            block_size=self.block_size, max_waiting=self.max_waiting,
            kv_layout=self.kv_layout, kv_block_size=self.kv_block_size,
            num_kv_blocks=self.prefill_kv_blocks, sched=self.sched,
            prefix_share=self.prefix_share,
            kernel_backend=self.kernel_backend, kv_dtype=self.kv_dtype)

    def decode_config(self) -> EngineConfig:
        # the decode engine is fed adopted handles, never a policy-ordered
        # queue, and adopted prompts bypass prefix lookup by construction
        return EngineConfig(
            num_slots=self.decode_slots, max_seq_len=self.max_seq_len,
            eos_id=self.eos_id, temperature=self.temperature,
            block_size=self.block_size, kv_layout=self.kv_layout,
            kv_block_size=self.kv_block_size,
            num_kv_blocks=self.decode_kv_blocks, sched="fifo",
            prefix_share=False,
            kernel_backend=self.kernel_backend, kv_dtype=self.kv_dtype)


class RouterStats:
    """Transfer counters + delegation to the two engines' stats, presenting
    the single-engine surface trace drivers read."""

    def __init__(self, router: "DisaggRouter"):
        self._router = router
        self.transfers = 0
        self.transfer_time_s = 0.0
        self.transferred_blocks = 0
        self.kv_routed = 0          # requests steered to a non-empty prefix

    @property
    def transfer_overhead_frac(self) -> float:
        """Transfer wall time as a fraction of transfer + decode time —
        guarded against the zero-decode-steps trace (nothing served)."""
        busy = self.transfer_time_s + self._router.decode._stats.decode_time_s
        if busy <= 0.0:
            return 0.0
        return self.transfer_time_s / busy

    # -- decode-side delegation (what run_trace reads) ----------------------
    @property
    def steps(self):
        return self._router.decode._stats.steps

    @property
    def decode_time_s(self):
        return self._router.decode._stats.decode_time_s

    @property
    def time_per_token(self):
        return self._router.decode._stats.time_per_token

    @property
    def slot_utilization(self):
        return self._router.decode._stats.slot_utilization

    @property
    def peak_active(self):
        return self._router.decode._stats.peak_active

    @property
    def peak_kv_blocks(self):
        return self._router.decode._stats.peak_kv_blocks

    @property
    def recorded_tokens(self):
        return self._router.decode._stats.recorded_tokens

    # -- prefill-side delegation (summed across prefill engines) ------------
    @property
    def prefills(self):
        return sum(pe.stats.prefills for pe in self._router.prefills)

    @property
    def prefix_hits(self):
        return sum(pe.stats.prefix_hits for pe in self._router.prefills)

    @property
    def prefix_partial_hits(self):
        return sum(pe.stats.prefix_partial_hits
                   for pe in self._router.prefills)

    @property
    def blocks_saved(self):
        return sum(pe.stats.blocks_saved for pe in self._router.prefills)


class DisaggRouter:
    """Drive one request stream through disaggregated prefill/decode pools.

    ``runtime``/``job_id`` make each KV transfer a planner-visible phase
    (see module docstring); both default to the in-process fast path with
    a local :attr:`transfer_timeline` either way.
    """

    def __init__(self, model, params, config: DisaggConfig, rng=None,
                 policy=None, runtime=None, job_id: Optional[str] = None):
        self.model = model
        self.config = config
        if config.prefill_engines < 1:
            raise ValueError(
                f"prefill_engines must be >= 1, got {config.prefill_engines}")
        if config.kv_routing not in ("kv_aware", "queue"):
            raise ValueError(
                f"kv_routing must be 'kv_aware' or 'queue', "
                f"got {config.kv_routing!r}")
        # ONE policy object drives every prefill queue: per-job token
        # budgets and the SLO service-time estimate are router-global, and
        # the deadline policies prune per-queue (keyed on queue identity)
        # so multi-queue sharing is safe.  A caller-supplied policy is
        # shared the same way.
        shared_policy = policy if policy is not None \
            else make_policy(config.sched)
        self.prefills = [
            PrefillEngine(model, params, config.prefill_config(),
                          policy=shared_policy)
            for _ in range(config.prefill_engines)]
        self.decode = Engine(model, params, config.decode_config(), rng=rng)
        self.pending_transfer: deque[KVTransferHandle] = deque()
        self.runtime = runtime
        self.job_id = job_id
        self._stats = RouterStats(self)
        self.transfer_timeline: list[tuple[str, float, float]] = []
        self._clock = None

    # ---- telemetry ---------------------------------------------------------
    @property
    def stats(self) -> RouterStats:
        """Deprecated stats facade — use :meth:`metrics` (the unified
        ``core.telemetry.MetricsSnapshot`` API).  Warn-once shim."""
        warn_legacy_once(
            _warned_legacy,
            "DisaggRouter.stats is deprecated; read the unified telemetry "
            "via DisaggRouter.metrics() (core.telemetry.MetricsSnapshot)")
        return self._stats

    def metrics(self) -> MetricsSnapshot:
        """One merged :class:`~repro.core.telemetry.MetricsSnapshot` across
        both planes: the decode engine's snapshot, prefill-side counters
        summed over all prefill engines, and the router's own transfer
        counters + backlog gauge."""
        snap = self.decode.metrics()
        snap.source = "router"
        for pe in self.prefills:
            s = pe.stats                    # PrefillEngine: plain record
            snap.prefills += s.prefills
            snap.prefix_hits += s.prefix_hits
            snap.prefix_partial_hits += s.prefix_partial_hits
            snap.blocks_saved += s.blocks_saved
            snap.queue_depth += len(pe.queue)
            snap.rejected_submits += pe.queue.rejected
            if pe.radix is not None:
                rs = pe.radix.stats
                snap.prefix_misses += rs["misses"]
                snap.prefix_evictions += rs["evictions"]
                snap.pinned_blocks += rs["pinned_blocks"]
                snap.prefix_snapshots += rs["snapshots"]
                snap.snapshot_demotions += rs["snapshot_demotions"]
        snap.transfers = self._stats.transfers
        snap.transfer_time_s = self._stats.transfer_time_s
        snap.transferred_blocks = self._stats.transferred_blocks
        snap.transfer_backlog = len(self.pending_transfer)
        snap.kv_routed = self._stats.kv_routed
        return snap

    # ---- Engine surface ----------------------------------------------------
    @property
    def prefill(self):
        """First prefill engine — the single-engine surface existing
        callers (and single-engine configs) read."""
        return self.prefills[0]

    @property
    def clock(self):
        return self._clock

    @clock.setter
    def clock(self, fn):
        self._clock = fn
        for pe in self.prefills:
            pe.clock = fn
        self.decode.clock = fn

    @property
    def params(self):
        return self.decode.params

    @property
    def paged(self) -> bool:
        return self.decode.paged

    @property
    def slots(self):
        return self.decode.slots

    @property
    def radix(self):
        return self.prefill.radix

    @property
    def queue(self):
        return self.prefill.queue

    @property
    def finished(self):
        return self.decode.finished

    @property
    def num_active(self) -> int:
        return self.decode.num_active + len(self.pending_transfer)

    @property
    def idle(self) -> bool:
        return (not any(pe.queue for pe in self.prefills)
                and not self.pending_transfer and self.decode.idle)

    def harvest(self):
        return self.decode.harvest()

    def submit(self, req) -> bool:
        """Validate against *both* pools, then enqueue on the prefill side.
        A request too big for either pool can never be served and raises;
        a full queue returns ``False`` (backpressure)."""
        if req.total_budget > self.config.max_seq_len:
            raise ValueError(
                f"request {req.rid}: prompt {req.prompt_len} + budget "
                f"{req.max_new_tokens} exceeds max_seq_len "
                f"{self.config.max_seq_len}")
        if self.decode.paged:
            need = self.decode.slots.blocks_required(req.total_budget)
            if need > self.decode.slots.alloc.num_blocks:
                raise ValueError(
                    f"request {req.rid}: needs {need} KV blocks but the "
                    f"decode pool has {self.decode.slots.alloc.num_blocks}")
        self.decode._validate_stop_tokens(req)
        for pe in self._route(req):
            if pe.submit(req):
                return True
        return False

    def _route(self, req) -> list:
        """Order the prefill engines for ``req``: with ``kv_aware``
        routing, by longest registered prefix first (each engine's radix
        tree probed with a countless ``match`` — admission counters stay
        untouched), ties broken by load (queue depth + resident handles);
        with ``"queue"`` routing, by load alone.  The request falls
        through to later engines on queue backpressure."""
        if len(self.prefills) == 1:
            return [self.prefills[0]]
        scored = []
        for i, pe in enumerate(self.prefills):
            score = 0
            if (self.config.kv_routing == "kv_aware"
                    and pe.radix is not None and req.frontend is None):
                m = pe.radix.match(req)
                if m is not None:
                    score = m.n_shared + (1 if m.exact else 0)
            load = len(pe.queue) + pe.resident
            scored.append((-score, load, i, pe))
        scored.sort(key=lambda s: s[:3])
        if -scored[0][0] > 0:
            self._stats.kv_routed += 1
        return [s[3] for s in scored]

    # ---- scheduler ---------------------------------------------------------
    def step(self) -> int:
        """One router tick: prefill admission, handle adoption, decode.
        Returns decode steps executed, or 1 when only prefill/transfer
        progressed — ``0`` keeps the ``Engine.step`` "no work" contract
        trace drivers sleep on."""
        prefilled = 0
        for pe in self.prefills:
            prefilled += pe.step()
            self.pending_transfer.extend(pe.pop_ready())
        moved = 0
        while (self.pending_transfer
               and self.decode.can_admit_prefilled(
                   self.pending_transfer[0].req)):
            self._transfer(self.pending_transfer.popleft())
            moved += 1
        k = self.decode.step()
        if not (prefilled or moved or k):
            if self.pending_transfer and self.decode.idle:
                h = self.pending_transfer[0]
                raise RuntimeError(
                    f"transfer stalled: handle for rid {h.req.rid} "
                    f"(budget {h.req.total_budget}) does not fit the idle "
                    f"decode pool — check decode slot/block sizing")
            waiting = sum(len(pe.queue) for pe in self.prefills)
            if waiting and self.decode.idle:
                raise RuntimeError(
                    f"admission stalled: {waiting} waiting, "
                    f"0 active — check prefill pool sizing")
            return 0
        return k if k else 1

    def _transfer(self, handle: KVTransferHandle) -> None:
        who = f"{self.job_id or handle.req.job_id or 'serve'}:transfer"
        ctx = (self.runtime.permit("transfer", who)
               if self.runtime is not None else contextlib.nullcontext())
        t0 = time.perf_counter()
        with ctx:
            # export from the engine that prefilled it — with several
            # prefill pools the handle's blocks live in its source pool
            one = handle.source.export_cache(handle)
            self.decode.admit_prefilled(handle.req, handle.logits, one)
        n_blocks = len(handle.block_ids)
        handle.release()
        dt = time.perf_counter() - t0
        now = self._clock() if self._clock is not None else t0 + dt
        self.transfer_timeline.append((who, now - dt, now))
        self._stats.transfers += 1
        self._stats.transfer_time_s += dt
        self._stats.transferred_blocks += n_blocks

    def run(self, *, max_ticks: Optional[int] = None):
        """Drive until queue, transfer queue and decode pool are empty."""
        ticks = 0
        while not self.idle:
            if max_ticks is not None and ticks >= max_ticks:
                break
            self.step()
            ticks += 1
        return [self.finished[r] for r in sorted(self.finished)]

    # ---- suspend / resume --------------------------------------------------
    @property
    def weight_version(self) -> int:
        return self.decode.weight_version

    @property
    def suspended(self):
        return self.decode.suspended

    def harvest_suspended(self):
        return self.decode.harvest_suspended()

    def suspend(self, rid: int):
        """Suspend an *actively decoding* request.  Requests still waiting
        or mid-transfer have no KV worth keeping — drop and resubmit
        those instead."""
        return self.decode.suspend(rid)

    def can_resume(self, sreq, tool_tokens=(), *,
                   max_new_tokens: Optional[int] = None) -> bool:
        return self.decode.can_resume(sreq, tool_tokens,
                                      max_new_tokens=max_new_tokens)

    def resume(self, sreq, tool_tokens=(), *,
               max_new_tokens: Optional[int] = None,
               rid: Optional[int] = None,
               stop_tokens: Optional[tuple] = None,
               continue_output: bool = False) -> int:
        """Resume a suspended request straight into the decode pool —
        suspended KV already lives (or is re-materialized) decode-side,
        so resumption bypasses the prefill engine entirely."""
        return self.decode.resume(
            sreq, tool_tokens, max_new_tokens=max_new_tokens, rid=rid,
            stop_tokens=stop_tokens, continue_output=continue_output)

    def can_admit_prefilled(self, req) -> bool:
        return self.decode.can_admit_prefilled(req)

    def admit_prefilled(self, req, logits, one) -> int:
        return self.decode.admit_prefilled(req, logits, one)

    # ---- checkpoint --------------------------------------------------------
    def export_state(self) -> dict:
        """Checkpoint the full router: the decode engine's device/host
        snapshot plus the prefill-side waiting set.  Prefilled-but-unadopted
        handles fold back into plain waiting requests (re-queued at the
        front, their pins released) — re-prefilling them under the same
        weights is bit-identical, so the snapshot stays exact without
        serializing the prefill pool."""
        for pe in self.prefills:
            self.pending_transfer.extend(pe.pop_ready())
        requeue = [h.req for h in self.pending_transfer]
        self.drop_pending()
        for req in reversed(requeue):
            self.prefill.queue._q.appendleft(req)
        state = self.decode.export_state()
        # the snapshot flattens every engine's waiting set into one list;
        # import re-routes each request through _route (kv_aware when
        # enabled), so the restored load spreads across all prefill
        # engines instead of concentrating in engine 0
        state["prefill_queue"] = copy.deepcopy(
            [r for pe in self.prefills for r in pe.queue._q])
        return state

    def import_state(self, state: dict) -> None:
        state = dict(state)
        waiting = state.pop("prefill_queue", [])
        for pe in self.prefills:
            self.pending_transfer.extend(pe.pop_ready())
        self.drop_pending()
        for pe in self.prefills:
            if pe.radix is not None:
                pe.radix.flush()
            if pe.paged:
                pe.slots.alloc.assert_clean(
                    context="DisaggRouter.import_state")
            pe.queue._q.clear()
        self._requeue(copy.deepcopy(waiting))
        self.decode.import_state(state)

    def _requeue(self, reqs) -> None:
        """Spread restored / carried waiting requests back over the prefill
        engines through the same :meth:`_route` scoring live submissions
        use (kv_aware when enabled; right after a flush every score is 0,
        so this degenerates to load balancing).  Restored requests are
        never dropped: when every queue refuses (backpressure), the
        best-ranked engine takes it on its raw deque."""
        for req in reqs:
            order = self._route(req)
            for pe in order:
                if pe.queue.push(req):
                    break
            else:
                order[0].queue._q.append(req)

    def drop_pending(self) -> int:
        """Release every handle still waiting for adoption (mid-flight
        drop).  The block conservation invariant must hold again
        afterwards — ``reset`` asserts it."""
        n = len(self.pending_transfer)
        while self.pending_transfer:
            self.pending_transfer.popleft().release()
        return n

    def reset(self, params=None, rng=None, *, carry_live=False) -> None:
        """Prepare both engines for the next batch (persistent-router reuse
        across GRPO iterations).  In-flight transfer handles are dropped —
        their pins released — and both pools are asserted leak-free.

        ``carry_live=True`` is the partial-rollout weight sync: live decode
        generations are suspended and resumed under the new weights by the
        decode engine itself (their outputs keep accumulating, with
        ``token_versions`` recording the switch), the waiting queue is held
        across the prefill reset, and prefilled-but-unadopted handles fall
        back to plain waiting requests — their KV is stale the moment the
        weights swap, so re-prefilling under the new weights is the correct
        (and cheapest-to-keep-exact) continuation."""
        if not carry_live:
            if any(pe.queue for pe in self.prefills) or not self.decode.idle:
                raise RuntimeError("reset() on a live router; drain first")
            for pe in self.prefills:
                self.pending_transfer.extend(pe.pop_ready())
            self.drop_pending()
            for pe in self.prefills:
                pe.reset(params)
            self.decode.reset(params, rng)
            return
        for pe in self.prefills:
            self.pending_transfer.extend(pe.pop_ready())
        requeue = [h.req for h in self.pending_transfer]
        self.drop_pending()
        held = [r for pe in self.prefills for r in pe.queue._q]
        for pe in self.prefills:
            pe.queue._q.clear()
            pe.reset(params)
        self.decode.reset(params, rng, carry_live=True)
        self._requeue(requeue + held)
