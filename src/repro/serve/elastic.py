"""Elastic capacity: closed-loop autoscaling over the unified telemetry
API, plus overload admission control that degrades before it misses.

Three layers, smallest first:

* :func:`resize_engine` / :func:`resize_router` — the actuators.  A
  resize is a *rebuild*: every live generation is suspended
  (``Engine._suspend_slot`` pins its KV in the old pool, zero copies), a
  fresh engine is constructed at the new slot-pool size, and the
  suspended work resumes on it with ``continue_output=True`` — the same
  suspend/resume machinery weight syncs and agentic tool boundaries
  already use, so no live KV is lost and greedy continuation stays
  bit-identical.  Counters (``EngineStats``), finished outputs and the
  admission-policy object (with its measured service-time EMA) all carry
  over, so telemetry is monotone across resizes.  Engines of distinct
  slot counts jit-compile separately — controllers must walk a small
  *ladder* of sizes, not a continuum.

* :class:`ElasticController` — the feedback loop.  Periodically reads
  one :class:`~repro.core.telemetry.MetricsSnapshot` from whatever it is
  steering (monolithic ``Engine`` or ``DisaggRouter`` — same API), and
  grows/shrinks along its ladder on queue pressure / occupancy with
  hysteresis and a post-resize cooldown.  For routers the prefill pool
  scales with the decode pool at the configured prefill:decode ratio.
  ``run_trace`` calls :meth:`ElasticController.attach` /
  :meth:`~ElasticController.admit` / :meth:`~ElasticController.maybe_resize`
  / :meth:`~ElasticController.summary`; the summary lands in the trace
  report under ``"elastic"`` (capacity-seconds vs the static baseline,
  shed/degrade records, the resize history).

* Admission control (inside the controller): when the predicted finish
  of a deadline request misses its contract, the controller first
  *degrades* — clamps ``max_new_tokens`` to the largest budget that
  still fits the deadline (greedy tokens of a clamped request are
  exactly a prefix of the unclamped ones, so token equality for admitted
  work is preserved) — and only *sheds* when even the minimum budget is
  provably doomed.  Sheds are recorded, never silent; at sub-saturation
  the predictor never fires (no queue, no predicted miss), so the shed
  count is exactly zero there (the benchmark's CI floor).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

__all__ = ["ElasticConfig", "ElasticController", "resize_engine",
           "resize_router", "rederive_slo"]


# ---------------------------------------------------------------------------
# actuators
# ---------------------------------------------------------------------------

def resize_engine(engine, num_slots: int, *, num_kv_blocks="keep"):
    """Rebuild ``engine`` at a new slot-pool size without losing work.

    Live generations are suspended (their KV pinned in the old pool),
    queued requests are carried over in order, and a fresh
    :class:`~repro.serve.engine.Engine` is built at ``num_slots`` with
    the same model/params/rng/policy.  The suspended generations resume
    on the new engine with ``continue_output=True`` — token streams,
    logprobs and per-token weight versions continue exactly where they
    left off (``sreq.source`` keeps the old pool's pins until each view
    is materialized on the new one).  Stats, finished outputs and the
    harvest backlog carry over, so counters stay monotone across
    resizes.

    ``num_kv_blocks="keep"`` (default) keeps the old config's paged pool
    sizing (explicit block count, or ``None`` = auto-scale with
    ``num_slots``); pass an int (or ``None``) to override.

    Handles suspended *before* the resize (agentic tool boundaries) stay
    registered on — and pinned in — the old engine; they resume on the
    new engine like on any engine of the same serving shape.  The old
    pool is conservation-checked: after the carried work re-admits, it
    holds exactly those handles' pins and nothing else.
    """
    from repro.serve.engine import Engine
    if num_slots < 1:
        raise ValueError(f"num_slots must be >= 1, got {num_slots}")
    if num_slots == engine.config.num_slots and num_kv_blocks == "keep":
        return engine
    if engine.num_active > num_slots:
        raise ValueError(
            f"cannot shrink to {num_slots} slots with "
            f"{engine.num_active} live requests; shrink targets must be "
            f"clamped to the live count")
    carried = [engine._suspend_slot(slot) for slot in sorted(engine._active)]
    queued = list(engine.queue._q)
    engine.queue._q.clear()
    kw = {"num_slots": num_slots}
    if num_kv_blocks != "keep":
        kw["num_kv_blocks"] = num_kv_blocks
    cfg = dataclasses.replace(engine.config, **kw)
    new = Engine(engine.model, engine.params, cfg, rng=engine._rng,
                 policy=engine.policy)
    new.clock = engine.clock
    new.weight_version = engine.weight_version
    new._slot_version = [engine.weight_version] * num_slots
    new._stats = engine._stats          # counters stay monotone
    new.finished.update(engine.finished)
    new._unharvested.extend(engine._unharvested)
    engine._unharvested = []
    new.queue.rejected = engine.queue.rejected
    if engine.radix is not None:
        # the old tree's snapshots reference the old pool; it must not
        # outlive its engine (the new engine grows its own tree)
        engine.radix.flush()
    for sreq in carried:
        new.resume(sreq, continue_output=True)
    new.queue._q.extend(queued)
    if engine.paged:
        pins = [b for s in engine.suspended.values() for b in s.block_ids]
        if pins:
            engine.slots.check(extra_pins=pins)
        else:
            engine.slots.alloc.assert_clean(context="resize_engine")
    return new


def resize_router(router, *, prefill_slots: Optional[int] = None,
                  decode_slots: Optional[int] = None):
    """Rebuild a :class:`~repro.serve.router.DisaggRouter` at a new
    prefill/decode shape without losing work.

    Live decode generations are suspended and resumed on the new decode
    pool (same mechanics as :func:`resize_engine`); prefilled-but-
    unadopted transfer handles fold back into plain waiting requests
    (their prompt KV is repaid by a re-prefill on the new shape — the
    same exactness argument ``export_state`` makes) and the combined
    waiting set is re-routed over the new prefill engines through
    ``_route``.  Decode counters, transfer counters and the shared
    admission-policy object carry over.
    """
    from repro.serve.router import DisaggRouter
    cfg = router.config
    new_cfg = dataclasses.replace(
        cfg,
        prefill_slots=(cfg.prefill_slots if prefill_slots is None
                       else prefill_slots),
        decode_slots=(cfg.decode_slots if decode_slots is None
                      else decode_slots))
    if new_cfg == cfg:
        return router
    if router.decode.num_active > new_cfg.decode_slots:
        raise ValueError(
            f"cannot shrink decode to {new_cfg.decode_slots} slots with "
            f"{router.decode.num_active} live requests")
    # fold un-adopted handles back to waiting requests, release their pins
    for pe in router.prefills:
        router.pending_transfer.extend(pe.pop_ready())
    requeue = [h.req for h in router.pending_transfer]
    router.drop_pending()
    held = [r for pe in router.prefills for r in pe.queue._q]
    for pe in router.prefills:
        pe.queue._q.clear()
        if pe.radix is not None:
            pe.radix.flush()
        if pe.paged:
            pe.slots.alloc.assert_clean(context="resize_router")
    carried = [router.decode._suspend_slot(s)
               for s in sorted(router.decode._active)]
    new = DisaggRouter(router.model, router.decode.params, new_cfg,
                       rng=router.decode._rng, policy=router.prefill.policy,
                       runtime=router.runtime, job_id=router.job_id)
    new.clock = router.clock
    new.decode.weight_version = router.decode.weight_version
    new.decode._stats = router.decode._stats
    new.decode.finished.update(router.decode.finished)
    new.decode._unharvested.extend(router.decode._unharvested)
    router.decode._unharvested = []
    # prefill/transfer counters stay monotone: seed engine 0's record and
    # the new RouterStats with the old totals
    ps = new.prefills[0].stats
    for pe in router.prefills:
        ps.prefills += pe.stats.prefills
        ps.prefix_hits += pe.stats.prefix_hits
        ps.prefix_partial_hits += pe.stats.prefix_partial_hits
        ps.blocks_saved += pe.stats.blocks_saved
    new.prefills[0].queue.rejected = sum(
        pe.queue.rejected for pe in router.prefills)
    for attr in ("transfers", "transfer_time_s", "transferred_blocks",
                 "kv_routed"):
        setattr(new._stats, attr, getattr(router._stats, attr))
    for sreq in carried:
        new.decode.resume(sreq, continue_output=True)
    new._requeue(requeue + held)
    if router.decode.paged:
        pins = [b for s in router.decode.suspended.values()
                for b in s.block_ids]
        if pins:
            router.decode.slots.check(extra_pins=pins)
        else:
            router.decode.slots.alloc.assert_clean(context="resize_router")
    return new


def rederive_slo(policy, runtime, *, rollout_nodes: int = 1,
                 train_nodes: int = 1, margin: float = 1.0):
    """Re-derive an :class:`~repro.serve.sched.SLOPolicy`'s slowdown
    contract from the DES planner on measured phase profiles — the
    planning-side half of a capacity change.

    Builds a co-execution group whose job durations are the runtime's
    engine-measured :class:`~repro.core.phase_control.PhaseProfile`
    records (``core.simulator.group_from_profiles``) and installs the
    group's tightest guaranteed slowdown bound as the policy's new
    ``slowdown``.  Returns the new bound, or ``None`` when the policy
    carries no contract or no profiles exist yet (first iteration).
    """
    if not hasattr(policy, "slowdown") or runtime is None:
        return None
    profiles = list(runtime.phase_profiles().values())
    if not profiles:
        return None
    from repro.core.simulator import group_from_profiles
    G = group_from_profiles(profiles, gid="elastic",
                            rollout_nodes=rollout_nodes,
                            train_nodes=train_nodes)
    bound = G.slowdown_bound(margin=margin)
    policy.slowdown = bound
    return bound


# ---------------------------------------------------------------------------
# controller
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ElasticConfig:
    """Feedback-loop knobs.  Thresholds are in units of the snapshot's
    derived ratios (``queue_pressure`` = waiting per configured slot,
    ``occupancy`` = live per configured slot)."""
    ladder: tuple = (2, 4, 8)        # slot counts the controller may visit
    #                                  (each size jit-compiles once)
    interval_s: float = 0.25         # min seconds between control decisions
    cooldown_s: float = 0.75         # post-resize settle time
    grow_pressure: float = 1.0       # queue_pressure >= this => grow
    shrink_pressure: float = 0.25    # queue_pressure <= this and ...
    shrink_occupancy: float = 0.5    # ... occupancy <= this => shrink
    shed: bool = False               # enable shed/degrade admission control
    degrade: bool = True             # clamp budgets before shedding
    min_degrade_tokens: int = 8      # never clamp below this budget
    deadline_margin: float = 0.0     # seconds reserved before the deadline


class ElasticController:
    """Closed-loop capacity controller for ``run_trace`` (and the serve
    launcher): admission gate + periodic resize along a slot ladder.

    The controller consumes *only* the unified telemetry API
    (``engine.metrics()`` → :class:`~repro.core.telemetry.MetricsSnapshot`)
    and actuates through :func:`resize_engine` / :func:`resize_router`.
    It keeps a capacity log — ``(t, slots)`` segments — whose integral
    (capacity-seconds) is the cost side of the elastic-vs-static
    comparison the benchmark reports.
    """

    def __init__(self, config: Optional[ElasticConfig] = None, *,
                 runtime=None):
        self.cfg = config if config is not None else ElasticConfig()
        if not self.cfg.ladder:
            raise ValueError("ladder must name at least one slot count")
        self.ladder = tuple(sorted(set(int(n) for n in self.cfg.ladder)))
        if self.ladder[0] < 1:
            raise ValueError("ladder slot counts must be >= 1")
        self.runtime = runtime          # optional: SLO re-derivation source
        self.capacity_log: list[tuple[float, int]] = []
        self.shed_records: list[dict] = []
        self.degrade_records: list[dict] = []
        self.resizes: list[tuple[float, int, int]] = []   # (t, from, to)
        self.class_counts: dict[str, dict] = {}
        self._static_slots = 0
        self._t0 = 0.0
        self._last_check = float("-inf")
        self._last_resize = float("-inf")
        self._budget_ema = 0.0          # mean admitted decode budget
        self._decisions: dict[int, tuple] = {}   # rid -> (verdict, req)

    # -- helpers ----------------------------------------------------------
    @staticmethod
    def _is_router(engine) -> bool:
        return hasattr(engine, "pending_transfer")

    @staticmethod
    def _slots_of(engine) -> int:
        if ElasticController._is_router(engine):
            return engine.config.decode_slots
        return engine.config.num_slots

    @staticmethod
    def classify(req) -> str:
        """Service class for accounting: requests carrying a deadline are
        interactive traffic; the rest are batch."""
        return "interactive" if req.deadline is not None else "batch"

    def _count(self, cls: str, key: str) -> None:
        c = self.class_counts.setdefault(
            cls, {"admitted": 0, "degraded": 0, "shed": 0})
        c[key] += 1

    # -- run_trace hooks --------------------------------------------------
    def attach(self, engine, now: float) -> None:
        """Start of a trace: pin the static baseline shape and open the
        capacity log."""
        self._static_slots = self._slots_of(engine)
        self._t0 = now
        self.capacity_log = [(now, self._static_slots)]
        self._last_check = now
        self._last_resize = float("-inf")
        self._decisions.clear()

    def admit(self, req, now: float, engine):
        """Admission gate: returns ``(verdict, req)`` with verdict one of
        ``"admit"`` (possibly unchanged), ``"degrade"`` (the returned
        request's decode budget was clamped to fit its deadline) or
        ``"shed"`` (caller drops it; the controller has recorded it).

        The predictor is deliberately conservative: with no measured
        service time yet, or no queue backlog, a deadline request is
        always admitted at full budget — sheds can only happen when the
        measured backlog makes the miss provable.
        """
        if req.rid in self._decisions:
            # queue backpressure made the driver retry this arrival: the
            # decision (and its records) stand — don't double-count
            return self._decisions[req.rid]
        cls = self.classify(req)
        self._budget_ema = (req.max_new_tokens if not self._budget_ema else
                            0.8 * self._budget_ema + 0.2 * req.max_new_tokens)
        if not self.cfg.shed or req.deadline is None:
            self._count(cls, "admitted")
            return self._decide(req, "admit", req)
        snap = engine.metrics()
        tpt = snap.time_per_token
        if tpt <= 0.0:
            self._count(cls, "admitted")
            return self._decide(req, "admit", req)
        # expected wait for a slot: the queued work ahead, spread over the
        # pool (continuous batching serves all slots each step)
        wait_s = tpt * snap.queue_depth * self._budget_ema \
            / max(snap.num_slots, 1)
        slack_s = req.deadline - self.cfg.deadline_margin - now - wait_s
        fit = int(slack_s / tpt)        # largest budget that still fits
        if fit >= req.max_new_tokens:
            self._count(cls, "admitted")
            return self._decide(req, "admit", req)
        if self.cfg.degrade and fit >= self.cfg.min_degrade_tokens:
            clamped = dataclasses.replace(req, max_new_tokens=fit)
            self.degrade_records.append({
                "rid": req.rid, "class": cls, "t": now,
                "from": req.max_new_tokens, "to": fit})
            self._count(cls, "admitted")
            self._count(cls, "degraded")
            return self._decide(req, "degrade", clamped)
        self.shed_records.append({
            "rid": req.rid, "class": cls, "t": now,
            "reason": (f"predicted finish misses deadline by "
                       f"{-slack_s + tpt * req.max_new_tokens:.3f}s even "
                       f"degraded")})
        self._count(cls, "shed")
        return self._decide(req, "shed", req)

    def _decide(self, req, verdict: str, out_req):
        self._decisions[req.rid] = (verdict, out_req)
        return verdict, out_req

    def maybe_resize(self, engine, now: float):
        """Periodic control decision: read one snapshot, walk the ladder
        one rung on sustained pressure (grow) or slack (shrink).  Returns
        the engine to keep driving — the same object when nothing
        changed, a rebuilt one after a resize."""
        if now - self._last_check < self.cfg.interval_s:
            return engine
        self._last_check = now
        if now - self._last_resize < self.cfg.cooldown_s:
            return engine
        snap = engine.metrics()
        current = self._slots_of(engine)
        target = None
        rungs = self.ladder
        if current not in rungs:
            # off-ladder start: snap to the nearest rung on first decision
            rungs = tuple(sorted(set(rungs) | {current}))
        i = rungs.index(current)
        if snap.queue_pressure >= self.cfg.grow_pressure \
                and i + 1 < len(rungs):
            target = rungs[i + 1]
        elif (snap.queue_pressure <= self.cfg.shrink_pressure
              and snap.occupancy <= self.cfg.shrink_occupancy and i > 0):
            cand = rungs[i - 1]
            live = (engine.decode.num_active if self._is_router(engine)
                    else engine.num_active)
            if cand >= live:
                target = cand
        if target is None or target == current:
            return engine
        if self._is_router(engine):
            ratio = max(engine.config.prefill_slots
                        / max(engine.config.decode_slots, 1), 1e-9)
            engine = resize_router(
                engine, decode_slots=target,
                prefill_slots=max(1, round(target * ratio)))
        else:
            engine = resize_engine(engine, target)
        self.resizes.append((now, current, target))
        self.capacity_log.append((now, target))
        self._last_resize = now
        # capacity changed: let the planner re-derive the SLO contract on
        # the new shape (no-op without a runtime / SLO policy)
        rederive_slo(engine.policy if hasattr(engine, "policy")
                     else engine.prefill.policy, self.runtime)
        return engine

    def summary(self, makespan: float) -> dict:
        """The trace report's ``"elastic"`` section: the capacity-seconds
        integral vs the static baseline, shed/degrade records (sheds are
        *reported*, never silent), and the resize history."""
        end = self._t0 + makespan
        cap = 0.0
        log = self.capacity_log or [(self._t0, self._static_slots)]
        for (t, slots), nxt in zip(log, log[1:] + [(end, 0)]):
            cap += slots * max(nxt[0] - t, 0.0)
        static = self._static_slots * max(makespan, 0.0)
        return {
            "capacity_seconds": cap,
            "static_capacity_seconds": static,
            "capacity_seconds_ratio": cap / max(static, 1e-9),
            "sheds": len(self.shed_records),
            "shed_records": list(self.shed_records),
            "degrades": len(self.degrade_records),
            "degrade_records": list(self.degrade_records),
            "resizes": len(self.resizes),
            "resize_log": [list(r) for r in self.resizes],
            "capacity_log": [list(c) for c in self.capacity_log],
            "class_counts": {k: dict(v)
                             for k, v in self.class_counts.items()},
        }
