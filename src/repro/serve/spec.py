"""``RolloutSpec``: one description of how rollouts are served.

Seven PRs of flag accretion left the engine-shape knobs (``num_slots``,
``kv_layout``, ``kv_block_size``, ``num_kv_blocks``, ``sched``,
``prefix_share``, ``disagg``, ``kernel_backend``, ``kv_dtype``, ...)
duplicated across ``generate_continuous``, ``generate_continuous_stream``,
``GRPOJob`` and two launch entrypoints, each copy one missed edit away
from drifting.  :class:`RolloutSpec` is the single source: it derives the
per-session :class:`~repro.serve.engine.EngineConfig` /
:class:`~repro.serve.router.DisaggConfig` (which add the session-scoped
sampler contract and sequence budget) and builds the engine.

``RolloutSpec.from_args`` consumes the argparse namespaces of both
``launch/serve.py`` and ``launch/train.py`` — attribute names differ
slightly between the two (``slots`` vs ``num_slots``; serve's
``--disagg`` family), so it reads defensively via ``getattr``.  The old
per-function kwargs keep working through a shim in ``rl.rollout`` that
warns once per process.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

from repro.serve.engine import EngineConfig
from repro.serve.router import DisaggConfig


@dataclass(frozen=True)
class RolloutSpec:
    """Engine shape + rollout session fields, sampler- and batch-agnostic.

    ``disagg`` selects disaggregated prefill/decode serving: ``None``
    (monolithic), ``True`` (split ``num_slots`` 1:3 prefill:decode), a
    dict of :class:`DisaggConfig` overrides, or a full ``DisaggConfig``.
    ``group``/``job_id`` tag GRPO prompt groups and the submitting job
    for per-job scheduler budgets; prefix sharing itself is
    content-addressed (identical token prefixes share KV untagged), with
    ``prefix_namespace`` an optional isolation namespace for requests
    that must not share across a tenant boundary.  ``carry`` opts the
    streaming executor into partial-rollout continuation: a mid-rollout
    weight sync suspends live generations and resumes them under the new
    weights (``Engine.reset(carry_live=True)``) instead of finishing the
    iteration on stale weights.
    """
    num_slots: Optional[int] = None      # default: one slot per request
    block_size: int = 1
    kv_layout: str = "contiguous"
    kv_block_size: int = 16
    num_kv_blocks: Optional[int] = None
    sched: str = "fifo"
    prefix_share: bool = False
    kernel_backend: str = "jnp"
    kv_dtype: Optional[str] = None
    disagg: Any = None                   # None | True | dict | DisaggConfig
    group: Optional[int] = None
    job_id: Optional[str] = None
    carry: bool = False
    prefix_namespace: Any = None         # radix isolation namespace
    #                                      (None = global content sharing)

    def replace(self, **kw) -> "RolloutSpec":
        return dataclasses.replace(self, **kw)

    # ---- config derivation -------------------------------------------------
    def engine_config(self, *, batch: int, max_seq_len: int, eos_id: int,
                      temperature: float,
                      max_waiting: Optional[int] = None) -> EngineConfig:
        return EngineConfig(
            num_slots=batch if self.num_slots is None else self.num_slots,
            max_seq_len=max_seq_len, eos_id=eos_id, temperature=temperature,
            block_size=self.block_size, max_waiting=max_waiting,
            kv_layout=self.kv_layout, kv_block_size=self.kv_block_size,
            num_kv_blocks=self.num_kv_blocks, sched=self.sched,
            prefix_share=self.prefix_share,
            kernel_backend=self.kernel_backend, kv_dtype=self.kv_dtype)

    def disagg_config(self, *, batch: int, max_seq_len: int, eos_id: int,
                      temperature: float) -> Optional[DisaggConfig]:
        """The two-pool shape, or ``None`` when serving monolithic.
        ``disagg=True`` splits ``num_slots`` 1:3 prefill:decode; a dict
        overrides any ``DisaggConfig`` field."""
        if not self.disagg:
            return None
        if isinstance(self.disagg, DisaggConfig):
            return self.disagg
        n = batch if self.num_slots is None else self.num_slots
        opts = {} if self.disagg is True else dict(self.disagg)
        pf = opts.pop("prefill_slots", max(1, n // 4))
        return DisaggConfig(
            prefill_slots=pf,
            decode_slots=opts.pop("decode_slots", max(1, n - pf)),
            max_seq_len=max_seq_len, eos_id=eos_id, temperature=temperature,
            block_size=self.block_size, kv_layout=self.kv_layout,
            kv_block_size=self.kv_block_size,
            decode_kv_blocks=opts.pop("decode_kv_blocks",
                                      self.num_kv_blocks),
            sched=self.sched, prefix_share=self.prefix_share,
            kernel_backend=opts.pop("kernel_backend", self.kernel_backend),
            kv_dtype=opts.pop("kv_dtype", self.kv_dtype), **opts)

    def build_engine(self, model, params, *, batch: int, max_seq_len: int,
                     eos_id: int, temperature: float, rng=None, policy=None):
        """Build the engine this spec describes — a monolithic
        :class:`~repro.serve.engine.Engine` or a
        :class:`~repro.serve.router.DisaggRouter` (both satisfy
        :class:`~repro.serve.protocol.EngineProtocol`)."""
        from repro.serve.engine import Engine
        from repro.serve.router import DisaggRouter

        dcfg = self.disagg_config(batch=batch, max_seq_len=max_seq_len,
                                  eos_id=eos_id, temperature=temperature)
        if dcfg is not None:
            return DisaggRouter(model, params, dcfg, rng=rng, policy=policy,
                                job_id=self.job_id)
        return Engine(model, params, self.engine_config(
            batch=batch, max_seq_len=max_seq_len, eos_id=eos_id,
            temperature=temperature), rng=rng, policy=policy)

    # ---- argparse bridge ---------------------------------------------------
    @classmethod
    def from_args(cls, args, **overrides) -> "RolloutSpec":
        """Build a spec from a launch-entrypoint argparse namespace
        (``launch/serve.py`` and ``launch/train.py`` both route through
        here).  Flags a given parser doesn't define fall back to the
        spec defaults; ``overrides`` win over everything."""
        def get(*names, default=None):
            for n in names:
                if getattr(args, n, None) is not None:
                    return getattr(args, n)
            return default

        disagg = None
        if getattr(args, "disagg", False):
            disagg = {k: v for k, v in
                      (("prefill_slots", getattr(args, "prefill_slots",
                                                 None)),
                       ("decode_slots", getattr(args, "decode_slots", None)),
                       ("prefill_kv_blocks", getattr(args,
                                                     "prefill_kv_blocks",
                                                     None)),
                       ("decode_kv_blocks", getattr(args, "decode_kv_blocks",
                                                    None)),
                       ("prefill_engines", getattr(args, "prefill_engines",
                                                   None)),
                       ("kv_routing", getattr(args, "kv_routing", None)))
                      if v is not None} or True
        spec = cls(
            num_slots=get("slots", "num_slots"),
            block_size=get("block_size", "engine_block_size", default=1),
            kv_layout=get("kv", "kv_layout", default="contiguous"),
            kv_block_size=get("kv_block_size", default=16),
            num_kv_blocks=get("num_kv_blocks"),
            sched=get("sched", default="fifo"),
            prefix_share=bool(getattr(args, "prefix_share", False)),
            kernel_backend=get("kernel_backend", default="jnp"),
            kv_dtype=get("kv_dtype"),
            disagg=disagg,
            group=get("group"),
            carry=bool(getattr(args, "carry", False)))
        return spec.replace(**overrides) if overrides else spec
