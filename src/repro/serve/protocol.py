"""The written engine contract: what a rollout executor may drive.

``DisaggRouter`` has always duck-typed the ``Engine`` surface that
``run_trace``, ``generate_continuous`` and the streaming executor drive;
with the suspend/resume lifecycle that surface grew, and an implicit
contract over two implementations is how surfaces silently drift.
:class:`EngineProtocol` writes it down once; the conformance test
(``tests/test_protocol.py``) is parameterized over both implementations
so a method added to one but not the other fails loudly.

Beyond the methods the protocol can express, conforming engines also
carry the data surface drivers read:

``params`` / ``paged`` / ``slots`` / ``queue`` / ``finished`` /
``stats`` / ``radix`` / ``num_active`` / ``idle`` / ``clock`` (settable)
/ ``weight_version`` / ``suspended``

— checked attribute-by-attribute in the conformance test, since
``runtime_checkable`` protocols only verify callables.
"""
from __future__ import annotations

from typing import Optional, Protocol, runtime_checkable

#: Data attributes every conforming engine exposes (see module docstring).
ENGINE_ATTRS = ("config", "params", "paged", "slots", "queue", "finished",
                "stats", "radix", "num_active", "idle", "clock",
                "weight_version", "suspended")


@runtime_checkable
class EngineProtocol(Protocol):
    """Continuous-batching engine surface (monolithic or disaggregated).

    Lifecycle: ``submit`` feeds the waiting queue, ``step`` runs one
    scheduler tick, ``harvest`` drains finished outputs without stopping
    the engine, ``run`` drives to idle.  ``reset`` prepares a persistent
    engine for the next GRPO iteration (``carry_live=True`` suspends and
    resumes live generations across the weight swap instead of requiring
    a drain).  ``export_state``/``import_state`` checkpoint mid-flight.
    ``suspend``/``resume`` (plus ``harvest_suspended`` for stop-token
    boundaries the engine detects itself) are the multi-turn tool-call
    lifecycle, and ``admit_prefilled`` is the underlying KV adoption path
    shared with disaggregated prefill/decode transfer.
    """

    def submit(self, req) -> bool: ...

    def step(self) -> int: ...

    def metrics(self): ...    # -> core.telemetry.MetricsSnapshot

    def run(self, *, max_ticks: Optional[int] = None): ...

    def harvest(self) -> list: ...

    def reset(self, params=None, rng=None, *, carry_live: bool = False
              ) -> None: ...

    def export_state(self) -> dict: ...

    def import_state(self, state: dict) -> None: ...

    def can_admit_prefilled(self, req) -> bool: ...

    def admit_prefilled(self, req, logits, one) -> int: ...

    def suspend(self, rid: int): ...

    def harvest_suspended(self) -> list: ...

    def can_resume(self, sreq, tool_tokens=(), *,
                   max_new_tokens: Optional[int] = None) -> bool: ...

    def resume(self, sreq, tool_tokens=(), *,
               max_new_tokens: Optional[int] = None,
               rid: Optional[int] = None,
               stop_tokens: Optional[tuple] = None,
               continue_output: bool = False) -> int: ...
