"""Ref-counted block allocator for the paged KV cache.

The serving pool's KV memory is a fixed array of ``num_blocks`` equal-sized
blocks (``block_size`` token positions each).  Block id ``0`` is reserved as
the *null block*: unassigned block-table entries point at it, recycled slots'
tables are zeroed to it, and any write from a dead or over-budget slot lands
there harmlessly (nothing unmasked ever reads it).  Real blocks carry ids
``1..num_blocks``.

Admission control uses *quota reservation*: at admit time a request reserves
the worst-case number of blocks its total budget (prompt + decode cap) can
ever touch, but blocks are only **materialized on demand** as the request's
``index`` crosses a block boundary.  Because the allocator never reserves
more than ``num_blocks`` across owners, every on-demand ``allocate`` within
quota is guaranteed to succeed — the engine can never deadlock mid-decode.
Long-tail traffic thus reserves what it might use, not a full
``max_seq_len`` stripe, which is exactly where paged beats the contiguous
layout on concurrency at equal memory.

Blocks are ref-counted (``incref``/``decref``), which is what radix
prompt-prefix sharing (``repro.serve.radix``) builds on: a donor request
allocates a prompt's blocks under its own reservation, the prefix index
pins them with one extra ref, and every sharing slot increfs them again —
an immutable full block lives until its *last* owner (slot or index) lets
go, and ``free_all`` on any single owner only drops that owner's refs.

Invariants (enforced here, locked in by ``tests/test_serve_paged.py`` and
the shared-interleaving sweeps in ``tests/test_serve_radix.py``):
  * a free block is never handed out twice (no double-assignment);
  * ``num_free + live_blocks == num_blocks`` at all times (conservation);
  * total committed (reserved-but-unmaterialized + live) never exceeds
    ``num_blocks``;
  * the null block 0 never enters the free list or the refcount map;
  * ``decref`` below zero / freeing an unknown block raises.
"""
from __future__ import annotations


def blocks_for(total_tokens: int, block_size: int) -> int:
    """Blocks needed to cover token positions ``0..total_tokens-1``."""
    return -(-total_tokens // block_size)


class BlockAllocator:
    """Fixed pool of ``num_blocks`` KV blocks with quota reservation."""

    def __init__(self, num_blocks: int, block_size: int):
        if num_blocks < 1:
            raise ValueError("num_blocks must be >= 1")
        if block_size < 1:
            raise ValueError("block_size must be >= 1")
        self.num_blocks = num_blocks
        self.block_size = block_size
        # LIFO free list; id 0 is the null block and never enters it.
        self.free: list[int] = list(range(num_blocks, 0, -1))
        self.refcount: dict[int, int] = {}        # bid -> live refs
        self.quota: dict[int, int] = {}           # owner -> claimable blocks
        self.owned: dict[int, list[int]] = {}     # owner -> materialized bids
        self.events: list[tuple] = []             # ("reserve"|"alloc"|"free", ...)

    # ---- accounting --------------------------------------------------------
    @property
    def num_free(self) -> int:
        return len(self.free)

    @property
    def num_live(self) -> int:
        return len(self.refcount)

    @property
    def num_committed(self) -> int:
        """Blocks spoken for: materialized + still-claimable reservations."""
        return self.num_live + sum(self.quota.values())

    def can_reserve(self, n: int) -> bool:
        return n <= self.num_blocks - self.num_committed

    # ---- lifecycle ---------------------------------------------------------
    def reserve(self, owner: int, n: int) -> None:
        """Set aside ``n`` blocks the request may later materialize."""
        if owner in self.quota:
            raise AssertionError(f"owner {owner} already has a reservation")
        if not self.can_reserve(n):
            raise RuntimeError(
                f"cannot reserve {n} blocks "
                f"({self.num_blocks - self.num_committed} uncommitted)")
        self.quota[owner] = n
        self.owned[owner] = []
        self.events.append(("reserve", owner, n))

    def allocate(self, owner: int) -> int:
        """Materialize one reserved block for ``owner``; returns its id."""
        if self.quota.get(owner, 0) <= 0:
            raise RuntimeError(f"owner {owner} has no remaining quota")
        if not self.free:                  # unreachable if invariants hold
            raise AssertionError("free list empty despite live reservation")
        bid = self.free.pop()
        if bid in self.refcount:           # invariant: never hand out twice
            raise AssertionError(f"block {bid} already live")
        self.refcount[bid] = 1
        self.quota[owner] -= 1
        self.owned[owner].append(bid)
        self.events.append(("alloc", owner, bid))
        return bid

    def incref(self, bid: int) -> None:
        if bid not in self.refcount:
            raise AssertionError(f"incref on non-live block {bid}")
        self.refcount[bid] += 1

    def decref(self, bid: int) -> None:
        if bid not in self.refcount:
            raise AssertionError(f"decref on non-live block {bid}")
        self.refcount[bid] -= 1
        if self.refcount[bid] == 0:
            del self.refcount[bid]
            self.free.append(bid)

    def free_all(self, owner: int) -> None:
        """Drop the owner's reservation and decref every block it holds."""
        if owner not in self.quota:
            raise AssertionError(f"owner {owner} has no reservation")
        for bid in self.owned.pop(owner):
            self.decref(bid)
        del self.quota[owner]
        self.events.append(("free", owner, None))

    def assert_clean(self, context: str = "") -> None:
        """Assert the pool is fully returned: every block free, zero
        dangling refcounts, no outstanding reservations.  This is the
        leak check engines run after ``reset`` (idle + flushed radix +
        released transfer handles ⇒ nothing may hold a block) — raising
        here turns a slow cross-iteration leak into an immediate, located
        failure."""
        self.check()
        if self.refcount or self.quota or self.num_free != self.num_blocks:
            where = f" after {context}" if context else ""
            raise RuntimeError(
                f"KV block leak{where}: {len(self.refcount)} block(s) still "
                f"referenced {sorted(self.refcount)!r}, outstanding "
                f"reservations {dict(self.quota)!r}, "
                f"free {self.num_free}/{self.num_blocks}")

    # ---- invariant check (cheap; called by property tests) -----------------
    def check(self) -> None:
        assert 0 not in self.refcount and 0 not in self.free
        assert len(set(self.free)) == len(self.free), "free list duplicates"
        assert not (set(self.free) & set(self.refcount)), \
            "block both free and live"
        assert self.num_free + self.num_live == self.num_blocks, \
            "block count not conserved"
        assert self.num_committed <= self.num_blocks
        owned_flat = [b for bids in self.owned.values() for b in bids]
        assert len(set(owned_flat)) == len(owned_flat), \
            "block owned by two requests"
        assert all(b in self.refcount for b in owned_flat)
        assert all(q >= 0 for q in self.quota.values())
