"""Continuous-batching rollout serving subsystem.

The paper's rollout phase is memory-bandwidth-bound *serving*; this package
makes it a first-class serving problem: ``Request``s flow through a
``RequestQueue`` into a fixed pool of KV-cache slots in the order a
pluggable admission policy picks (``repro.serve.sched``: strict ``FIFO``,
deadline-aware EDF with bounded head skipping + per-job token budgets, or
an ``SLO`` policy that enforces the inter-group scheduler's slowdown
contract per request), and the ``Engine`` interleaves
prefill-into-free-slot admission with batched single-token decode across
all live slots (in-flight batching).

KV memory comes in two layouts.  ``SlotManager`` (contiguous) gives every
slot a full ``max_seq_len`` stripe; ``PagedSlotManager`` shares a pool of
fixed-size blocks (``BlockAllocator``: ref-counted free list, worst-case
reservation at admit, on-demand materialization as ``index`` crosses block
boundaries) so long-tail response lengths stop stranding memory — the same
KV bytes admit strictly more concurrent requests.  On top of the paged
layout, ``RadixPrefixIndex`` (``repro.serve.radix``) is a
content-addressed radix tree over full token blocks: any requests
agreeing on a block-aligned token prefix — GRPO's duplicated prompts,
shared system preambles, multi-turn histories — share those blocks, and
exact repeats admit with zero model compute; admission gates on net-new
blocks only.  All layouts and policies produce token/logprob-
identical greedy output.  See ``repro.serve.engine`` for the scheduling
model and exactness guarantees, ``repro.serve.slots`` for the layout
invariants.
"""
from repro.serve.blocks import BlockAllocator, blocks_for
from repro.serve.disagg import KVTransferHandle, PrefillEngine
from repro.serve.elastic import (ElasticConfig, ElasticController,
                                 rederive_slo, resize_engine, resize_router)
from repro.serve.engine import (Engine, EngineConfig, EngineStats,
                                SuspendedRequest, run_trace)
from repro.serve.protocol import ENGINE_ATTRS, EngineProtocol
from repro.serve.queue import RequestQueue
from repro.serve.radix import PrefixMatch, RadixNode, RadixPrefixIndex
from repro.serve.request import Request, RequestOutput
from repro.serve.router import DisaggConfig, DisaggRouter, RouterStats
from repro.serve.sched import (DeadlinePolicy, FIFOPolicy, SchedulerPolicy,
                               SLOPolicy, make_policy)
from repro.serve.slots import PagedSlotManager, SlotManager
from repro.serve.spec import RolloutSpec

__all__ = ["BlockAllocator", "blocks_for", "Engine", "EngineConfig",
           "EngineStats", "SuspendedRequest", "run_trace", "RequestQueue",
           "Request", "RequestOutput", "PagedSlotManager", "SlotManager",
           "PrefixMatch", "RadixNode", "RadixPrefixIndex", "SchedulerPolicy",
           "FIFOPolicy", "DeadlinePolicy", "SLOPolicy", "make_policy",
           "KVTransferHandle", "PrefillEngine", "DisaggConfig",
           "DisaggRouter", "RouterStats", "EngineProtocol", "ENGINE_ATTRS",
           "RolloutSpec", "ElasticConfig", "ElasticController",
           "resize_engine", "resize_router", "rederive_slo"]
