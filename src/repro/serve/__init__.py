"""Continuous-batching rollout serving subsystem.

The paper's rollout phase is memory-bandwidth-bound *serving*; this package
makes it a first-class serving problem: ``Request``s flow through a FIFO
``RequestQueue`` into a fixed pool of KV-cache slots (``SlotManager``) and
the ``Engine`` interleaves prefill-into-free-slot admission with batched
single-token decode across all live slots (in-flight batching).  See
``repro.serve.engine`` for the scheduling model and exactness guarantees.
"""
from repro.serve.engine import Engine, EngineConfig, EngineStats, run_trace
from repro.serve.queue import RequestQueue
from repro.serve.request import Request, RequestOutput
from repro.serve.slots import SlotManager

__all__ = ["Engine", "EngineConfig", "EngineStats", "run_trace",
           "RequestQueue", "Request", "RequestOutput", "SlotManager"]
