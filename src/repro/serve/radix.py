"""Content-addressed radix tree over paged KV blocks: prompt-prefix
sharing by *token content*, not caller tags.

Prompts that share a block-aligned token prefix share those KV blocks —
across requests, jobs, tenants and multi-turn episode histories.  The
index is a radix tree in the sglang style: each :class:`RadixNode` owns
exactly one **full** block's worth of prompt tokens and the physical
block id holding that KV, pinned under one allocator ``incref`` for as
long as the node lives.  A node's identity is the content hash of
``(parent_hash, tokens)``, so a path from a root spells out a
block-aligned token prefix and two requests agreeing on any prefix walk
the same path — admission is longest-prefix match
(:meth:`RadixPrefixIndex.match`), with all shared full blocks pinned
instead of re-allocated and the write-masked scatter never touching
them.

**Boundary snapshots.**  Block sharing alone still re-prefills (compute
is not shareable below block granularity); an *exact* repeat of a
registered prompt should admit with zero model compute.  Registration
therefore stores a :class:`BoundarySnapshot` — the partial tail block,
slot-resident rows and post-prompt logits, exactly what a
``KVTransferHandle`` carries — at the final node of the registered
path, keyed by the prompt's residual tail tokens.  A match that covers
every full block *and* finds the tail's snapshot is exact; families
with no paged leaves (rwkv6) degenerate to a snapshot at the root
(prefill-once, nothing to pin).

**Namespaces.**  ``Request.prefix_key`` is no longer what *enables*
sharing (content does); it is an optional isolation namespace — each
distinct key gets its own root, so callers that must not share across a
boundary (e.g. distinct fine-tune tenants) simply key their requests.
``None`` is the global namespace.  Frontend-conditioned requests never
register or match (the engine gates them out: prompt tokens alone do
not identify image/audio-conditioned KV).

**Eviction.**  Under block pressure :meth:`evict_for` frees
least-recently-used *leaves* first (an inner node's block only becomes
reusable once its subtree is gone), skipping nodes whose block is still
shared by a live slot or handle (``refcount > 1``) and the
``protect``\\ ed path of the request being admitted.  Victims are
collected into a heap **once per call** and parents enter it as their
last child is evicted — no per-iteration re-sort.  The eviction
sequence is recorded in :attr:`RadixPrefixIndex.eviction_log` (cleared
on ``flush``) so the strict-LRU contract is testable.

Counter ownership: :meth:`match` with ``count=True`` — the admission
lookup — bumps exactly one of ``hits``/``partial_hits``/``misses`` per
request; capacity probes and the router's KV-aware scoring pass the
default ``count=False`` and never skew the stats.
"""
from __future__ import annotations

import hashlib
import heapq
from dataclasses import dataclass, field
from typing import Any, Iterator, Optional

import numpy as np

__all__ = ["BoundarySnapshot", "PrefixMatch", "RadixNode",
           "RadixPrefixIndex"]


def _content_hash(parent_hash: bytes, token_bytes: bytes) -> bytes:
    return hashlib.sha1(parent_hash + token_bytes).digest()


class RadixNode:
    """One full KV block of prompt tokens in the tree.

    ``tokens`` (``block_size`` int32s) is the edge label from ``parent``;
    ``block_id`` is the physical block pinned on this node's behalf
    (``None`` on namespace roots, which own no KV).  ``block_hash`` is
    the sglang-style ``(parent_hash, tokens)`` content id: equal hashes
    ⇔ equal block-aligned prefixes within a namespace.  ``snapshots``
    maps residual tail tokens (bytes) to the :class:`BoundarySnapshot`
    registered at this boundary."""

    __slots__ = ("node_id", "parent", "children", "tokens", "key",
                 "block_id", "block_hash", "snapshots", "last_used")

    def __init__(self, node_id: int, parent: Optional["RadixNode"],
                 tokens: Optional[np.ndarray], block_id: Optional[int],
                 block_hash: bytes, last_used: int = 0):
        self.node_id = node_id
        self.parent = parent
        self.children: dict[bytes, RadixNode] = {}
        self.tokens = tokens
        self.key = tokens.tobytes() if tokens is not None else b""
        self.block_id = block_id
        self.block_hash = block_hash
        self.snapshots: dict[bytes, BoundarySnapshot] = {}
        self.last_used = last_used

    @property
    def is_root(self) -> bool:
        return self.parent is None

    def __repr__(self) -> str:  # pragma: no cover — debugging aid
        return (f"RadixNode(id={self.node_id}, block={self.block_id}, "
                f"children={len(self.children)}, "
                f"snapshots={len(self.snapshots)})")


@dataclass
class BoundarySnapshot:
    """Zero-compute admission state at a registered prompt boundary: the
    donor's partial tail block, slot-resident rows and post-prompt
    logits (device arrays), keyed under its node by ``tail_tokens`` —
    the prompt tokens past the last full block."""
    sid: int
    tail_tokens: np.ndarray
    logits: Any
    tail: dict
    slot_leaves: dict
    hits: int = 0
    last_used: int = 0             # LRU tick of registration / last hit


@dataclass
class PrefixMatch:
    """Longest-prefix match result: the walked node path (one node per
    shared full block, root excluded) and, when the whole prompt is
    covered, the boundary snapshot for zero-compute admission."""
    namespace: Any
    nodes: list = field(default_factory=list)
    snapshot: Optional[BoundarySnapshot] = None

    @property
    def n_shared(self) -> int:
        return len(self.nodes)

    @property
    def exact(self) -> bool:
        return self.snapshot is not None

    @property
    def block_ids(self) -> list[int]:
        return [n.block_id for n in self.nodes]

    @property
    def node_ids(self) -> list[int]:
        return [n.node_id for n in self.nodes]


class RadixPrefixIndex:
    """Radix tree of registered prompt prefixes over one block pool.

    The tree holds one ``incref`` per node — blocks stay resident after
    every sharing slot releases, until LRU eviction under pressure
    (:meth:`evict_for`) or a weight-sync :meth:`flush` unpins them.
    ``len(index)`` is the number of block-bearing nodes."""

    def __init__(self, alloc):
        self.alloc = alloc
        self.block_size = alloc.block_size
        self.roots: dict[Any, RadixNode] = {}      # namespace -> root
        self.nodes: dict[int, RadixNode] = {}      # block-bearing nodes
        self.hits = 0                  # exact-match admissions
        self.partial_hits = 0          # block-sharing admissions
        self.misses = 0                # admissions that found nothing
        self.evictions = 0             # nodes evicted under pressure
        self.snapshot_demotions = 0    # snapshots dropped by TTL demotion
        self.eviction_log: list[int] = []   # node ids, eviction order
        self._tick = 0                 # LRU clock
        self._next_id = 0
        self._next_sid = 0
        self._n_snapshots = 0

    # ---- bookkeeping -------------------------------------------------------
    def __len__(self) -> int:
        return len(self.nodes)

    def _bump(self) -> int:
        self._tick += 1
        return self._tick

    def _fresh_id(self) -> int:
        self._next_id += 1
        return self._next_id - 1

    def _fresh_sid(self) -> int:
        self._next_sid += 1
        return self._next_sid - 1

    @staticmethod
    def _tok(req) -> np.ndarray:
        return np.asarray(req.prompt, np.int32).reshape(-1)[:req.prompt_len]

    def _root(self, namespace, *, create: bool = False
              ) -> Optional[RadixNode]:
        root = self.roots.get(namespace)
        if root is None and create:
            root = RadixNode(self._fresh_id(), None, None, None,
                             hashlib.sha1(repr(namespace).encode()).digest())
            self.roots[namespace] = root
        return root

    def _all_nodes(self) -> Iterator[RadixNode]:
        yield from self.roots.values()
        yield from self.nodes.values()

    # ---- lookup ------------------------------------------------------------
    def match(self, req, *, count: bool = False) -> Optional[PrefixMatch]:
        """Longest block-aligned prefix of ``req.prompt`` registered under
        its namespace (``req.prefix_key``); ``None`` when nothing
        matches.

        ``count=True`` marks this as the request's *admission* lookup
        and bumps exactly one of the hit/partial/miss counters — this
        method owns all counter accounting; callers never bump them."""
        tokens = self._tok(req)
        bs = self.block_size
        n_full = len(tokens) // bs
        nodes: list[RadixNode] = []
        snapshot = None
        node = self._root(req.prefix_key)
        if node is not None:
            for d in range(n_full):
                child = node.children.get(
                    tokens[d * bs:(d + 1) * bs].tobytes())
                if child is None:
                    break
                nodes.append(child)
                node = child
            if len(nodes) == n_full:
                snapshot = node.snapshots.get(tokens[n_full * bs:].tobytes())
        if count:
            if snapshot is not None:
                self.hits += 1
            elif nodes:
                self.partial_hits += 1
            else:
                self.misses += 1
        if snapshot is None and not nodes:
            return None
        return PrefixMatch(namespace=req.prefix_key, nodes=nodes,
                           snapshot=snapshot)

    def touch(self, m: PrefixMatch) -> None:
        """Bump recency along a matched path (LRU protection for the
        admission about to share it).  Counters are ``match``'s job."""
        t = self._bump()
        root = self.roots.get(m.namespace)
        if root is not None:
            root.last_used = t
        for node in m.nodes:
            node.last_used = t
        if m.snapshot is not None:
            m.snapshot.hits += 1
            m.snapshot.last_used = t

    # ---- registration ------------------------------------------------------
    def register(self, req, block_ids, *, logits, tail,
                 slot_leaves) -> RadixNode:
        """Record a freshly prefilled (or adopted) prompt: walk/extend the
        namespace's tree with one node per full block — new nodes pin
        their block with an ``incref`` of the registering slot's table
        entry; blocks whose content already has a node keep the
        incumbent's pin — and store the boundary snapshot at the final
        node (first donor wins per distinct tail)."""
        tokens = self._tok(req)
        bs = self.block_size
        t = self._bump()
        node = self._root(req.prefix_key, create=True)
        node.last_used = t
        for d, bid in enumerate(block_ids):
            chunk = tokens[d * bs:(d + 1) * bs]
            key = chunk.tobytes()
            child = node.children.get(key)
            if child is None:
                child = RadixNode(self._fresh_id(), node, chunk.copy(),
                                  int(bid),
                                  _content_hash(node.block_hash, key))
                node.children[key] = child
                self.nodes[child.node_id] = child
                self.alloc.incref(int(bid))
            child.last_used = t
            node = child
        tail_key = tokens[len(block_ids) * bs:].tobytes()
        if tail_key not in node.snapshots:
            node.snapshots[tail_key] = BoundarySnapshot(
                sid=self._fresh_sid(),
                tail_tokens=tokens[len(block_ids) * bs:].copy(),
                logits=logits, tail=tail, slot_leaves=slot_leaves,
                last_used=t)
            self._n_snapshots += 1
        return node

    # ---- eviction ----------------------------------------------------------
    def _evictable(self, node: RadixNode, protect: frozenset) -> bool:
        return (not node.children and not node.is_root
                and node.node_id not in protect
                and self.alloc.refcount.get(node.block_id, 0) == 1)

    def _evict_node(self, node: RadixNode) -> None:
        assert not node.children, "evicting a non-leaf radix node"
        self.alloc.decref(node.block_id)
        del node.parent.children[node.key]
        del self.nodes[node.node_id]
        self._n_snapshots -= len(node.snapshots)
        node.snapshots.clear()
        self.evictions += 1
        self.eviction_log.append(node.node_id)

    def evict_for(self, n_blocks: int, *, protect=()) -> bool:
        """LRU-evict leaf nodes until ``n_blocks`` can be reserved.

        Candidates are collected **once**: every current leaf whose
        block no live slot/handle still shares (tree-only
        ``refcount == 1``) and whose id is not in ``protect`` (the path
        the pending request would share from).  A parent becomes a
        candidate the moment its last child is evicted — pushed onto the
        same heap, keeping the whole call ``O(n log n)`` instead of the
        old re-sort-per-victim loop.  Heap order is strict LRU:
        ``register``/``touch`` bump whole paths, so a parent is never
        less recent than its children and leaf-first never violates
        recency order.  Returns whether the reservation now fits."""
        if self.alloc.can_reserve(n_blocks):
            return True
        protect = frozenset(protect)
        heap: list[tuple[int, int]] = [
            (node.last_used, node.node_id)
            for node in self.nodes.values()
            if self._evictable(node, protect)]
        heapq.heapify(heap)
        while heap and not self.alloc.can_reserve(n_blocks):
            _, nid = heapq.heappop(heap)
            node = self.nodes.get(nid)
            if node is None or not self._evictable(node, protect):
                continue
            parent = node.parent
            self._evict_node(node)
            if not parent.is_root and self._evictable(parent, protect):
                heapq.heappush(heap, (parent.last_used, parent.node_id))
        return self.alloc.can_reserve(n_blocks)

    def demote_stale(self, ttl: int) -> int:
        """Age-based snapshot demotion: drop every boundary snapshot not
        touched within the last ``ttl`` LRU ticks (``register``/``touch``
        calls).  Long-lived servers otherwise hold snapshot device arrays
        until block-pressure eviction or a weight-sync flush — boundary
        snapshots are *not* allocator blocks, so ``evict_for`` pressure
        never reclaims a snapshot whose node the tree keeps.  The tree
        structure (and its block pins) is untouched: a demoted prompt
        still block-shares, it just re-prefills its tail on the next
        exact repeat.  Returns the number demoted (also accumulated in
        ``snapshot_demotions`` / the ``stats`` dict)."""
        if ttl < 0:
            raise ValueError("ttl must be >= 0")
        horizon = self._tick - ttl
        n = 0
        for node in self._all_nodes():
            stale = [k for k, s in node.snapshots.items()
                     if s.last_used < horizon]
            for k in stale:
                del node.snapshots[k]
            n += len(stale)
        self._n_snapshots -= n
        self.snapshot_demotions += n
        return n

    def flush(self) -> int:
        """Drop the whole tree (weight sync: every cached prefill is
        stale), unpinning every node's block.  Not counted as
        evictions.  Returns the number of nodes + snapshots dropped."""
        n = len(self.nodes) + self._n_snapshots
        for node in self.nodes.values():
            self.alloc.decref(node.block_id)
        self.nodes.clear()
        self.roots.clear()
        self._n_snapshots = 0
        self.eviction_log.clear()
        return n

    # ---- introspection -----------------------------------------------------
    def pinned_blocks(self) -> list[int]:
        """Block ids currently pinned by the tree (one per node)."""
        return [node.block_id for node in self.nodes.values()]

    @property
    def stats(self) -> dict:
        return {"nodes": len(self.nodes),
                "entries": self._n_snapshots,
                "snapshots": self._n_snapshots,
                "hits": self.hits,
                "partial_hits": self.partial_hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "snapshot_demotions": self.snapshot_demotions,
                "pinned_blocks": len(self.nodes)}

    # ---- checkpoint --------------------------------------------------------
    def export_device_state(self) -> dict:
        """Snapshot pytrees (device arrays), keyed by snapshot id."""
        return {snap.sid: {"logits": snap.logits, "tail": snap.tail,
                           "slot_leaves": snap.slot_leaves}
                for node in self._all_nodes()
                for snap in node.snapshots.values()}

    def export_host_state(self) -> dict:
        """Tree structure + counters (host data only — parent links by
        node id, tokens as arrays, snapshots by sid)."""
        return {
            "roots": [{"id": r.node_id, "namespace": ns,
                       "last_used": r.last_used}
                      for ns, r in self.roots.items()],
            "nodes": [{"id": n.node_id, "parent": n.parent.node_id,
                       "tokens": n.tokens.copy(), "block_id": n.block_id,
                       "last_used": n.last_used}
                      for n in self.nodes.values()],
            "snapshots": [{"sid": s.sid, "node": n.node_id,
                           "tail_tokens": s.tail_tokens.copy(),
                           "hits": s.hits, "last_used": s.last_used}
                          for n in self._all_nodes()
                          for s in n.snapshots.values()],
            "counters": {"tick": self._tick, "hits": self.hits,
                         "partial_hits": self.partial_hits,
                         "misses": self.misses,
                         "evictions": self.evictions,
                         "demotions": self.snapshot_demotions,
                         "next_id": self._next_id,
                         "next_sid": self._next_sid},
        }

    def import_state(self, host: Optional[dict], device: dict) -> None:
        """Rebuild the tree from :meth:`export_host_state` +
        :meth:`export_device_state`.  Structural only — the block pins
        the nodes stand behind travel in the allocator's own exported
        state, so nothing is increfed here (mirroring the engine's
        alloc import)."""
        self.roots.clear()
        self.nodes.clear()
        self._n_snapshots = 0
        self.eviction_log.clear()
        if not host:
            return
        by_id: dict[int, RadixNode] = {}
        for r in host["roots"]:
            ns = r["namespace"]
            root = RadixNode(
                r["id"], None, None, None,
                hashlib.sha1(repr(ns).encode()).digest(),
                last_used=r["last_used"])
            self.roots[ns] = root
            by_id[root.node_id] = root
        # parents are always created before children (smaller ids), so
        # the tree rebuilds in id order without a second pass
        for n in sorted(host["nodes"], key=lambda d: d["id"]):
            parent = by_id[n["parent"]]
            tokens = np.asarray(n["tokens"], np.int32)
            node = RadixNode(
                n["id"], parent, tokens, int(n["block_id"]),
                _content_hash(parent.block_hash, tokens.tobytes()),
                last_used=n["last_used"])
            parent.children[node.key] = node
            self.nodes[node.node_id] = node
            by_id[node.node_id] = node
        for s in host["snapshots"]:
            d = device[s["sid"]]
            node = by_id[s["node"]]
            tt = np.asarray(s["tail_tokens"], np.int32)
            node.snapshots[tt.tobytes()] = BoundarySnapshot(
                sid=s["sid"], tail_tokens=tt, logits=d["logits"],
                tail=d["tail"], slot_leaves=d["slot_leaves"],
                hits=s["hits"], last_used=s.get("last_used", 0))
            self._n_snapshots += 1
        c = host["counters"]
        self._tick = c["tick"]
        self.hits = c["hits"]
        self.partial_hits = c["partial_hits"]
        self.misses = c["misses"]
        self.evictions = c["evictions"]
        self.snapshot_demotions = c.get("demotions", 0)
        self._next_id = c["next_id"]
        self._next_sid = c["next_sid"]
