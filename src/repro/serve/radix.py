"""Block-granular prefix-sharing index for the paged KV cache (SGLang-style
radix sharing, specialised to the rollout-serving workload).

GRPO submits every prompt ``group`` times (one request per group member),
so the prompt's KV is byte-identical across ``group`` live requests.  This
index makes that sharing real at block granularity, on top of
``BlockAllocator``'s existing ``incref``/``decref``:

* the **first** member of a prefix (the *donor*) prefills normally into
  its own freshly allocated blocks; ``register`` then records, under the
  request's ``prefix_key``, the prompt's *full* blocks (positions a decode
  step can never write again) plus a small device snapshot — the partial
  tail block's KV, the slot-resident cache rows (SSM/conv state,
  cross-attention KV) and the post-prompt logits — and increfs the full
  blocks so they outlive the donor;
* every **later** member with the same key and prompt (``match`` →
  ``exact``) skips prefill compute entirely: its slot pins the shared full
  blocks (incref per sharer, several slot owners per block) and receives a
  private **copy-on-write tail** — the first block its decode diverges
  into is materialized from its own reservation and seeded from the
  snapshot, so shared blocks are never written (the engine's decode
  write-back only touches the block containing the slot's own ``index``,
  which lies at or beyond the tail);
* a request whose prompt merely *extends* a registered prefix
  (block-granular match, not exact) still prefills — compute is not
  shareable — but pins the matching full blocks instead of allocating
  them, scattering its prefill through a write-masked table row whose
  shared entries point at the null block (paged admission then gates on
  **net-new** blocks only).

Entries are LRU-evicted (``evict_for``) when admission runs out of
uncommitted blocks: dropping an entry only releases the *index's* pin —
live sharers keep theirs, so eviction is always safe.  ``flush`` drops
everything (the engine does this on ``reset``: new params invalidate every
cached prefill).  Greedy tokens/logprobs stay bit-identical to the
unshared engine: shared blocks hold the donor's prefill output, which is
THE prefill output for that prompt, and gathers are permutation-copies.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

from repro.serve.blocks import BlockAllocator
from repro.serve.request import Request


@dataclass
class RadixEntry:
    """One registered prompt prefix: pinned full blocks + admit snapshot."""
    key: Any
    tokens: np.ndarray                 # donor's full prompt (int32, host)
    block_ids: tuple[int, ...]         # the prompt's FULL blocks, in order
    prompt_len: int
    logits: Any                        # (vocab,) post-prompt logits (device)
    tail: dict                         # paged leaves' partial tail block
    #                                    {name: (L, bs, *rest)} — empty when
    #                                    the prompt ends on a block boundary
    slot_leaves: dict                  # non-paged cache rows (batch=1 pytree)
    hits: int = 0
    last_used: int = 0
    meta: dict = field(default_factory=dict)


class RadixPrefixIndex:
    """Prefix entries keyed by ``Request.prefix_key``, pinned in a
    :class:`~repro.serve.blocks.BlockAllocator` via incref/decref."""

    def __init__(self, alloc: BlockAllocator):
        self.alloc = alloc
        self.block_size = alloc.block_size
        self.entries: dict[Any, RadixEntry] = {}
        self._tick = 0
        self.hits = 0                  # exact hits (prefill skipped)
        self.partial_hits = 0          # block-prefix hits (blocks shared)
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self.entries)

    # ---- lookup ------------------------------------------------------------
    def match(self, req: Request) -> tuple[Optional[RadixEntry], int, bool]:
        """Longest block-granular prefix match for ``req``.

        Returns ``(entry, n_shared, exact)``: ``n_shared`` full blocks of
        the request's prompt are already resident (token-verified — the key
        is a tag, the tokens are the truth), and ``exact`` means the whole
        prompt matches so prefill can be skipped.  Shared blocks are capped
        at the request's own full-block count: the block its decode writes
        into is never shared.
        """
        if req.prefix_key is None:
            return None, 0, False
        entry = self.entries.get(req.prefix_key)
        if entry is None:
            return None, 0, False
        prompt = req.prompt
        exact = (entry.prompt_len == req.prompt_len
                 and np.array_equal(entry.tokens, prompt))
        # full blocks the request itself will never write again
        req_full = req.prompt_len // self.block_size
        common = min(len(entry.block_ids), req_full) * self.block_size
        eq = entry.tokens[:common] == prompt[:common]
        n_shared = (int(common // self.block_size) if eq.all()
                    else int(np.argmin(eq)) // self.block_size)
        return entry, n_shared, exact

    def touch(self, entry: RadixEntry, *, exact: bool) -> None:
        self._tick += 1
        entry.last_used = self._tick
        entry.hits += 1
        if exact:
            self.hits += 1
        else:
            self.partial_hits += 1

    # ---- registration ------------------------------------------------------
    def register(self, req: Request, block_ids, *, logits, tail,
                 slot_leaves) -> RadixEntry:
        """Pin the donor's full prompt blocks under this index and cache the
        admit snapshot.  No-op (returns the existing entry) if the key is
        already registered — first donor wins until flush/evict."""
        if req.prefix_key in self.entries:
            return self.entries[req.prefix_key]
        for bid in block_ids:
            self.alloc.incref(bid)
        self._tick += 1
        entry = RadixEntry(
            key=req.prefix_key, tokens=np.array(req.prompt, np.int32),
            block_ids=tuple(int(b) for b in block_ids),
            prompt_len=req.prompt_len, logits=logits, tail=tail,
            slot_leaves=slot_leaves, last_used=self._tick)
        self.entries[req.prefix_key] = entry
        return entry

    # ---- eviction ----------------------------------------------------------
    def evict(self, key: Any) -> None:
        """Drop one entry: release the index's pin on its blocks (sharers
        keep theirs — blocks free only when the last owner lets go)."""
        entry = self.entries.pop(key)
        for bid in entry.block_ids:
            self.alloc.decref(bid)
        self.evictions += 1

    def evict_for(self, n_blocks: int, *, protect: Any = None) -> bool:
        """LRU-evict entries until ``n_blocks`` can be reserved (or nothing
        *useful* is left to evict).  ``protect`` names a key that must
        survive — the entry the pending admission is about to share from.

        Only entries whose eviction actually frees memory are touched: an
        entry whose blocks are all still pinned by live sharer slots frees
        nothing when dropped (the sharers keep their refs), and evicting
        it would just destroy sharing for the group's remaining members —
        so such entries are skipped rather than sacrificed pointlessly
        (admissibility probes call this as a side effect)."""
        while not self.alloc.can_reserve(n_blocks):
            victims = sorted(
                (e for k, e in self.entries.items()
                 if k != protect
                 and any(self.alloc.refcount.get(b, 0) == 1
                         for b in e.block_ids)),
                key=lambda e: e.last_used)
            if not victims:
                return self.alloc.can_reserve(n_blocks)
            self.evict(victims[0].key)
        return True

    def flush(self) -> int:
        """Drop every entry (params changed / engine reset); returns how
        many were flushed.  Every index pin must be gone afterwards — an
        entry surviving here would leak its blocks across engine resets,
        which is exactly what ``BlockAllocator.assert_clean`` (called by
        ``Engine.reset`` right after this) would then trip on."""
        n = len(self.entries)
        for key in list(self.entries):
            self.evict(key)
        self.evictions -= n                  # flushes aren't pressure events
        assert not self.entries, "flush left radix entries behind"
        return n

    # ---- accounting --------------------------------------------------------
    def pinned_blocks(self) -> set[int]:
        """Distinct block ids currently pinned by the index itself."""
        return {b for e in self.entries.values() for b in e.block_ids}

    @property
    def stats(self) -> dict:
        return {"entries": len(self.entries), "hits": self.hits,
                "partial_hits": self.partial_hits, "misses": self.misses,
                "evictions": self.evictions,
                "pinned_blocks": len(self.pinned_blocks())}
