"""GRPO / PPO objectives (the paper's workloads train with these, §4.4)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class GRPOConfig:
    group_size: int = 4          # completions per prompt
    clip_eps: float = 0.2
    kl_coef: float = 0.0
    adv_eps: float = 1.0e-4


def group_advantages(rewards: np.ndarray, group_size: int,
                     eps: float = 1e-4) -> np.ndarray:
    """GRPO: advantage = (r - mean_group) / (std_group + eps).

    rewards: (B,) where B = n_prompts * group_size, grouped contiguously.
    """
    r = rewards.reshape(-1, group_size)
    mean = r.mean(axis=1, keepdims=True)
    std = r.std(axis=1, keepdims=True)
    return ((r - mean) / (std + eps)).reshape(-1).astype(np.float32)


def token_logprobs(logits, labels):
    """logits: (B,S,V) fp32; labels: (B,S) -> (B,S) log p(label)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    return jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]


def policy_gradient_loss(logits, labels, advantages, loss_mask,
                         behavior_logp=None, clip_eps: float = 0.2):
    """Clipped-ratio policy gradient (PPO/GRPO); ratio=1 when no behaviour
    logprobs are given (pure on-policy single update, the paper's setting).

    logits (B,S,V), labels/advantages/loss_mask (B,S). Returns (loss, metrics).
    """
    logp = token_logprobs(logits, labels)
    adv = advantages
    denom = jnp.maximum(loss_mask.sum(), 1.0)
    if behavior_logp is None:
        pg = -(logp * adv * loss_mask).sum() / denom
        clip_frac = jnp.zeros(())
        ratio_mean = jnp.ones(())
        ratio_max = jnp.ones(())
    else:
        ratio = jnp.exp(logp - behavior_logp)
        unclipped = ratio * adv
        clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps) * adv
        pg = -(jnp.minimum(unclipped, clipped) * loss_mask).sum() / denom
        clip_frac = ((jnp.abs(ratio - 1) > clip_eps) * loss_mask).sum() / denom
        # off-policy drift diagnostics: how far the sampled (behaviour)
        # policy has drifted from the trained one — the quantity the
        # staleness guard bounds and the clipping corrects.  Masked stats
        # only (padding rows carry ratio exp(0-0)=1 and would dilute them).
        ratio_mean = (ratio * loss_mask).sum() / denom
        ratio_max = jnp.max(jnp.where(loss_mask > 0, ratio, 1.0))
    ent = -(jax.nn.softmax(logits) * jax.nn.log_softmax(logits)).sum(-1)
    entropy = (ent * loss_mask).sum() / denom
    return pg, {"pg_loss": pg, "entropy": entropy, "clip_frac": clip_frac,
                "ratio_mean": ratio_mean, "ratio_max": ratio_max}
