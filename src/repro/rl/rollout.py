"""Rollout phase: batched autoregressive generation with a KV cache.

This is the memory-bandwidth-bound phase of the paper's workload model.
Generation runs prefill once then a lax.scan of decode steps; per-token
behaviour logprobs are recorded for the (optionally off-policy-corrected)
training phase.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.data import tokenizer as tok
from repro.models.model import Model


@dataclass(frozen=True)
class SamplerConfig:
    max_new_tokens: int = 16
    temperature: float = 1.0
    eos_id: int = tok.EOS


@partial(jax.jit, static_argnames=("model", "sampler"))
def generate(model: Model, params, prompts, rng, sampler: SamplerConfig,
             frontend=None):
    """prompts: (B, Sp) int32 -> dict with tokens/completions/logprobs/mask.

    Completion stops contributing (mask=0) after the first EOS; token length
    is static (max_new_tokens) as in a fixed-budget rollout.
    """
    B, Sp = prompts.shape
    T = sampler.max_new_tokens
    cache = model.init_cache(B, Sp + T)
    logits, cache = model.prefill(params, prompts, cache, frontend=frontend)

    def sample(logits, key):
        if sampler.temperature == 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / sampler.temperature, axis=-1).astype(jnp.int32)

    def step(carry, key):
        logits, cache, alive = carry
        nxt = sample(logits, key)                            # (B,)
        logp = jax.nn.log_softmax(logits, -1)
        tok_logp = jnp.take_along_axis(logp, nxt[:, None], -1)[:, 0]
        logits, cache = model.decode_step(params, nxt[:, None], cache)
        mask = alive.astype(jnp.float32)
        alive = alive & (nxt != sampler.eos_id)
        return (logits, cache, alive), (nxt, tok_logp, mask)

    keys = jax.random.split(rng, T)
    alive0 = jnp.ones((B,), bool)
    (_, cache, _), (toks, logps, mask) = jax.lax.scan(
        step, (logits, cache, alive0), keys)
    completions = jnp.moveaxis(toks, 0, 1)                   # (B,T)
    return {
        "prompts": prompts,
        "completions": completions,
        "tokens": jnp.concatenate([prompts, completions], axis=1),
        "behavior_logp": jnp.moveaxis(logps, 0, 1),          # (B,T)
        "mask": jnp.moveaxis(mask, 0, 1),                    # (B,T) fp32
    }


#: Legacy per-call engine-shape kwargs and their defaults — the pre-
#: ``RolloutSpec`` surface the deprecation shim keeps alive.
_LEGACY_DEFAULTS = dict(num_slots=None, block_size=1, kv_layout="contiguous",
                        kv_block_size=16, num_kv_blocks=None, sched="fifo",
                        prefix_share=False, disagg=None, kernel_backend="jnp",
                        kv_dtype=None)
_warned_legacy = [False]


def _resolve_spec(spec, group, job_id, legacy: dict):
    """Fold the legacy per-call kwargs and ``spec`` into one
    ``RolloutSpec``.  Passing engine-shape kwargs without a spec still
    works — once per process it warns to migrate; passing both raises
    rather than silently picking a winner.  ``group``/``job_id`` stay
    per-call (they describe the batch, not the engine) and override the
    spec's own."""
    import warnings

    from repro.serve import RolloutSpec

    non_default = {k: v for k, v in legacy.items()
                   if v != _LEGACY_DEFAULTS[k]}
    if spec is None:
        if non_default and not _warned_legacy[0]:
            _warned_legacy[0] = True
            warnings.warn(
                "passing engine-shape kwargs (num_slots/kv_layout/...) to "
                "the rollout executors is deprecated; build a "
                "repro.serve.RolloutSpec and pass spec=",
                DeprecationWarning, stacklevel=3)
        spec = RolloutSpec(**legacy)
    elif non_default:
        raise ValueError(
            f"spec= given alongside legacy engine kwargs "
            f"{sorted(non_default)}; move them into the RolloutSpec")
    if group is not None:
        spec = spec.replace(group=group)
    if job_id is not None:
        spec = spec.replace(job_id=job_id)
    return spec


def _engine_session(model, params, prompts_np, rng, sampler: SamplerConfig,
                    frontend, *, spec, engine, policy):
    """Shared engine setup for the batch and streaming rollout executors:
    build the engine ``spec`` describes (or validate + ``reset`` a
    persistent one) and turn the prompt rows into the pending request
    deque."""
    from collections import deque

    from repro.serve import Request

    B, Sp = prompts_np.shape
    T = sampler.max_new_tokens
    if engine is None:
        engine = spec.build_engine(
            model, params, batch=B, max_seq_len=Sp + T,
            eos_id=sampler.eos_id, temperature=sampler.temperature,
            rng=rng, policy=policy)
    else:
        cfg = engine.config
        if cfg.max_seq_len < Sp + T:
            raise ValueError(
                f"persistent engine max_seq_len {cfg.max_seq_len} "
                f"< prompt {Sp} + budget {T}")
        # the engine's sampling behaviour is baked into its jitted fns —
        # a sampler that disagrees would be silently ignored, so refuse
        if (cfg.temperature, cfg.eos_id) != (sampler.temperature,
                                             sampler.eos_id):
            raise ValueError(
                f"persistent engine serves temperature={cfg.temperature}, "
                f"eos_id={cfg.eos_id} but sampler asks for "
                f"temperature={sampler.temperature}, eos_id={sampler.eos_id}")
        if cfg.kv_layout != spec.kv_layout:
            raise ValueError(
                f"persistent engine kv_layout={cfg.kv_layout!r} != "
                f"requested {spec.kv_layout!r}")
        if spec.prefix_share and not cfg.prefix_share:
            raise ValueError("persistent engine was built without "
                             "prefix_share")
        # decode backend and KV storage dtype are baked into the jitted
        # fns / pool layout — a disagreeing request would silently serve
        # the engine's own configuration, so refuse
        if cfg.kernel_backend != spec.kernel_backend:
            raise ValueError(
                f"persistent engine kernel_backend="
                f"{cfg.kernel_backend!r} != requested "
                f"{spec.kernel_backend!r}")
        if cfg.kv_dtype != spec.kv_dtype:
            raise ValueError(
                f"persistent engine kv_dtype={cfg.kv_dtype!r} != "
                f"requested {spec.kv_dtype!r}")
        engine.reset(params, rng)
    pending = deque()
    for i in range(B):
        fr = None if frontend is None else frontend[i:i + 1]
        # sharing is content-addressed: GRPO's group-of-N duplicate rows
        # (and any cross-group common preamble) match in the radix tree
        # by token content alone — prefix_key only selects an isolation
        # namespace when the spec asks for one
        pending.append(Request(rid=i, prompt=prompts_np[i],
                               max_new_tokens=T, frontend=fr,
                               prefix_key=spec.prefix_namespace,
                               job_id=spec.job_id))
    return engine, pending


def generate_continuous(model, params, prompts, rng, sampler: SamplerConfig,
                        frontend=None, *, spec=None,
                        num_slots: int | None = None,
                        block_size: int = 1, kv_layout: str = "contiguous",
                        kv_block_size: int = 16,
                        num_kv_blocks: int | None = None, engine=None,
                        sched: str = "fifo", policy=None,
                        prefix_share: bool = False, group: int | None = None,
                        job_id: str | None = None, disagg=None,
                        kernel_backend: str = "jnp",
                        kv_dtype: str | None = None):
    """Rollout-phase executor backed by the continuous-batching engine.

    Drop-in alternative to :func:`generate`: same inputs, same output dict
    ((B, T) completions / behaviour logprobs / mask, T = max_new_tokens),
    so GRPO training consumes it unchanged.  Internally each prompt row
    becomes a ``repro.serve.Request`` served by ``repro.serve.Engine`` over
    ``num_slots`` KV-cache slots (default: one per request) — with fewer
    slots than requests the engine queues and recycles, which is the
    serving regime the paper's rollout pool actually runs in.
    ``kv_layout="paged"`` serves from the block-pool KV layout
    (``kv_block_size`` tokens per block, ``num_kv_blocks`` pool size) —
    same outputs, heterogeneous lengths share memory.

    Greedy decoding (``temperature=0``) is token- and logprob-identical to
    per-request :func:`generate`; sampled decoding draws per-step keys from
    ``rng`` via the engine (a different, equally valid stream than
    ``generate``'s).

    ``engine`` lets a training driver reuse one persistent (drained)
    :class:`~repro.serve.Engine` across GRPO iterations: the call swaps in
    freshly synced ``params`` and the new key stream via ``Engine.reset``
    (which also flushes the prefix index — new weights invalidate cached
    prefills) and serves from the existing slot pool / jit cache (the mux
    trainer's rollout actor).  The engine must have been built for the
    same model and a compatible ``max_seq_len``.

    ``sched`` / ``policy`` pick the admission policy
    (``repro.serve.sched``; a policy object wins — pass e.g.
    ``SLOPolicy.from_contract(...)`` to enforce a co-execution group's
    slowdown bound).  ``prefix_share=True`` (paged only) enables the
    content-addressed radix tree: any requests agreeing on a
    block-aligned token prefix — GRPO's ``group``-way duplicated
    prompts, a shared few-shot preamble across groups, a multi-turn
    episode's own history — share those KV blocks automatically, with
    exact repeats admitted at zero model compute; no tag is needed
    (``spec.prefix_namespace`` optionally isolates tenants that must not
    share).  ``job_id`` tags requests for per-job token budgets in
    deadline/SLO policies.

    ``disagg`` serves through disaggregated prefill/decode pools
    (``repro.serve.router.DisaggRouter``) instead of one monolithic
    engine — same outputs, bit for bit under greedy decoding.  Pass
    ``True`` (splits ``num_slots`` 1:3 prefill:decode), a dict of
    ``DisaggConfig`` overrides (``prefill_slots``, ``decode_slots``,
    ``prefill_kv_blocks``, ``decode_kv_blocks``, ...), or a full
    ``DisaggConfig``.  A persistent ``engine`` may itself be a
    ``DisaggRouter`` — ``reset`` drops un-adopted transfer handles and
    asserts both pools leak-free.

    ``kernel_backend="pallas"`` serves decode through the batched Pallas
    decode-attention kernels (token-identical to the default vmapped-step
    path; see ``serve.engine.EngineConfig``), and ``kv_dtype="int8"``
    (paged only) stores KV blocks quantized with per-position scales —
    roughly double the live requests at equal KV memory for a bounded
    logprob perturbation.  Both are baked into a persistent engine; a
    mismatching request raises rather than silently serving the engine's
    own configuration.

    ``spec`` bundles all the engine-shape kwargs above into one
    :class:`~repro.serve.RolloutSpec` — the consolidated surface both
    launch entrypoints use.  The loose kwargs keep working (a one-time
    ``DeprecationWarning`` nudges migration); passing both raises.
    """
    import numpy as np

    spec = _resolve_spec(spec, group, job_id, dict(
        num_slots=num_slots, block_size=block_size, kv_layout=kv_layout,
        kv_block_size=kv_block_size, num_kv_blocks=num_kv_blocks,
        sched=sched, prefix_share=prefix_share, disagg=disagg,
        kernel_backend=kernel_backend, kv_dtype=kv_dtype))
    B, Sp = prompts.shape
    T = sampler.max_new_tokens
    prompts_np = np.asarray(prompts, np.int32)
    engine, pending = _engine_session(
        model, params, prompts_np, rng, sampler, frontend,
        spec=spec, engine=engine, policy=policy)
    # backpressure-aware drive: a full queue (max_waiting) defers
    # submission until the engine drains instead of crashing
    while pending or not engine.idle:
        while pending and engine.submit(pending[0]):
            pending.popleft()
        if not engine.idle:
            engine.step()
    outs = [engine.finished[r] for r in sorted(engine.finished)]

    completions = np.full((B, T), sampler.eos_id, np.int32)
    behavior_logp = np.zeros((B, T), np.float32)
    mask = np.zeros((B, T), np.float32)
    token_versions = np.full((B, T), -1, np.int32)
    for o in outs:
        n = o.num_tokens
        completions[o.rid, :n] = o.tokens
        behavior_logp[o.rid, :n] = o.logprobs
        mask[o.rid, :n] = 1.0
        token_versions[o.rid, :n] = o.token_versions
    completions = jnp.asarray(completions)
    return {
        "prompts": prompts,
        "completions": completions,
        "tokens": jnp.concatenate([prompts, completions], axis=1),
        "behavior_logp": jnp.asarray(behavior_logp),
        "mask": jnp.asarray(mask),
        "token_versions": token_versions,
        "engine_stats": engine.metrics(),
    }


def generate_continuous_stream(model, params, prompts, rng,
                               sampler: SamplerConfig, frontend=None, *,
                               spec=None, sync_params=None,
                               group: int | None = None,
                               num_slots: int | None = None,
                               block_size: int = 1,
                               kv_layout: str = "contiguous",
                               kv_block_size: int = 16,
                               num_kv_blocks: int | None = None, engine=None,
                               sched: str = "fifo", policy=None,
                               prefix_share: bool = False,
                               job_id: str | None = None, disagg=None,
                               kernel_backend: str = "jnp",
                               kv_dtype: str | None = None):
    """Streaming rollout executor: yield completed GRPO prompt **groups**
    the moment their last member finishes decoding, while the engine keeps
    serving the stragglers.

    Same engine computation as :func:`generate_continuous` — identical
    tokens, behaviour logprobs and masks — but instead of one dict after a
    full drain, this generator yields one dict per prompt group (``group``
    consecutive rows; each row its own group when ``group`` is None/1), in
    **completion order**, each with:

    ``group_index``
        ``rid // group`` — position of the group's prompt in the batch.
    ``rows``
        the global row indices (ascending) the group's arrays map to.
    ``completions`` / ``behavior_logp`` / ``mask``
        ``(group, T)`` arrays with exactly the padding semantics of the
        batch executor (EOS-fill / zero-fill past each row's length), so
        stacking every yielded group by ``rows`` reproduces
        ``generate_continuous``'s output arrays bit for bit.

    This is the engine-side half of the paper's sub-phase bubble
    reclamation: finished groups flow to reward verification and training
    micro-batches (``rl.stream``) while decode is still in flight — the
    driver pulls via :meth:`Engine.harvest` (partial harvest, no drain).

    ``sync_params`` is partial-rollout continuation across weight syncs:
    a zero-argument callable returning ``(params, version)`` with the
    newest synced weights, polled between scheduler ticks.  When the
    version advances mid-rollout the engine weight-syncs *live* —
    ``reset(carry_live=True)`` suspends every in-flight generation,
    swaps weights, and resumes them with outputs carried forward — so
    stragglers finish on fresh weights instead of the iteration-start
    ones.  Each group dict then carries ``token_versions`` (the
    per-token behaviour-weight provenance; ``-1`` past each row's
    length) feeding the clipped importance-ratio diagnostics.
    ``spec``/loose-kwargs semantics are those of
    :func:`generate_continuous`.
    """
    import numpy as np

    spec = _resolve_spec(spec, group, job_id, dict(
        num_slots=num_slots, block_size=block_size, kv_layout=kv_layout,
        kv_block_size=kv_block_size, num_kv_blocks=num_kv_blocks,
        sched=sched, prefix_share=prefix_share, disagg=disagg,
        kernel_backend=kernel_backend, kv_dtype=kv_dtype))
    B, Sp = prompts.shape
    T = sampler.max_new_tokens
    g = spec.group or 1
    prompts_np = np.asarray(prompts, np.int32)
    engine, pending = _engine_session(
        model, params, prompts_np, rng, sampler, frontend,
        spec=spec, engine=engine, policy=policy)
    engine.harvest()                    # drop any stale pre-session leftovers
    synced_version = None
    if sync_params is not None:
        _, synced_version = sync_params()   # session-start baseline
    buckets: dict[int, list] = {}
    sizes = [min(B, (gi + 1) * g) - gi * g for gi in range((B + g - 1) // g)]

    def drain_finished():
        for o in engine.harvest():
            gi = o.rid // g
            buckets.setdefault(gi, []).append(o)
            if len(buckets[gi]) == sizes[gi]:
                yield _group_dict(gi, buckets.pop(gi))

    def _group_dict(gi: int, outs: list):
        outs = sorted(outs, key=lambda o: o.rid)
        n_rows = len(outs)
        completions = np.full((n_rows, T), sampler.eos_id, np.int32)
        behavior_logp = np.zeros((n_rows, T), np.float32)
        mask = np.zeros((n_rows, T), np.float32)
        token_versions = np.full((n_rows, T), -1, np.int32)
        for r, o in enumerate(outs):
            n = o.num_tokens
            completions[r, :n] = o.tokens
            behavior_logp[r, :n] = o.logprobs
            mask[r, :n] = 1.0
            token_versions[r, :n] = o.token_versions
        return {"group_index": gi,
                "rows": [o.rid for o in outs],
                "completions": completions,
                "behavior_logp": behavior_logp,
                "mask": mask,
                "token_versions": token_versions}

    def _maybe_carry_sync():
        nonlocal synced_version
        if sync_params is None:
            return
        new_params, version = sync_params()
        if version == synced_version:
            return
        synced_version = version
        engine.reset(new_params, carry_live=True)

    # backpressure-aware drive, harvesting between scheduler ticks
    while pending or not engine.idle:
        while pending and engine.submit(pending[0]):
            pending.popleft()
        if not engine.idle:
            engine.step()
        yield from drain_finished()
        _maybe_carry_sync()
    yield from drain_finished()         # anything finalized by the last tick


def completions_to_text(completions, mask) -> list[str]:
    import numpy as np
    out = []
    for row, m in zip(np.asarray(completions), np.asarray(mask)):
        ids = [int(t) for t, mi in zip(row, m) if mi > 0 and int(t) != tok.EOS]
        out.append(tok.decode(ids))
    return out
