"""Rollout phase: batched autoregressive generation with a KV cache.

This is the memory-bandwidth-bound phase of the paper's workload model.
Generation runs prefill once then a lax.scan of decode steps; per-token
behaviour logprobs are recorded for the (optionally off-policy-corrected)
training phase.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.data import tokenizer as tok
from repro.models.model import Model


@dataclass(frozen=True)
class SamplerConfig:
    max_new_tokens: int = 16
    temperature: float = 1.0
    eos_id: int = tok.EOS


@partial(jax.jit, static_argnames=("model", "sampler"))
def generate(model: Model, params, prompts, rng, sampler: SamplerConfig,
             frontend=None):
    """prompts: (B, Sp) int32 -> dict with tokens/completions/logprobs/mask.

    Completion stops contributing (mask=0) after the first EOS; token length
    is static (max_new_tokens) as in a fixed-budget rollout.
    """
    B, Sp = prompts.shape
    T = sampler.max_new_tokens
    cache = model.init_cache(B, Sp + T)
    logits, cache = model.prefill(params, prompts, cache, frontend=frontend)

    def sample(logits, key):
        if sampler.temperature == 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(
            key, logits / sampler.temperature, axis=-1).astype(jnp.int32)

    def step(carry, key):
        logits, cache, alive = carry
        nxt = sample(logits, key)                            # (B,)
        logp = jax.nn.log_softmax(logits, -1)
        tok_logp = jnp.take_along_axis(logp, nxt[:, None], -1)[:, 0]
        logits, cache = model.decode_step(params, nxt[:, None], cache)
        mask = alive.astype(jnp.float32)
        alive = alive & (nxt != sampler.eos_id)
        return (logits, cache, alive), (nxt, tok_logp, mask)

    keys = jax.random.split(rng, T)
    alive0 = jnp.ones((B,), bool)
    (_, cache, _), (toks, logps, mask) = jax.lax.scan(
        step, (logits, cache, alive0), keys)
    completions = jnp.moveaxis(toks, 0, 1)                   # (B,T)
    return {
        "prompts": prompts,
        "completions": completions,
        "tokens": jnp.concatenate([prompts, completions], axis=1),
        "behavior_logp": jnp.moveaxis(logps, 0, 1),          # (B,T)
        "mask": jnp.moveaxis(mask, 0, 1),                    # (B,T) fp32
    }


def generate_continuous(model, params, prompts, rng, sampler: SamplerConfig,
                        frontend=None, *, num_slots: int | None = None,
                        block_size: int = 1, kv_layout: str = "contiguous",
                        kv_block_size: int = 16,
                        num_kv_blocks: int | None = None, engine=None,
                        sched: str = "fifo", policy=None,
                        prefix_share: bool = False, group: int | None = None,
                        job_id: str | None = None):
    """Rollout-phase executor backed by the continuous-batching engine.

    Drop-in alternative to :func:`generate`: same inputs, same output dict
    ((B, T) completions / behaviour logprobs / mask, T = max_new_tokens),
    so GRPO training consumes it unchanged.  Internally each prompt row
    becomes a ``repro.serve.Request`` served by ``repro.serve.Engine`` over
    ``num_slots`` KV-cache slots (default: one per request) — with fewer
    slots than requests the engine queues and recycles, which is the
    serving regime the paper's rollout pool actually runs in.
    ``kv_layout="paged"`` serves from the block-pool KV layout
    (``kv_block_size`` tokens per block, ``num_kv_blocks`` pool size) —
    same outputs, heterogeneous lengths share memory.

    Greedy decoding (``temperature=0``) is token- and logprob-identical to
    per-request :func:`generate`; sampled decoding draws per-step keys from
    ``rng`` via the engine (a different, equally valid stream than
    ``generate``'s).

    ``engine`` lets a training driver reuse one persistent (drained)
    :class:`~repro.serve.Engine` across GRPO iterations: the call swaps in
    freshly synced ``params`` and the new key stream via ``Engine.reset``
    (which also flushes the prefix index — new weights invalidate cached
    prefills) and serves from the existing slot pool / jit cache (the mux
    trainer's rollout actor).  The engine must have been built for the
    same model and a compatible ``max_seq_len``.

    ``sched`` / ``policy`` pick the admission policy
    (``repro.serve.sched``; a policy object wins — pass e.g.
    ``SLOPolicy.from_contract(...)`` to enforce a co-execution group's
    slowdown bound).  ``prefix_share=True`` (paged only) enables radix
    prompt-prefix KV sharing, and ``group`` tags every ``group``
    consecutive rows — GRPO's duplicated prompts — with a shared
    ``prefix_key`` so the group prefills once and its prompt blocks are
    pinned, not copied.  ``job_id`` tags requests for per-job token
    budgets in deadline/SLO policies.
    """
    import numpy as np

    from repro.serve import Engine, EngineConfig, Request

    B, Sp = prompts.shape
    T = sampler.max_new_tokens
    prompts_np = np.asarray(prompts, np.int32)
    if engine is None:
        engine = Engine(model, params, EngineConfig(
            num_slots=B if num_slots is None else num_slots,
            max_seq_len=Sp + T,
            eos_id=sampler.eos_id, temperature=sampler.temperature,
            block_size=block_size, kv_layout=kv_layout,
            kv_block_size=kv_block_size, num_kv_blocks=num_kv_blocks,
            sched=sched, prefix_share=prefix_share),
            rng=rng, policy=policy)
    else:
        cfg = engine.config
        if cfg.max_seq_len < Sp + T:
            raise ValueError(
                f"persistent engine max_seq_len {cfg.max_seq_len} "
                f"< prompt {Sp} + budget {T}")
        # the engine's sampling behaviour is baked into its jitted fns —
        # a sampler that disagrees would be silently ignored, so refuse
        if (cfg.temperature, cfg.eos_id) != (sampler.temperature,
                                             sampler.eos_id):
            raise ValueError(
                f"persistent engine serves temperature={cfg.temperature}, "
                f"eos_id={cfg.eos_id} but sampler asks for "
                f"temperature={sampler.temperature}, eos_id={sampler.eos_id}")
        if cfg.kv_layout != kv_layout:
            raise ValueError(
                f"persistent engine kv_layout={cfg.kv_layout!r} != "
                f"requested {kv_layout!r}")
        if prefix_share and not cfg.prefix_share:
            raise ValueError("persistent engine was built without "
                             "prefix_share")
        engine.reset(params, rng)
    from collections import deque
    pending = deque()
    for i in range(B):
        fr = None if frontend is None else frontend[i:i + 1]
        # one shared prefix key per GRPO prompt group: rows i*group ..
        # (i+1)*group-1 are the same prompt repeated
        key = ((job_id, i // group)
               if engine.radix is not None and group else None)
        pending.append(Request(rid=i, prompt=prompts_np[i],
                               max_new_tokens=T, frontend=fr,
                               prefix_key=key, job_id=job_id))
    # backpressure-aware drive: a full queue (max_waiting) defers
    # submission until the engine drains instead of crashing
    while pending or not engine.idle:
        while pending and engine.submit(pending[0]):
            pending.popleft()
        if not engine.idle:
            engine.step()
    outs = [engine.finished[r] for r in sorted(engine.finished)]

    completions = np.full((B, T), sampler.eos_id, np.int32)
    behavior_logp = np.zeros((B, T), np.float32)
    mask = np.zeros((B, T), np.float32)
    for o in outs:
        n = o.num_tokens
        completions[o.rid, :n] = o.tokens
        behavior_logp[o.rid, :n] = o.logprobs
        mask[o.rid, :n] = 1.0
    completions = jnp.asarray(completions)
    return {
        "prompts": prompts,
        "completions": completions,
        "tokens": jnp.concatenate([prompts, completions], axis=1),
        "behavior_logp": jnp.asarray(behavior_logp),
        "mask": jnp.asarray(mask),
        "engine_stats": engine.stats,
    }


def completions_to_text(completions, mask) -> list[str]:
    import numpy as np
    out = []
    for row, m in zip(np.asarray(completions), np.asarray(mask)):
        ids = [int(t) for t, mi in zip(row, m) if mi > 0 and int(t) != tok.EOS]
        out.append(tok.decode(ids))
    return out
