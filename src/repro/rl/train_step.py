"""The training phase: RL policy-gradient step (fwd+bwd+AdamW), with
microbatched gradient accumulation and activation checkpointing — this is
what ``train_4k`` lowers in the dry-run."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


def make_loss_fn(model: Model, *, remat: bool = True, clip_eps: float = 0.2):
    from repro.rl.grpo import policy_gradient_loss

    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch["tokens"],
                                    frontend=batch.get("frontend"),
                                    remat=remat)
        pg, metrics = policy_gradient_loss(
            logits, batch["labels"], batch["advantages"], batch["loss_mask"],
            behavior_logp=batch.get("behavior_logp"), clip_eps=clip_eps)
        loss = pg + aux
        metrics = dict(metrics, moe_aux=aux, loss=loss)
        return loss, metrics

    return loss_fn


def make_train_step(model: Model, opt_cfg: AdamWConfig = AdamWConfig(), *,
                    microbatches: int = 1, remat: bool = True,
                    lr_schedule=None):
    """Returns train_step(state, batch) -> (state, metrics).

    state = {"params": ..., "opt": ...}. ``microbatches`` > 1 scans gradient
    accumulation over the leading batch dim (memory lever for 32B+ archs).
    """
    loss_fn = make_loss_fn(model, remat=remat)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(state, batch):
        params = state["params"]
        if microbatches <= 1:
            (_, metrics), grads = grad_fn(params, batch)
        else:
            def mb_slice(i, x):
                size = x.shape[0] // microbatches
                return jax.lax.dynamic_slice_in_dim(x, i * size, size, 0)

            def acc_step(carry, i):
                gsum = carry
                mb = jax.tree.map(partial(mb_slice, i), batch)
                (_, m), g = grad_fn(params, mb)
                return jax.tree.map(jnp.add, gsum, g), m

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            gsum, ms = jax.lax.scan(acc_step, zeros,
                                    jnp.arange(microbatches))
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            metrics = jax.tree.map(lambda m: m[-1], ms)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state["opt"], params, opt_cfg, lr_schedule)
        return {"params": new_params, "opt": new_opt}, metrics | opt_metrics

    return train_step


def init_train_state(model: Model, key, opt_cfg: AdamWConfig = AdamWConfig()):
    params = model.init(key)
    return {"params": params, "opt": adamw_init(params, opt_cfg)}
