"""Verifiable rewards (RLVR): pluggable verifiers over generated answers.

Every verifier shares one signature — ``fn(completions, mask, answers) ->
(B,) float32`` — and is **row-wise**: row ``i``'s reward depends only on
row ``i``'s completion and answer.  That contract is what lets the
streaming mux (``rl.stream``) verify each GRPO prompt group the moment it
finishes decoding, on a reward-pool worker, without changing the math:
per-group verification concatenated in row order is bit-identical to one
batch-at-once call.

Shipped verifiers:

* :func:`arithmetic_reward` — exact-match numeric verification (the
  original task reward).
* :func:`length_penalty_reward` — exact match with a per-token length
  penalty beyond a target budget (rewards concise answers).
* :func:`format_reward` — regex/format checking: full-match against a
  pattern (default: a bare integer) earns the format point independent of
  numeric correctness.
* :class:`ExternalVerifier` — the *slow verifier* stub: wraps any reward
  fn behind a configurable (deterministically jittered) latency, modeling
  an external judge / sandbox / unit-test runner whose verdict takes real
  wall time.  This is the workload the reward permit pool exists for —
  verification runs off the critical path while the engine decodes
  stragglers and the trainer steps.
* :class:`CompositeReward` — weighted sum of verifiers (still row-wise).

``make_reward`` is the factory behind ``launch/train.py --reward`` /
``--reward-latency``.
"""
from __future__ import annotations

import re
import threading
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.rl.rollout import completions_to_text

RewardFn = Callable[..., np.ndarray]


def arithmetic_reward(completions, mask, answers: list[str]) -> np.ndarray:
    """1.0 for exact numeric match, +0.1 shaping for a digit-only prefix."""
    texts = completions_to_text(completions, mask)
    out = np.zeros(len(texts), np.float32)
    for i, (txt, ans) in enumerate(zip(texts, answers)):
        txt = txt.strip()
        if txt == ans:
            out[i] = 1.0
        elif txt and all(c in "-0123456789" for c in txt):
            out[i] = 0.1
    return out


def length_penalty_reward(completions, mask, answers: list[str], *,
                          target_tokens: int = 4,
                          penalty_per_token: float = 0.05) -> np.ndarray:
    """Exact-match reward with a length penalty: every recorded token
    beyond ``target_tokens`` costs ``penalty_per_token`` (floored at the
    shaping level).  Rewards answers that are both right and concise —
    the verifier RL-with-verifiable-rewards setups use to stop length
    inflation."""
    base = arithmetic_reward(completions, mask, answers)
    lengths = np.asarray(mask).sum(axis=1)
    over = np.maximum(lengths - target_tokens, 0.0)
    return np.maximum(base - penalty_per_token * over, 0.0).astype(np.float32)


def format_reward(completions, mask, answers: Optional[list[str]] = None, *,
                  pattern: str = r"-?\d+") -> np.ndarray:
    """Regex/format checker: 1.0 when the stripped completion full-matches
    ``pattern`` (default: a bare, possibly negative integer), else 0.0.
    Independent of numeric correctness — the "did the model answer in the
    required format" verifier."""
    texts = completions_to_text(completions, mask)
    rx = re.compile(pattern)
    return np.asarray([1.0 if rx.fullmatch(t.strip()) else 0.0
                       for t in texts], np.float32)


class ExternalVerifier:
    """Slow external-verifier stub: delegate to ``base`` after a
    configurable latency.

    ``latency_s`` is the mean verdict latency; ``jitter`` adds a
    deterministic per-call uniform perturbation in ``[-jitter, +jitter] *
    latency_s`` drawn from a seeded stream, so repeated runs see the same
    latency sequence (benchmarks stay comparable) while calls still
    interleave non-trivially across reward-pool workers.  The sleep
    releases the GIL, which is exactly how a real external judge behaves
    from the driver's point of view: the reward worker blocks, the engine
    and trainer do not.
    """

    def __init__(self, base: RewardFn = arithmetic_reward, *,
                 latency_s: float = 0.1, jitter: float = 0.0, seed: int = 0):
        if latency_s < 0:
            raise ValueError("latency_s must be >= 0")
        if not 0.0 <= jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1] (fraction of latency)")
        self.base = base
        self.latency_s = latency_s
        self.jitter = jitter
        self._rng = np.random.default_rng(seed)
        self._lock = threading.Lock()
        self.calls = 0

    def __call__(self, completions, mask, answers) -> np.ndarray:
        with self._lock:                    # deterministic draw order
            self.calls += 1
            delay = self.latency_s
            if self.jitter:
                delay *= 1.0 + float(self._rng.uniform(-self.jitter,
                                                       self.jitter))
        if delay > 0:
            time.sleep(delay)
        return self.base(completions, mask, answers)


class CompositeReward:
    """Weighted sum of row-wise verifiers (itself row-wise)."""

    def __init__(self, parts: Sequence[tuple[RewardFn, float]]):
        if not parts:
            raise ValueError("CompositeReward needs at least one part")
        self.parts = list(parts)

    def __call__(self, completions, mask, answers) -> np.ndarray:
        out = np.zeros(np.asarray(mask).shape[0], np.float32)
        for fn, w in self.parts:
            out += w * fn(completions, mask, answers)
        return out


_NAMED: dict[str, RewardFn] = {
    "arith": arithmetic_reward,
    "length": length_penalty_reward,
    "format": format_reward,
}


def make_reward(name: str = "arith", *, latency_s: float = 0.0,
                jitter: float = 0.0, seed: int = 0) -> RewardFn:
    """Factory behind ``--reward`` / ``--reward-latency``.

    ``name`` picks the verifier (``arith`` | ``length`` | ``format`` |
    ``composite`` = arith + 0.25*format - length folded in); a nonzero
    ``latency_s`` wraps it in an :class:`ExternalVerifier` so rollout
    drivers can model slow external judgment without changing rewards."""
    if name == "composite":
        fn: RewardFn = CompositeReward([(arithmetic_reward, 1.0),
                                        (format_reward, 0.25)])
    elif name in _NAMED:
        fn = _NAMED[name]
    else:
        raise ValueError(f"unknown reward {name!r} "
                         f"(choose from {sorted(_NAMED) + ['composite']})")
    if latency_s > 0:
        fn = ExternalVerifier(fn, latency_s=latency_s, jitter=jitter,
                              seed=seed)
    return fn
