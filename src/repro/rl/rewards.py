"""Verifiable rewards (RLVR): exact-match verification of generated answers."""
from __future__ import annotations

import numpy as np

from repro.rl.rollout import completions_to_text


def arithmetic_reward(completions, mask, answers: list[str]) -> np.ndarray:
    """1.0 for exact numeric match, +0.1 shaping for a digit-only prefix."""
    texts = completions_to_text(completions, mask)
    out = np.zeros(len(texts), np.float32)
    for i, (txt, ans) in enumerate(zip(texts, answers)):
        txt = txt.strip()
        if txt == ans:
            out[i] = 1.0
        elif txt and all(c in "-0123456789" for c in txt):
            out[i] = 0.1
    return out
