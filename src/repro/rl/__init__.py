from repro.rl.grpo import GRPOConfig, group_advantages, policy_gradient_loss
from repro.rl.rollout import (SamplerConfig, completions_to_text, generate,
                              generate_continuous)
from repro.rl.rewards import arithmetic_reward
from repro.rl.train_step import init_train_state, make_loss_fn, make_train_step
from repro.rl.coexec import (GRPOJob, MuxConfig, MuxReport, build_train_batch,
                             run_coexec, run_pipelined, run_sequential)

__all__ = ["GRPOConfig", "group_advantages", "policy_gradient_loss",
           "SamplerConfig", "generate", "generate_continuous",
           "completions_to_text", "arithmetic_reward", "init_train_state",
           "make_loss_fn", "make_train_step", "GRPOJob", "MuxConfig",
           "MuxReport", "build_train_batch", "run_coexec", "run_pipelined",
           "run_sequential"]
