from repro.rl.grpo import GRPOConfig, group_advantages, policy_gradient_loss
from repro.rl.rollout import (SamplerConfig, completions_to_text, generate,
                              generate_continuous, generate_continuous_stream)
from repro.rl.rewards import (CompositeReward, ExternalVerifier,
                              arithmetic_reward, format_reward,
                              length_penalty_reward, make_reward)
from repro.rl.train_step import init_train_state, make_loss_fn, make_train_step
from repro.rl.coexec import (GRPOJob, MuxConfig, MuxReport, build_train_batch,
                             run_coexec, run_pipelined, run_sequential)
from repro.rl.stream import run_streaming
from repro.rl.agentic import (CountdownToolEnv, Environment, Episode, Turn,
                              run_episodes)

__all__ = ["GRPOConfig", "group_advantages", "policy_gradient_loss",
           "SamplerConfig", "generate", "generate_continuous",
           "generate_continuous_stream", "completions_to_text",
           "arithmetic_reward", "length_penalty_reward", "format_reward",
           "ExternalVerifier", "CompositeReward", "make_reward",
           "init_train_state", "make_loss_fn", "make_train_step", "GRPOJob",
           "MuxConfig", "MuxReport", "build_train_batch", "run_coexec",
           "run_pipelined", "run_sequential", "run_streaming",
           "Environment", "CountdownToolEnv", "Episode", "Turn",
           "run_episodes"]
