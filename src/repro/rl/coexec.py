"""Phase-multiplexed GRPO execution — the paper's two-tier runtime (§5).

This module turns "engine + simulator side-by-side" into the actual
co-execution plane: GRPO loops run their rollout phase through the
continuous-batching ``serve.Engine`` (or the static ``generate`` scan) and
their training phase through ``rl.train_step``, scheduled by
``core.phase_control`` run permits so the dependency bubble between the
two phases is reclaimed instead of serialized away.

Four executors, selected by ``launch/train.py --mux`` (the fourth,
:func:`repro.rl.stream.run_streaming`, lives in ``rl/stream.py`` — it
pipelines *inside* the job at GRPO-group granularity with a third
"reward" permit pool); the three whole-phase executors here:

* :func:`run_sequential` (``--mux off``) — the standard-disaggregation
  baseline: rollout and training back-to-back in one thread.  Phases still
  run under run permits, so the executed timeline (and hence the measured
  bubble) is recorded the same way as the multiplexed modes.
* :func:`run_pipelined` (``--mux pipeline``) — single job: the rollout of
  GRPO iteration ``k+1`` overlaps with the training step of iteration
  ``k``, behind an **on-policy staleness guard**: the rollout weights may
  lag the trained weights by at most ``max_staleness`` optimizer steps
  (``0`` forces full synchronization and is bit-exact to ``off``).  The
  off-policy drift a lag of ``>= 1`` introduces is exactly what the
  clipped importance ratio in :func:`repro.rl.grpo.policy_gradient_loss`
  corrects — behaviour logprobs are recorded by the engine per token.
* :func:`run_coexec` (``--mux coexec``) — two or more logical jobs
  time-multiplex the shared rollout/train pools round-robin (intra-group
  FIFO permits): while job A holds the ``train`` permit, job B's rollout
  drains through the serving engine.  Between phases each job's state is
  suspended to the host-DRAM actor cache and warm-started back
  (``device_put``), so per-job losses are bit-exact to running the job
  alone — co-execution changes the schedule, never the math.

Every executor returns a :class:`MuxReport` whose per-pool timelines
measure the reclaimed bubble and export measured
:class:`~repro.core.phase_control.PhaseProfile` records for the
co-execution simulator (``core.simulator.simulate_profiles``).
"""
from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.phase_control import PhaseProfile, RollMuxRuntime
from repro.data import ArithmeticTask
from repro.models import build_model
from repro.rl.grpo import group_advantages
from repro.rl.rewards import arithmetic_reward
from repro.rl.rollout import SamplerConfig, generate, generate_continuous
from repro.rl.train_step import init_train_state, make_train_step
from repro.train.optimizer import AdamWConfig, warmup_cosine


def build_train_batch(out, adv, prompt_len):
    """Rollout output + GRPO advantages -> the train-step batch dict."""
    tokens = out["tokens"][:, :-1]
    labels = out["tokens"][:, 1:]
    B, T = out["completions"].shape
    zeros = jnp.zeros((B, prompt_len - 1), jnp.float32)
    loss_mask = jnp.concatenate([zeros, out["mask"]], axis=1)
    advm = jnp.broadcast_to(jnp.asarray(adv)[:, None], (B, T))
    advantages = jnp.concatenate([zeros, advm], axis=1)
    return {"tokens": tokens, "labels": labels, "loss_mask": loss_mask,
            "advantages": advantages,
            "behavior_logp": jnp.concatenate([zeros, out["behavior_logp"]], 1)}


@dataclass(frozen=True)
class MuxConfig:
    """Phase-multiplexing knobs (see module docstring / ``--mux``)."""
    mode: str = "off"                 # "off" | "pipeline" | "coexec" | "stream"
    max_staleness: int = 1            # pipeline/stream: optimizer steps the
    #                                   rollout weights may lag (0 = sync,
    #                                   bit-exact to the sequential path)
    host_cache_gb: float = 8.0        # coexec actor-cache budget
    reward_workers: int = 2           # stream: reward permit-pool capacity
    micro_groups: Optional[int] = None    # stream: groups per train
    #                                       micro-step (None = one full-batch
    #                                       optimizer step per iteration —
    #                                       the bit-exact default)

    def __post_init__(self):
        if self.mode not in ("off", "pipeline", "coexec", "stream"):
            raise ValueError(f"unknown mux mode {self.mode!r}")
        if self.max_staleness < 0:
            raise ValueError("max_staleness must be >= 0")
        if self.reward_workers < 1:
            raise ValueError("reward_workers must be >= 1")
        if self.micro_groups is not None and self.micro_groups < 1:
            raise ValueError("micro_groups must be >= 1 (or None)")


@functools.lru_cache(maxsize=32)
def _jitted_train_step(model, opt_cfg: AdamWConfig, steps: int):
    """One jitted train step per (model, optimizer, schedule) — co-executing
    jobs with the same training shape share the compilation (keyed on the
    hashable frozen ``Model``), like the engine's jit cache."""
    return jax.jit(make_train_step(
        model, opt_cfg, lr_schedule=warmup_cosine(opt_cfg.lr, 10, steps)))


class GRPOJob:
    """One logical RL post-training job: model, task stream, sampler and
    jitted train step, with its rollout phase routed through either the
    static ``generate`` scan or the continuous-batching serving engine.

    The job is executor-agnostic: every executor drives the same two
    methods (:meth:`rollout_step`, :meth:`train_phase`) in iteration order,
    so losses are identical across ``off`` / ``pipeline``(sync) / ``coexec``
    by construction.  Task batches and rollout keys are drawn from per-job
    streams in call order — executors must call ``rollout_step`` with
    ``k = 0, 1, 2, ...`` exactly once each (they do).
    """

    def __init__(self, job_id: str, model=None, *, arch: str = "internlm2-1.8b",
                 reduced: bool = True, seed: int = 0, steps: int = 50,
                 batch: int = 8, group: int = 4, max_new: int = 8,
                 lr: float = 3e-4, temperature: float = 1.0,
                 rollout: str = "static", num_slots: Optional[int] = None,
                 engine_block_size: int = 1, kv: str = "contiguous",
                 kv_block_size: int = 16, num_kv_blocks: Optional[int] = None,
                 sched: str = "fifo", prefix_share: bool = False,
                 kernel_backend: str = "jnp",
                 kv_dtype: Optional[str] = None,
                 token_budget: Optional[int] = None, slo_bound: float = 2.0,
                 reward_fn=None, spec=None, carry: bool = False):
        from repro.serve import RolloutSpec

        if rollout not in ("static", "engine"):
            raise ValueError(f"unknown rollout backend {rollout!r}")
        self.job_id = job_id
        self.model = model or build_model(arch, reduced=reduced)
        self.seed = seed
        self.steps = steps
        self.batch = batch
        self.group = group
        self.lr = lr
        self.rollout = rollout
        if spec is None:
            spec = RolloutSpec(
                num_slots=num_slots, block_size=engine_block_size,
                kv_layout=kv, kv_block_size=kv_block_size,
                num_kv_blocks=num_kv_blocks, sched=sched,
                prefix_share=prefix_share, kernel_backend=kernel_backend,
                kv_dtype=kv_dtype, carry=carry)
        # the spec is the single source for the engine shape; the loose
        # attributes below mirror it for existing call sites
        self.spec = spec.replace(group=group, job_id=job_id,
                                 carry=spec.carry or carry)
        self.carry = self.spec.carry
        self.num_slots = self.spec.num_slots
        self.engine_block_size = self.spec.block_size
        self.kv = self.spec.kv_layout
        self.kv_block_size = self.spec.kv_block_size
        self.num_kv_blocks = self.spec.num_kv_blocks
        self.sched = self.spec.sched
        self.prefix_share = self.spec.prefix_share
        self.kernel_backend = self.spec.kernel_backend
        self.kv_dtype = self.spec.kv_dtype
        # per-job token budget for deadline/SLO admission: what one run
        # permit lets this job put in flight — a full GRPO iteration's
        # rollout (batch * group members, max_new decode tokens each).
        # A co-executed engine serving several jobs then cannot let one
        # job's burst monopolise the slot pool beyond its permit's worth.
        self.token_budget = (token_budget if token_budget is not None
                             else batch * group * max_new)
        self.slo_bound = slo_bound
        self.reward_fn = reward_fn or arithmetic_reward
        self.opt_cfg = AdamWConfig(lr=lr)
        self.task = ArithmeticTask(seed=seed)
        self.sampler = SamplerConfig(max_new_tokens=max_new,
                                     temperature=temperature)
        self._train_step = _jitted_train_step(self.model, self.opt_cfg, steps)
        self._key = jax.random.PRNGKey(seed)
        self._engines: dict[int, object] = {}   # max_seq_len -> Engine

    def init_state(self):
        """Fresh optimizer state; also the initial rollout weights."""
        return init_train_state(self.model, jax.random.PRNGKey(self.seed),
                                self.opt_cfg)

    # ---- rollout phase -----------------------------------------------------
    def _make_policy(self):
        """The admission policy this job's engine enforces.  Deadline/SLO
        policies carry the job's token budget (one permit's worth of
        rollout — see ``token_budget``); the SLO policy additionally
        enforces the slowdown bound the inter-group planner admitted the
        job under (``core.InterGroupScheduler.slo_contract``)."""
        from repro.serve.sched import make_policy
        if self.sched == "fifo":
            return make_policy("fifo")
        kw = {"token_budgets": {self.job_id: self.token_budget}}
        if self.sched == "slo":
            kw["slowdown"] = self.slo_bound
        return make_policy(self.sched, **kw)

    def _engine_for(self, num_slots: int, max_seq_len: int):
        """Persistent per-shape engine, reused (jit cache and all) across
        GRPO iterations via ``Engine.reset`` — weight sync swaps params in,
        the slot pool and compiled admit/decode blocks stay."""
        eng = self._engines.get(max_seq_len)
        if eng is None:
            eng = self.spec.build_engine(
                self.model, None, batch=num_slots,
                max_seq_len=max_seq_len, eos_id=self.sampler.eos_id,
                temperature=self.sampler.temperature,
                policy=self._make_policy())
            self._engines[max_seq_len] = eng
        return eng

    def rollout_step(self, params, k: int):
        """Generate completions for iteration ``k`` with weights ``params``.
        Returns ``(task_batch, rollout_out)``; blocks until device work is
        done so permit timelines measure real phase time."""
        b = self.task.sample_batch(self.batch)
        prompts = jnp.asarray(np.repeat(b.prompts, self.group, axis=0))
        self._key, k1 = jax.random.split(self._key)
        if self.rollout == "engine":
            B, Sp = prompts.shape
            eng = self._engine_for(self.num_slots or B,
                                   Sp + self.sampler.max_new_tokens)
            out = generate_continuous(
                self.model, params, prompts, k1, self.sampler,
                engine=eng, spec=self.spec)
        else:
            out = generate(self.model, params, prompts, k1, self.sampler)
        jax.block_until_ready(out["completions"])
        return b, out

    def rollout_stream(self, params, k: int, on_group, on_batch=None,
                       sync_params=None):
        """Streaming rollout for iteration ``k``: ``on_group(gout)`` fires
        the moment each GRPO prompt group finishes decoding (the engine
        keeps serving the stragglers — partial harvest, no drain).  Same
        task batch, key stream and engine computation as
        :meth:`rollout_step`, so the union of the streamed groups is
        bit-identical to the batch rollout.  Returns the task batch;
        ``on_batch(b)``, when given, receives it *before* the engine runs
        — reward workers need the answers before the first group lands.
        ``sync_params`` (engine backend only) enables partial-rollout
        continuation: the newest-weights poll the engine weight-syncs
        against mid-rollout via ``reset(carry_live=True)`` — see
        :func:`~repro.rl.rollout.generate_continuous_stream`.

        The static backend has no sub-phase granularity to expose: it
        generates the whole batch, then emits the groups in row order —
        correct, just without intra-rollout overlap."""
        from repro.rl.rollout import generate_continuous_stream

        b = self.task.sample_batch(self.batch)
        if on_batch is not None:
            on_batch(b)
        prompts = jnp.asarray(np.repeat(b.prompts, self.group, axis=0))
        self._key, k1 = jax.random.split(self._key)
        if self.rollout == "engine":
            B, Sp = prompts.shape
            eng = self._engine_for(self.num_slots or B,
                                   Sp + self.sampler.max_new_tokens)
            for gout in generate_continuous_stream(
                    self.model, params, prompts, k1, self.sampler,
                    engine=eng, spec=self.spec, sync_params=sync_params):
                on_group(gout)
        else:
            out = generate(self.model, params, prompts, k1, self.sampler)
            jax.block_until_ready(out["completions"])
            comp = np.asarray(out["completions"])
            logp = np.asarray(out["behavior_logp"])
            mask = np.asarray(out["mask"])
            g = self.group
            for gi in range(comp.shape[0] // g):
                rows = list(range(gi * g, (gi + 1) * g))
                on_group({"group_index": gi, "rows": rows,
                          "completions": comp[rows],
                          "behavior_logp": logp[rows],
                          "mask": mask[rows]})
        return b

    # ---- reward phase ------------------------------------------------------
    def compute_rewards(self, b, out) -> np.ndarray:
        """Batch-at-once verification (the inline path)."""
        answers = [a for a in b.answers for _ in range(self.group)]
        return self.reward_fn(out["completions"], out["mask"], answers)

    def reward_group(self, b, gout) -> np.ndarray:
        """Verify one streamed group on a reward-pool worker.  Verifiers
        are row-wise (see ``rl.rewards``), so per-group verification
        concatenated in row order is bit-identical to
        :meth:`compute_rewards` on the assembled batch."""
        answers = [b.answers[gout["group_index"]]] * len(gout["rows"])
        return self.reward_fn(gout["completions"], gout["mask"], answers)

    # ---- training phase ----------------------------------------------------
    def train_phase(self, state, b, out, rewards: Optional[np.ndarray] = None):
        """Reward (unless precomputed by the reward pool) -> GRPO
        advantages -> one optimizer step.  Returns ``(state, rec)`` with
        the scalar metrics the history records, including the clipped
        importance-ratio diagnostics that surface off-policy drift under
        staleness > 0."""
        if rewards is None:
            rewards = self.compute_rewards(b, out)
        adv = group_advantages(rewards, self.group)
        tb = build_train_batch(out, adv, b.prompts.shape[1])
        state, metrics = self._train_step(state, tb)
        jax.block_until_ready(metrics["loss"])
        rec = {"reward": float(rewards.mean()),
               "acc": float((rewards >= 1.0).mean()),
               "loss": float(metrics["loss"]),
               "entropy": float(metrics["entropy"]),
               "clip_frac": float(metrics["clip_frac"]),
               "ratio_mean": float(metrics["ratio_mean"]),
               "ratio_max": float(metrics["ratio_max"]),
               "tokens": int(np.asarray(out["mask"]).sum())}
        return state, rec


# ---------------------------------------------------------------------------
# Reporting: measured timelines -> reclaimed bubble + PhaseProfiles
# ---------------------------------------------------------------------------
def _union_s(intervals: list[tuple[float, float]]) -> float:
    """Total length of the union of (possibly overlapping) intervals."""
    ivs = sorted(intervals)
    tot = 0.0
    cur_lo = cur_hi = None
    for lo, hi in ivs:
        if cur_hi is None or lo > cur_hi:
            if cur_hi is not None:
                tot += cur_hi - cur_lo
            cur_lo, cur_hi = lo, hi
        else:
            cur_hi = max(cur_hi, hi)
    if cur_hi is not None:
        tot += cur_hi - cur_lo
    return tot


@dataclass
class MuxReport:
    """What a mux run measured: per-pool busy timelines, the overlap they
    achieved, and the per-job :class:`PhaseProfile` records that feed the
    co-execution simulator.

    Overlap generalizes to any number of pools (rollout/train, plus the
    streaming executor's reward pool): ``overlap_s`` is total busy time
    minus the union of all busy intervals — every second during which two
    or more permits were in flight at once counts once per *extra* permit.
    With only rollout and train this reduces exactly to their pairwise
    intersection, so the two-pool modes report the same numbers as before.
    """
    mode: str
    wall_s: float
    timelines: dict[str, list[tuple[str, float, float]]]
    profiles: dict[str, PhaseProfile] = field(default_factory=dict)
    cache_stats: dict = field(default_factory=dict)

    def _pool_busy_s(self, name: str) -> float:
        return sum(t1 - t0 for _, t0, t1 in self.timelines.get(name, []))

    @property
    def total_rollout_s(self) -> float:
        return self._pool_busy_s("rollout")

    @property
    def total_train_s(self) -> float:
        return self._pool_busy_s("train")

    @property
    def total_reward_s(self) -> float:
        """Reward-pool busy time (0 for executors that verify inline)."""
        return self._pool_busy_s("reward")

    @property
    def _total_busy_s(self) -> float:
        return sum(self._pool_busy_s(p) for p in self.timelines)

    @property
    def overlap_s(self) -> float:
        """Wall time re-claimed by concurrency: total permit-busy seconds
        minus the union of all busy intervals (see class docstring)."""
        all_ivs = [(t0, t1) for tl in self.timelines.values()
                   for _, t0, t1 in tl]
        return self._total_busy_s - _union_s(all_ivs)

    @property
    def bubble_back_to_back_s(self) -> float:
        """The dependency bubble the fully serialized schedule pays: with
        every phase back-to-back, wall time is the sum of all phases while
        the ideal is the busiest pool's total — the difference
        (``sum - max``; ``min(roll, train)`` in the two-pool case) is the
        reclaimable part."""
        busiest = max((self._pool_busy_s(p) for p in self.timelines),
                      default=0.0)
        return self._total_busy_s - busiest

    @property
    def reclaimed_bubble_frac(self) -> float:
        """Fraction of the back-to-back bubble the schedule reclaimed."""
        return self.overlap_s / max(self.bubble_back_to_back_s, 1e-9)

    def summary(self) -> dict:
        return {
            "mode": self.mode,
            "wall_s": self.wall_s,
            "total_rollout_s": self.total_rollout_s,
            "total_train_s": self.total_train_s,
            "total_reward_s": self.total_reward_s,
            "overlap_s": self.overlap_s,
            "bubble_back_to_back_s": self.bubble_back_to_back_s,
            "reclaimed_bubble_frac": self.reclaimed_bubble_frac,
            "cache_stats": dict(self.cache_stats),
        }


def _report(mode: str, rt: RollMuxRuntime, wall_s: float) -> MuxReport:
    return MuxReport(
        mode=mode, wall_s=wall_s,
        timelines={name: list(p.timeline) for name, p in rt.pools.items()},
        profiles=rt.phase_profiles(),
        cache_stats=dict(rt.cache.stats))


def _log(rec: dict, log_every: int, jid: str = "") -> None:
    if log_every and rec["step"] % log_every == 0:
        tag = f"[{jid}] " if jid else ""
        print(f"{tag}step {rec['step']:4d} reward={rec['reward']:.3f} "
              f"acc={rec['acc']:.3f} loss={rec['loss']:.4f} "
              f"entropy={rec['entropy']:.3f}", flush=True)


# ---------------------------------------------------------------------------
# Executors
# ---------------------------------------------------------------------------
def run_sequential(job: GRPOJob, *, steps: Optional[int] = None,
                   runtime: Optional[RollMuxRuntime] = None,
                   log_every: int = 0):
    """``--mux off``: the back-to-back baseline.  Phases run under permits
    so the executed (bubbled) timeline is measured like the mux modes.
    ``steps`` overrides the job's step count (e.g. a short warmup run)."""
    rt = runtime or RollMuxRuntime()
    state = job.init_state()
    history = []
    t0 = time.perf_counter()
    for k in range(job.steps if steps is None else steps):
        with rt.permit("rollout", f"{job.job_id}:roll"):
            b, out = job.rollout_step(state["params"], k)
        with rt.permit("train", f"{job.job_id}:train"):
            state, rec = job.train_phase(state, b, out)
        rec = {"step": k, **rec, "rollout_staleness": 0}
        history.append(rec)
        _log(rec, log_every)
    return state, history, _report("off", rt, time.perf_counter() - t0)


def run_pipelined(job: GRPOJob, *, max_staleness: int = 1,
                  runtime: Optional[RollMuxRuntime] = None,
                  log_every: int = 0):
    """``--mux pipeline``: overlap rollout of iteration ``k+1`` with the
    training step of iteration ``k`` (one job, two permit pools, two
    threads), behind the on-policy staleness guard.

    The rollout thread may generate for iteration ``k`` only once
    ``trained >= k - max_staleness`` optimizer steps have completed, and it
    always uses the *newest* synced weights available when the guard opens.
    ``max_staleness=0`` therefore degenerates to the sequential schedule —
    same weights, same keys, bit-exact losses — while ``>= 1`` buys overlap
    at the price of a bounded, importance-corrected policy lag (recorded
    per step as ``rollout_staleness``)."""
    rt = runtime or RollMuxRuntime()
    steps = job.steps
    state = job.init_state()
    cv = threading.Condition()
    shared = {"params": state["params"], "trained": 0, "err": None}
    rollouts: dict[int, tuple] = {}
    history = []
    t0 = time.perf_counter()

    def roll_loop():
        try:
            for k in range(steps):
                with cv:
                    while (shared["trained"] < k - max_staleness
                           and shared["err"] is None):
                        cv.wait()
                    if shared["err"] is not None:
                        return
                    params = shared["params"]   # newest synced weights
                    version = shared["trained"]
                with rt.permit("rollout", f"{job.job_id}:roll"):
                    b, out = job.rollout_step(params, k)
                with cv:
                    rollouts[k] = (b, out, version)
                    cv.notify_all()
        except BaseException as e:           # surface into the train loop
            with cv:
                shared["err"] = e
                cv.notify_all()

    t = threading.Thread(target=roll_loop, name=f"{job.job_id}-rollout")
    t.start()
    try:
        for k in range(steps):
            with cv:
                while k not in rollouts and shared["err"] is None:
                    cv.wait()
                if shared["err"] is not None:
                    raise shared["err"]
                b, out, version = rollouts.pop(k)
            with rt.permit("train", f"{job.job_id}:train"):
                state, rec = job.train_phase(state, b, out)
            with cv:
                shared["params"] = state["params"]  # weight sync
                shared["trained"] = k + 1
                cv.notify_all()
            rec = {"step": k, **rec, "rollout_staleness": k - version}
            history.append(rec)
            _log(rec, log_every)
    except BaseException:
        with cv:
            if shared["err"] is None:
                shared["err"] = RuntimeError("train loop aborted")
            cv.notify_all()
        raise
    finally:
        t.join()
    return state, history, _report("pipeline", rt, time.perf_counter() - t0)


def run_coexec(jobs: list[GRPOJob], *, host_cache_gb: float = 8.0,
               runtime: Optional[RollMuxRuntime] = None, log_every: int = 0):
    """``--mux coexec``: N logical jobs' GRPO loops time-multiplex the
    shared ``rollout`` / ``train`` permit pools (intra-group FIFO =
    round-robin once saturated).  While one job holds the train permit,
    another's rollout drains through the serving engine.

    Per-job state lives in the host-DRAM actor cache between phases
    (``RollMuxRuntime.phase`` offloads after, warm-starts before), and the
    weight-sync step pushes freshly trained params into the job's rollout
    actor entry — so each job computes exactly what it would alone, and
    nothing but the schedule changes.

    Returns ``(states, histories, report)`` keyed by ``job_id``."""
    rt = runtime or RollMuxRuntime(host_cache_gb=host_cache_gb)
    rt.pool("rollout", 1)
    rt.pool("train", 1)
    for job in jobs:
        state0 = job.init_state()
        rt.seed_state(job.job_id, "train", state0)
        rt.seed_state(job.job_id, "rollout", {"params": state0["params"]})
    histories: dict[str, list] = {j.job_id: [] for j in jobs}
    errors: dict[str, BaseException] = {}

    def make_loop(job: GRPOJob):
        jid = job.job_id

        @rt.phase("rollout", name="roll")
        def roll(rstate, k):
            b, out = job.rollout_step(rstate["params"], k)
            return rstate, (b, out)

        @rt.phase("train", name="train")
        def train(tstate, b, out):
            tstate, rec = job.train_phase(tstate, b, out)
            return tstate, (tstate["params"], rec)

        def loop():
            try:
                for k in range(job.steps):
                    b, out = roll(jid, k)
                    new_params, rec = train(jid, b, out)
                    # weight sync: trained params -> this job's rollout
                    # actor entry (the rollout state is exactly the params,
                    # so overwrite in place — no device round trip)
                    rt.cache.offload(f"{jid}/rollout",
                                     {"params": new_params})
                    rec = {"step": k, **rec, "rollout_staleness": 0}
                    histories[jid].append(rec)
                    _log(rec, log_every, jid)
            except BaseException as e:
                errors[jid] = e
        return loop

    t0 = time.perf_counter()
    threads = [threading.Thread(target=make_loop(j), name=j.job_id)
               for j in jobs]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    if errors:
        jid, e = next(iter(errors.items()))
        raise RuntimeError(f"co-executed job {jid} failed") from e
    states = {}
    for job in jobs:
        state, _ = rt.cache.restore(f"{job.job_id}/train")
        states[job.job_id] = state
    return states, histories, _report("coexec", rt,
                                      time.perf_counter() - t0)
