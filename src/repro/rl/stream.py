"""Streaming phase mux (``--mux stream``): group-level rollout -> reward ->
train pipelining behind a reward permit pool.

The pipeline executor (:func:`repro.rl.coexec.run_pipelined`) reclaims the
rollout<->train bubble at *whole-phase* granularity: rollout ``k+1``
overlaps train ``k``, but inside an iteration the trainer still waits for
the entire rollout batch, and rewards are verified inline on the critical
path.  The remaining bubble lives at sub-phase granularity — and that is
what this executor reclaims:

* **Group streaming** — the engine yields each completed GRPO prompt
  group the moment its last member finishes decoding
  (``rl.rollout.generate_continuous_stream`` over ``Engine.harvest``), so
  early groups flow downstream while stragglers are still decoding.
* **Reward permit pool** — a third pool (capacity ``reward_workers``)
  runs the verifiers (``rl.rewards``: length penalties, format checkers,
  slow external judges) off the critical path.  A group is dispatched to
  a reward worker as soon as it streams out of the engine; with a slow
  verifier this is the difference between paying verification latency
  serially per group and hiding it under decode + train.
* **Micro-batched training** — the trainer consumes rewarded groups as
  they accumulate.  By default it takes one optimizer step per iteration
  over the fully assembled batch, which keeps the math *bit-exact* to the
  pipeline/sequential path; ``micro_groups=m`` instead steps the
  optimizer on every ``m`` rewarded groups (completion order), trading
  exact equivalence for sub-iteration train overlap.
* **Staleness > 1** — the on-policy guard generalizes: the rollout of
  iteration ``k`` may start once ``trained >= k - max_staleness``.  The
  bounded off-policy drift is corrected by the clipped importance ratio
  and *surfaced* per step: every history record carries ``clip_frac`` /
  ``ratio_mean`` / ``ratio_max`` diagnostics next to the realized
  ``rollout_staleness``.

Equivalence contract (locked by ``tests/test_stream.py``): with
``max_staleness=0``, instant rewards and the default full-batch trainer,
``run_streaming`` produces bit-identical losses and params to
``run_pipelined(max_staleness=0)`` — and therefore to ``run_sequential``.
The streaming machinery changes *when* things run, never what is
computed.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Optional

import numpy as np

from repro.core.phase_control import RollMuxRuntime
from repro.rl.coexec import GRPOJob, _log, _report

__all__ = ["run_streaming"]


def _assemble_out(b, gouts: list[dict], group: int):
    """Stack streamed group dicts into the batch-executor output layout.

    ``gouts`` in row order reproduces ``generate_continuous``'s arrays bit
    for bit; in completion order (micro-batching) the rows are simply
    permuted and each group still lines up with its own advantages."""
    import jax.numpy as jnp

    rows = np.concatenate([np.asarray(g["rows"], np.int64) for g in gouts])
    prompts_rep = np.repeat(np.asarray(b.prompts), group, axis=0)[rows]
    completions = np.concatenate([g["completions"] for g in gouts])
    behavior_logp = np.concatenate([g["behavior_logp"] for g in gouts])
    mask = np.concatenate([g["mask"] for g in gouts])
    prompts_dev = jnp.asarray(prompts_rep)
    completions_dev = jnp.asarray(completions)
    return {
        "prompts": prompts_dev,
        "completions": completions_dev,
        "tokens": jnp.concatenate([prompts_dev, completions_dev], axis=1),
        "behavior_logp": jnp.asarray(behavior_logp),
        "mask": jnp.asarray(mask),
    }


def _merge_recs(recs: list[dict]) -> dict:
    """Collapse the iteration's micro-step records into one history row
    (token-weighted means for rates, sums for counts, max for ratio_max)."""
    if len(recs) == 1:
        return dict(recs[0])
    toks = np.asarray([max(r["tokens"], 1) for r in recs], np.float64)
    w = toks / toks.sum()
    out = {}
    for key in ("reward", "acc", "loss", "entropy", "clip_frac",
                "ratio_mean"):
        out[key] = float(sum(wi * r[key] for wi, r in zip(w, recs)))
    out["ratio_max"] = float(max(r["ratio_max"] for r in recs))
    out["tokens"] = int(sum(r["tokens"] for r in recs))
    return out


def run_streaming(job: GRPOJob, *, max_staleness: int = 1,
                  reward_workers: int = 2,
                  micro_groups: Optional[int] = None,
                  runtime: Optional[RollMuxRuntime] = None,
                  log_every: int = 0, elastic: bool = False):
    """``--mux stream``: group-level rollout -> reward -> train pipelining.

    Three planes run concurrently, arbitrated by the runtime's permit
    pools:

    * the **rollout thread** holds the ``rollout`` permit while the
      engine streams completed prompt groups; each group is handed to a
      reward worker *immediately* (the engine keeps decoding);
    * ``reward_workers`` **reward workers** verify groups under the
      ``reward`` permit pool (capacity = worker count) — slow verifiers
      therefore never serialize against decode or the optimizer;
    * the **train loop** (this thread) consumes rewarded groups under the
      ``train`` permit: by default one optimizer step per iteration over
      the re-assembled full batch (bit-exact to the pipeline path), or
      every ``micro_groups`` rewarded groups in completion order.

    The staleness guard is the pipeline executor's, extended past 1: the
    rollout for iteration ``k`` may start once ``trained >= k -
    max_staleness`` iterations have finished their optimizer steps,
    always picking up the newest synced weights.  Each history record
    carries the realized ``rollout_staleness`` plus the clipped
    importance-ratio diagnostics (``clip_frac`` / ``ratio_mean`` /
    ``ratio_max``) that make the off-policy drift auditable.

    Returns ``(state, history, report)`` like the other executors; the
    report's timelines include the third (``reward``) pool, and the
    exported :class:`~repro.core.phase_control.PhaseProfile` records
    carry ``reward_s`` durations for the simulator's reward phase.

    ``elastic=True`` closes the capacity loop on the reward pool: between
    iterations the runtime's telemetry (``rt.metrics().pool_busy_frac``,
    the pool's ``waiting`` gauge) retunes the reward permit count within
    ``[1, reward_workers]`` via :meth:`PermitPool.resize` — queued reward
    work grows the pool back toward ``reward_workers``, a mostly-idle
    pool shrinks so its permits stop masking contention elsewhere.  The
    executor threads are fixed at ``reward_workers``; only the permit
    bound (what the planner's timelines account) moves.  Each history
    record then carries the realized ``reward_permits``.
    """
    if max_staleness < 0:
        raise ValueError("max_staleness must be >= 0")
    if reward_workers < 1:
        raise ValueError("reward_workers must be >= 1")
    if micro_groups is not None and micro_groups < 1:
        raise ValueError("micro_groups must be >= 1 (or None)")
    rt = runtime or RollMuxRuntime()
    rt.pool("rollout", 1)
    rt.pool("train", 1)
    rt.pool("reward", reward_workers)
    steps = job.steps
    n_groups = job.batch                    # one GRPO group per task prompt
    state = job.init_state()
    cv = threading.Condition()
    # "version" counts optimizer steps (weight syncs) — finer-grained than
    # "trained" (iterations): the carry path polls it mid-rollout
    shared = {"params": state["params"], "trained": 0, "version": 0,
              "err": None}
    batches: dict[int, object] = {}         # k -> task batch (answers)
    versions: dict[int, int] = {}           # k -> behaviour-weight version
    rewarded: dict[int, list] = {}          # k -> [(gout, rewards)] arrivals
    history = []
    pool = ThreadPoolExecutor(max_workers=reward_workers,
                              thread_name_prefix=f"{job.job_id}-reward")
    t0 = time.perf_counter()

    def fail(e: BaseException) -> None:
        with cv:
            if shared["err"] is None:
                shared["err"] = e
            cv.notify_all()

    def reward_task(k: int, gout: dict) -> None:
        try:
            with rt.permit("reward", f"{job.job_id}:reward",
                           capacity=reward_workers):
                r = job.reward_group(batches[k], gout)
            with cv:
                rewarded.setdefault(k, []).append((gout, r))
                cv.notify_all()
        except BaseException as e:          # surface into the train loop
            fail(e)

    def sync_fn():
        """Newest synced weights + optimizer-step version, polled by the
        streaming generator between scheduler ticks (partial-rollout
        continuation — only wired when the job opted in via ``carry``)."""
        with cv:
            return shared["params"], shared["version"]

    def roll_loop():
        try:
            for k in range(steps):
                with cv:
                    while (shared["trained"] < k - max_staleness
                           and shared["err"] is None):
                        cv.wait()
                    if shared["err"] is not None:
                        return
                    params = shared["params"]   # newest synced weights
                    versions[k] = shared["trained"]

                def publish(b, k=k):
                    with cv:
                        batches[k] = b
                with rt.permit("rollout", f"{job.job_id}:roll"):
                    job.rollout_stream(
                        params, k,
                        on_group=lambda g, k=k: pool.submit(reward_task,
                                                            k, g),
                        on_batch=publish,
                        sync_params=(sync_fn if getattr(job, "carry", False)
                                     else None))
        except BaseException as e:
            fail(e)

    roll_thread = threading.Thread(target=roll_loop,
                                   name=f"{job.job_id}-rollout")
    try:
        roll_thread.start()
        for k in range(steps):
            recs: list[dict] = []
            consumed = 0
            pending_gouts: list[dict] = []
            pending_rewards: list[np.ndarray] = []
            carried_rows = 0                # rows with mixed weight versions
            vers_seen: set[int] = set()     # behaviour versions this iter
            want = micro_groups if micro_groups is not None else n_groups
            while consumed < n_groups:
                with cv:
                    while not rewarded.get(k) and shared["err"] is None:
                        cv.wait()
                    if shared["err"] is not None:
                        raise shared["err"]
                    take, rewarded[k] = rewarded[k], []
                for gout, r in take:
                    tv = gout.get("token_versions")
                    if tv is not None:
                        msk = np.asarray(gout["mask"]) > 0
                        for row in range(tv.shape[0]):
                            vs = tv[row][msk[row]]
                            if vs.size:
                                vers_seen.update(int(v)
                                                 for v in np.unique(vs))
                                if vs.min() != vs.max():
                                    carried_rows += 1
                    pending_gouts.append(gout)
                    pending_rewards.append(r)
                consumed += len(take)
                while (len(pending_gouts) >= want
                       or (consumed == n_groups and pending_gouts)):
                    m = min(want, len(pending_gouts))
                    gouts, rs = pending_gouts[:m], pending_rewards[:m]
                    del pending_gouts[:m], pending_rewards[:m]
                    if micro_groups is None:
                        # full batch: restore row order for bit-exactness
                        order = np.argsort([g["group_index"]
                                            for g in gouts])
                        gouts = [gouts[i] for i in order]
                        rs = [rs[i] for i in order]
                    b = batches[k]
                    out = _assemble_out(b, gouts, job.group)
                    rewards = np.concatenate(rs).astype(np.float32)
                    # advantages normalize within each GRPO group, so the
                    # micro-batch step computes exactly what the full-
                    # batch path would on the same rows
                    with rt.permit("train", f"{job.job_id}:train"):
                        state, rec = job.train_phase(state, b, out,
                                                     rewards=rewards)
                    recs.append(rec)
                    with cv:
                        shared["params"] = state["params"]  # weight sync
                        shared["version"] += 1
                        cv.notify_all()
            with cv:
                shared["trained"] = k + 1
                cv.notify_all()
                rewarded.pop(k, None)
                batches.pop(k, None)
            if elastic:
                rp = rt.pools["reward"]
                busy = rt.metrics().pool_busy_frac.get("reward", 0.0)
                if rp.waiting and rp.capacity < reward_workers:
                    rp.resize(rp.capacity + 1)
                elif busy < 0.2 and rp.capacity > 1 and not rp.waiting:
                    rp.resize(rp.capacity - 1)
            rec = {"step": k, **_merge_recs(recs),
                   "rollout_staleness": k - versions[k],
                   "micro_steps": len(recs),
                   # partial-rollout continuation provenance: rows whose
                   # behaviour logprobs mix weight versions, and how many
                   # distinct versions fed this iteration's batch
                   "carried_rows": carried_rows,
                   "behavior_versions": max(len(vers_seen), 1)}
            if elastic:
                rec["reward_permits"] = rt.pools["reward"].capacity
            history.append(rec)
            _log(rec, log_every)
    except BaseException:
        fail(RuntimeError("train loop aborted"))
        raise
    finally:
        roll_thread.join()
        pool.shutdown(wait=True)
    return state, history, _report("stream", rt,
                                   time.perf_counter() - t0)
