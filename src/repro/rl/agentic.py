"""Multi-turn agentic rollouts over the engine's suspend/resume lifecycle.

An *episode* alternates model turns with environment (tool) turns: the
model generates until it emits one of the environment's ``stop_tokens``
(a tool-call boundary), the engine **suspends** the request — its KV
blocks stay pinned under a :class:`~repro.serve.engine.SuspendedRequest`
handle while the slot goes back to the pool — the environment computes
the tool result, and the episode **resumes** with the result tokens
injected.  Long-tail tool latencies therefore cost *zero* slot time:
the slot serves other episodes while the tool runs.  That is the
ROADMAP's "biggest remaining bubble at long-tail episode lengths", and
:func:`run_episodes` measures it directly by also offering the
``hold_slots`` baseline — identical token mechanics, but an episode
waiting on its tool still counts against the slot pool (what an engine
without suspend support would do), so admission of new work stalls.

The driver is engine-agnostic (anything satisfying
:class:`~repro.serve.protocol.EngineProtocol`: monolithic ``Engine`` or
``DisaggRouter``) and deterministic under greedy decoding: per-episode
token streams are independent of batch composition, so ``hold_slots``
changes *when* things run, never what is generated — the bench's two
arms are token-identical by construction.

Time is virtual: one engine scheduler tick = one driver tick, and tool
latency is expressed in ticks (``tool_latency_ticks``), which keeps the
bench hermetic and the tests exact.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np

__all__ = ["Environment", "CountdownToolEnv", "Turn", "Episode",
           "run_episodes"]


class Environment:
    """Pluggable environment contract for multi-turn episodes.

    ``stop_tokens`` are the token ids that mark a tool-call boundary —
    they go on every generation request, so sampling one suspends the
    request instead of finishing it.  :meth:`react` is called once per
    suspension with the tokens of the turn just generated (including the
    trigger token) and decides what happens next:

    * ``(tool_tokens, False)`` — inject the tool result and keep going
      (the next turn may suspend again);
    * ``(tool_tokens, True)`` — inject and run the **final** turn: the
      resumed generation carries no stop tokens, so it ends the episode
      at EOS or budget exhaustion;
    * ``(None, _)`` — end the episode at this boundary (the environment
      is done with it).
    """
    stop_tokens: tuple = ()

    def react(self, episode: "Episode", turn_tokens: list[int]
              ) -> tuple[Optional[np.ndarray], bool]:
        raise NotImplementedError


class CountdownToolEnv(Environment):
    """Deterministic tool stub: allow ``turns`` tool calls per episode,
    each answered with ``tool_len`` tokens derived arithmetically from
    the turn's tokens (no RNG — byte-identical across runs and modes).
    Turn ``turns - 1`` is marked final, so the episode closes with a
    free-running generation."""

    def __init__(self, stop_tokens: tuple, *, vocab: int,
                 turns: int = 2, tool_len: int = 3):
        if turns < 1:
            raise ValueError("turns must be >= 1")
        self.stop_tokens = tuple(stop_tokens)
        self.vocab = vocab
        self.turns = turns
        self.tool_len = tool_len

    def react(self, episode, turn_tokens):
        t = len(episode.turns)              # 0-based index of this boundary
        if t >= self.turns:
            return None, True
        base = (int(np.sum(turn_tokens)) + 131 * t
                + 17 * episode.index) % self.vocab
        tool = np.asarray([(base + 7 * j) % self.vocab
                           for j in range(self.tool_len)], np.int32)
        return tool, t == self.turns - 1


@dataclass
class Turn:
    """One model turn plus the tool reply that followed it (empty for the
    final turn / an env-terminated boundary)."""
    tokens: list[int]
    logprobs: list[float]
    token_versions: list[int]
    tool_tokens: list[int] = field(default_factory=list)


@dataclass
class Episode:
    """One multi-turn episode: prompt, accumulated turns, and the virtual-
    tick accounting the bubble-reclaim bench reads."""
    index: int
    prompt: np.ndarray
    job_id: Optional[str] = None
    priority: int = 0
    turns: list[Turn] = field(default_factory=list)
    finish_reason: str = ""      # "eos" | "length" | "env_done"
    submit_tick: int = -1
    finish_tick: int = -1
    tool_wait_ticks: int = 0     # total ticks spent waiting on tools

    @property
    def gen_tokens(self) -> list[int]:
        """Model-generated tokens across all turns (no tool tokens)."""
        return [t for turn in self.turns for t in turn.tokens]

    @property
    def logprobs(self) -> list[float]:
        return [lp for turn in self.turns for lp in turn.logprobs]

    @property
    def token_versions(self) -> list[int]:
        return [v for turn in self.turns for v in turn.token_versions]

    @property
    def full_completion(self) -> list[int]:
        """The episode's full post-prompt sequence: model turns with the
        tool replies interleaved, in generation order."""
        out: list[int] = []
        for turn in self.turns:
            out.extend(turn.tokens)
            out.extend(turn.tool_tokens)
        return out

    @property
    def action_mask(self) -> list[int]:
        """1 for model-generated positions of :attr:`full_completion`,
        0 for injected tool tokens — only actions carry policy gradient."""
        out: list[int] = []
        for turn in self.turns:
            out.extend([1] * len(turn.tokens))
            out.extend([0] * len(turn.tool_tokens))
        return out


def _capacity(engine) -> int:
    cfg = engine.config
    return getattr(cfg, "num_slots", None) or cfg.decode_slots


def run_episodes(engine, env: Environment, prompts, *,
                 max_new_tokens: int, tool_latency_ticks: int = 0,
                 hold_slots: bool = False, job_id: Optional[str] = None,
                 priorities: Optional[list[int]] = None,
                 job_ids: Optional[list[Optional[str]]] = None,
                 max_ticks: Optional[int] = None):
    """Drive a batch of multi-turn episodes to completion.

    ``prompts`` is a list of 1-D int32 token arrays (heterogeneous
    lengths welcome); ``max_new_tokens`` is each episode's *total* model
    budget across turns.  ``tool_latency_ticks`` is how many engine
    ticks each tool call takes; ``hold_slots=True`` runs the baseline
    where a tool-waiting episode keeps its slot occupied (admission of
    new episodes is gated on ``live + waiting < capacity``), versus the
    default suspend mode where the slot is reclaimed for other work the
    moment the boundary token is sampled.

    ``job_ids``/``priorities`` tag each episode's requests for the
    engine's admission policy (deadline / SLO token budgets) — the
    tag-aware mixing path for heterogeneous agentic jobs; both default
    to uniform.  Returns ``(episodes, stats)`` where ``stats["ticks"]``
    is the virtual makespan the bench compares across modes.
    """
    from repro.serve import Request

    n = len(prompts)
    if priorities is None:
        priorities = [0] * n
    if job_ids is None:
        job_ids = [job_id] * n
    episodes = [Episode(index=i, prompt=np.asarray(p, np.int32),
                        job_id=job_ids[i], priority=priorities[i])
                for i, p in enumerate(prompts)]
    capacity = _capacity(engine)
    limit = max_ticks if max_ticks is not None else \
        200 * n * (max_new_tokens + 1) * (tool_latency_ticks + 1)

    next_rid = [0]

    def fresh_rid() -> int:
        next_rid[0] += 1
        return next_rid[0] - 1

    by_rid: dict[int, Episode] = {}       # rid of the *current* turn -> ep
    to_submit = deque(episodes)           # episodes awaiting their 1st turn
    waiting: list[list] = []              # [due_tick, ep, sreq, tool, last]
    ready = deque()                       # resumable: (ep, sreq, tool, last)
    done = 0
    tick = 0
    stats = {"mode": "hold" if hold_slots else "suspend",
             "episodes": n, "turns": 0, "tool_calls": 0,
             "tool_wait_ticks": 0, "ticks": 0}

    def remaining(ep: Episode) -> int:
        return max_new_tokens - len(ep.gen_tokens)

    def record_turn(ep: Episode, out) -> None:
        ep.turns.append(Turn(tokens=list(out.tokens),
                             logprobs=list(out.logprobs),
                             token_versions=list(out.token_versions)))
        stats["turns"] += 1

    def finish(ep: Episode, reason: str) -> None:
        nonlocal done
        ep.finish_reason = reason
        ep.finish_tick = tick
        done += 1

    def in_flight() -> int:
        """Episodes currently consuming (hold mode: or holding) a slot."""
        return len(by_rid) + len(waiting) + len(ready)

    while done < n:
        if tick >= limit:
            raise RuntimeError(
                f"agentic driver exceeded {limit} ticks with "
                f"{n - done}/{n} episodes unfinished — check stop_tokens/"
                f"budget sizing")
        # tool results whose latency elapsed become resumable
        still = []
        for w in waiting:
            if tick >= w[0]:
                ready.append(tuple(w[1:]))
            else:
                still.append(w)
        waiting[:] = still
        # resume before admitting new work: in hold mode the resume
        # reclaims the episode's own held slot, in suspend mode it
        # competes for free slots like any admission
        n_ready = len(ready)
        for _ in range(n_ready):
            ep, sreq, tool, last = ready[0]
            budget = remaining(ep)
            if budget <= 0:
                ready.popleft()
                sreq.release()
                finish(ep, "length")
                continue
            if not engine.can_resume(sreq, tool, max_new_tokens=budget):
                break
            ready.popleft()
            rid = fresh_rid()
            engine.resume(sreq, tool, max_new_tokens=budget, rid=rid,
                          stop_tokens=(() if last else None))
            by_rid[rid] = ep
        # first-turn submissions (hold mode: gated on held capacity)
        while to_submit:
            if hold_slots and in_flight() >= capacity:
                break
            ep = to_submit[0]
            req = Request(rid=fresh_rid(), prompt=ep.prompt,
                          max_new_tokens=max_new_tokens,
                          stop_tokens=env.stop_tokens, job_id=ep.job_id,
                          priority=ep.priority)
            if not engine.submit(req):
                break                     # queue backpressure
            to_submit.popleft()
            ep.submit_tick = tick
            by_rid[req.rid] = ep
        if not engine.idle:
            engine.step()
        tick += 1
        # tool boundaries: ask the environment what happens next
        for sreq in engine.harvest_suspended():
            ep = by_rid.pop(sreq.req.rid)
            record_turn(ep, sreq.out)
            tool, last = env.react(ep, list(sreq.out.tokens))
            if tool is None:
                sreq.release()
                finish(ep, "env_done")
                continue
            stats["tool_calls"] += 1
            ep.turns[-1].tool_tokens = [int(t) for t in np.asarray(tool)]
            ep.tool_wait_ticks += tool_latency_ticks
            stats["tool_wait_ticks"] += tool_latency_ticks
            waiting.append([tick + tool_latency_ticks, ep, sreq,
                            np.asarray(tool, np.int32), last])
        # finished turns (EOS / budget): the episode is over
        for out in engine.harvest():
            ep = by_rid.pop(out.rid)
            record_turn(ep, out)
            finish(ep, out.finish_reason)
    stats["ticks"] = tick
    radix = getattr(engine, "radix", None)
    if radix is not None:
        # resumed histories register in the content-addressed tree, so
        # sibling episodes (and turn k+1) share turn k's prompt blocks —
        # surface the hit/saving counters alongside the episode stats
        stats["radix"] = dict(radix.stats)
    return episodes, stats
