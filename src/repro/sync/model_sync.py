"""Topology-aware model synchronization (paper §5.2) as JAX collectives.

The disaggregated layout is a 2-D mesh ("cluster", "intra"): row 0 = training
pool (holds fresh shards), row 1 = rollout pool. RollMux's hierarchical
two-stage transfer maps to
  stage 1 (inter-cluster scatter):  jax.lax.ppermute over the "cluster" axis
                                    — exactly one model copy crosses the link,
                                    as |intra| parallel P2P shard streams;
  stage 2 (intra-cluster broadcast): jax.lax.all_gather over "intra" on the
                                    rollout row, on the fast local fabric.

The veRL baseline (flat AllGather spanning both pools) is provided for the
collective-bytes comparison: the dry-run HLO shows it moving |intra| x more
bytes across the slow axis. Collective-byte attribution = ppermute bytes ->
slow link, all-gather bytes -> fast fabric (see launch/roofline.py).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def make_sync_mesh(n_per_cluster: int) -> Mesh:
    devs = np.array(jax.devices()[:2 * n_per_cluster]).reshape(2, n_per_cluster)
    return Mesh(devs, ("cluster", "intra"))


def _flatten_concat(params) -> jax.Array:
    leaves = jax.tree.leaves(params)
    return jnp.concatenate([x.reshape(-1) for x in leaves])


def hierarchical_sync(mesh: Mesh, flat_train: jax.Array) -> jax.Array:
    """flat_train: model flattened, sharded over ("cluster","intra") so the
    training row holds the fresh copy. Returns the full model replicated on
    every rollout device (and the training row keeps its shards).
    """
    n_intra = mesh.shape["intra"]
    pad = (-flat_train.size) % n_intra
    x = jnp.pad(flat_train, (0, pad))

    @partial(shard_map, mesh=mesh,
             in_specs=P("intra"),                 # shards along intra only
             out_specs=P("cluster", "intra"),
             check_rep=False)
    def _sync(shard):                             # shard: (M/n,) on all devs
        # stage 1: training row pushes its shard to the rollout peer —
        # ONE model copy total crosses the "cluster" (slow) axis.
        recv = jax.lax.ppermute(shard, "cluster", perm=[(0, 1)])
        cluster_id = jax.lax.axis_index("cluster")
        mine = jnp.where(cluster_id == 1, recv, shard)
        # stage 2: broadcast shards inside the cluster on the fast fabric.
        full = jax.lax.all_gather(mine, "intra", tiled=True)
        return full[None, None]                   # (1,1,M) per device

    return _sync(x)


def flat_sync_baseline(mesh: Mesh, flat_train: jax.Array) -> jax.Array:
    """veRL-style flat AllGather spanning BOTH pools: every rollout device
    independently pulls every shard across the slow axis."""
    n_intra = mesh.shape["intra"]
    pad = (-flat_train.size) % n_intra
    x = jnp.pad(flat_train, (0, pad))

    @partial(shard_map, mesh=mesh, in_specs=P("intra"),
             out_specs=P("cluster", "intra"), check_rep=False)
    def _sync(shard):
        full = jax.lax.all_gather(shard, ("cluster", "intra"), tiled=True)
        # both rows hold 2 copies worth of shards; keep one model's length
        return full[None, None, :shard.size * n_intra]

    return _sync(x)


def lower_sync(n_per_cluster: int, model_bytes: int, *, mode: str):
    """Lower either sync strategy for HLO collective-byte analysis."""
    mesh = make_sync_mesh(n_per_cluster)
    n_elem = model_bytes // 2  # bf16
    flat = jax.ShapeDtypeStruct((n_elem,), jnp.bfloat16)
    fn = hierarchical_sync if mode == "hierarchical" else flat_sync_baseline
    sharding = NamedSharding(mesh, P("intra"))
    return jax.jit(partial(fn, mesh),
                   in_shardings=(sharding,)).lower(flat)


def sync_params_between_jobs(train_params, rollout_params):
    """Single-host execution plane: the 'sync' phase of the RL loop — copy
    the updated training params into the rollout actor's tree."""
    return jax.tree.map(lambda t, _: t, train_params, rollout_params)
