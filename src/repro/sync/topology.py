"""Cluster network topology + analytic transfer-time model (paper §5.2, §7.1).

Defaults follow the paper's testbed: 400 Gbps InfiniBand inside each cluster,
a 20 Gbps Ethernet link between the rollout and training clusters.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class ClusterTopology:
    inter_cluster_gbps: float = 20.0       # cross-cluster Ethernet
    intra_cluster_gbps: float = 400.0      # IB / NVLink fabric
    nvlink_gbps: float = 3200.0            # intra-node NVLink (8-GPU node)
    p2p_streams: int = 8                   # parallel cross-link streams
    stream_efficiency: float = 0.92

    # ---- baseline: veRL-style flat collectives -----------------------------
    def flat_fetch_time_s(self, model_bytes: float, n_rollout_gpus: int) -> float:
        """Every rollout GPU independently fetches a full copy across the
        slow link (single-node veRL behaviour, Fig 8-top / Fig 12-left)."""
        bits = model_bytes * 8 * n_rollout_gpus
        return bits / (self.inter_cluster_gbps * 1e9 * self.stream_efficiency)

    def ring_allgather_time_s(self, model_bytes: float, n_total_gpus: int)\
            -> float:
        """Multi-node flat all-gather ring spanning both clusters: the ring
        crosses the slow boundary twice, each crossing carrying ~the full
        model (Fig 12-right baseline)."""
        bits = model_bytes * 8 * 2 * (n_total_gpus - 1) / n_total_gpus
        return bits / (self.inter_cluster_gbps * 1e9 * self.stream_efficiency)

    # ---- RollMux hierarchical two-stage transfer ----------------------------
    def hierarchical_time_s(self, model_bytes: float, n_train_gpus: int,
                            n_rollout_gpus: int) -> float:
        """Stage 1: exactly one model copy crosses the slow link as
        n_train parallel P2P shard streams. Stage 2: intra-cluster
        all-gather over the fast fabric."""
        stage1 = (model_bytes * 8
                  / (self.inter_cluster_gbps * 1e9 * self.stream_efficiency))
        ag_bytes = model_bytes * (n_rollout_gpus - 1) / n_rollout_gpus
        stage2 = ag_bytes * 8 / (self.intra_cluster_gbps * 1e9
                                 * self.stream_efficiency)
        return stage1 + stage2

    def speedup_single_node(self, model_bytes: float, n: int = 8) -> float:
        return (self.flat_fetch_time_s(model_bytes, n)
                / self.hierarchical_time_s(model_bytes, n, n))

    def speedup_multi_node(self, model_bytes: float, n: int = 16) -> float:
        return (self.ring_allgather_time_s(model_bytes, 2 * n)
                / self.hierarchical_time_s(model_bytes, n, n))

    # ---- cold vs warm start (paper Fig 4 / C3) ------------------------------
    def cold_start_s(self, state_bytes: float, *, control_plane_s: float = 18.0)\
            -> float:
        """Re-fetch weights/optimizer across the slow link + control-plane
        re-init (NCCL communicators, dataset pipeline, env handles)."""
        xfer = state_bytes * 8 / (self.inter_cluster_gbps * 1e9
                                  * self.stream_efficiency)
        return xfer + control_plane_s

    def warm_start_s(self, state_bytes: float,
                     host_to_device_gbps: float = 200.0,
                     wake_overhead_s: float = 0.8) -> float:
        """Host-DRAM -> HBM reload over PCIe/DMA (8 GPUs in parallel);
        control plane retained by the sleeping process (paper §5.1)."""
        return state_bytes * 8 / (host_to_device_gbps * 1e9) + wake_overhead_s
