from repro.sync.topology import ClusterTopology
from repro.sync.model_sync import (flat_sync_baseline, hierarchical_sync,
                                   lower_sync, make_sync_mesh,
                                   sync_params_between_jobs)

__all__ = ["ClusterTopology", "flat_sync_baseline", "hierarchical_sync",
           "lower_sync", "make_sync_mesh", "sync_params_between_jobs"]
