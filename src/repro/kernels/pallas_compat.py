"""Compatibility shim for the Pallas TPU compiler-params rename.

Newer JAX exposes ``pltpu.CompilerParams``; 0.4.x-era releases (this
container ships jax 0.4.37) only have ``pltpu.TPUCompilerParams``. Both
accept the same keyword arguments we use (``dimension_semantics``), so the
kernels import :func:`compiler_params` from here instead of touching the
class directly.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


def compiler_params(**kwargs):
    """Build TPU compiler params under whichever name this JAX provides."""
    return CompilerParams(**kwargs)
