"""Pallas TPU kernels for the phase-defining compute hot-spots:
flash_attention (training), decode_attention (rollout, HBM-bound),
rwkv6_scan (SSM archs). Each has a pure-jnp oracle in ref.py and a jit'd
wrapper in ops.py; validation runs in interpret mode on CPU."""
from repro.kernels.ops import (decode_attention_op, flash_attention_op,
                               greedy_sample_op, mamba2_scan_op,
                               paged_decode_attention_op, resolve_interpret,
                               rwkv6_scan_op, set_interpret, topk_mask_op)

__all__ = ["decode_attention_op", "flash_attention_op", "greedy_sample_op",
           "mamba2_scan_op", "paged_decode_attention_op",
           "resolve_interpret", "rwkv6_scan_op", "set_interpret",
           "topk_mask_op"]
