"""RWKV6 (Finch) chunked WKV recurrence as a Pallas TPU kernel.

Grid (B, H, n_chunks): chunks are sequential; the (Dk, Dv) state matrix
persists in VMEM scratch across chunk steps. All exponentials are of
non-positive numbers (decay ratios between ordered positions), so the chunk
math is fp32-safe without secondary chunking — same algorithm as
``models.linear_scan.chunked_decay_attention`` (the jnp path the dry-run
lowers), validated against the naive-scan oracle in ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params


def _rwkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, y_ref, st_ref, state_s,
                 *, chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_s[...] = jnp.zeros_like(state_s)

    r = r_ref[0, 0].astype(jnp.float32)               # (c, Dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)               # (c, Dv)
    lw = lw_ref[0, 0].astype(jnp.float32)             # (c, Dk), <= 0
    u = u_ref[0].astype(jnp.float32)                  # (Dk,)
    state = state_s[...]                              # (Dk, Dv)

    cl = jnp.cumsum(lw, axis=0)                       # (c, Dk)
    e = cl - lw                                       # cl_{t-1}

    # inter-chunk: read state with decay exp(e_t)
    r_sc = r * jnp.exp(e)
    y = jax.lax.dot_general(r_sc, state, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk: A[t,s] = sum_d r_t k_s exp(e_t - cl_s) (s < t), u on diag
    expo = jnp.exp(e[:, None, :] - cl[None, :, :])    # (t, s, Dk) args <= 0
    A = jnp.einsum("td,sd,tsd->ts", r, k, expo)
    c = chunk
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) \
        > jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    A = jnp.where(tri, A, 0.0)
    diag = ((r * u) * k).sum(axis=1)                  # (c,)
    A = A + diag[:, None] * jnp.eye(c, dtype=jnp.float32)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: S' = diag(exp(cl_c)) S + sum_s exp(cl_c - cl_s) k_s v_s^T
    clc = cl[-1]                                      # (Dk,)
    k_sc = k * jnp.exp(clc[None, :] - cl)
    state = jnp.exp(clc)[:, None] * state + jax.lax.dot_general(
        k_sc, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_s[...] = state

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        st_ref[0, 0] = state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv6_scan(r, k, v, log_w, u, *, chunk: int = 64, interpret: bool = True):
    """r/k/log_w: (B,S,H,Dk); v: (B,S,H,Dv); u: (H,Dk).

    Returns (y (B,S,H,Dv), state (B,H,Dk,Dv) fp32)."""
    B, S, H, Dk = r.shape
    Dv = v.shape[-1]
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S

    def prep(x):
        x = jnp.moveaxis(x, 2, 1)                     # (B,H,S,·)
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else x

    rt, kt, vt = prep(r), prep(k), prep(v)
    lwt = prep(log_w)  # padded zeros decay = exp(0)=1: harmless, masked below
    kernel = functools.partial(_rwkv_kernel, chunk=c, n_chunks=n)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, c, Dk), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, c, Dk), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, c, Dv), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, c, Dk), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, Dk), lambda b, h, ci: (h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, Dv), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, Dk, Dv), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, n * c, Dv), v.dtype),
            jax.ShapeDtypeStruct((B, H, Dk, Dv), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, lwt, u)
    return jnp.moveaxis(y[:, :, :S], 1, 2), state
