"""Flash attention Pallas TPU kernel — the compute-bound training hotspot.

Grid (B, H, nq, nk): the nk dimension is sequential ("arbitrary"); running
max / denominator / accumulator live in VMEM scratch across nk steps.
BlockSpecs tile Q/K/V into (bq, D)/(bk, D) VMEM blocks with MXU-friendly
128-multiples; GQA is handled in the K/V index_map (kv head = q head // G),
so grouped K/V blocks are fetched once per group without materializing a
repeated KV tensor in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1.0e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
               scale: float, causal: bool, window, bq: int, bk: int,
               nk: int, seq_len: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)              # (bq, D)
    k = k_ref[0, 0].astype(jnp.float32)              # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < seq_len                           # padding
    if causal:
        mask &= k_pos <= q_pos
        if window is not None:
            mask &= (q_pos - k_pos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q: (B,S,H,D); k/v: (B,S,Hkv,D). Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    bq, bk = min(block_q, S), min(block_k, S)
    nq, nk = -(-S // bq), -(-S // bk)
    pad_q, pad_k = nq * bq - S, nk * bk - S
    # (B,H,S,D) layout so BlockSpec tiles the trailing (S, D) plane
    qt = jnp.moveaxis(q, 2, 1)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    kernel = functools.partial(_fa_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, qi, ki, g=G: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, nq * bq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return jnp.moveaxis(out[:, :, :S], 1, 2)
