"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        scale=None):
    """q: (B,S,H,D); k/v: (B,S,Hkv,D), H % Hkv == 0. Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        pos = jnp.arange(S)
        m = pos[None, :] <= pos[:, None]
        if window is not None:
            m &= (pos[:, None] - pos[None, :]) < window
        s = jnp.where(m[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def decode_attention_ref(q, k, v, length, *, window=None, scale=None):
    """One-token GQA decode. q: (B,H,D); k/v: (B,S,Hkv,D); length: int32
    scalar or (B,) per-row live prefix; window: optional sliding-window
    size (the query sits at position length-1).

    Attends over cache positions [0, length). Returns (B,H,D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    lengths = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (B,))
    pos = jnp.arange(S)
    valid = pos[None, :] < lengths[:, None]            # (B, S)
    if window is not None:
        valid &= (lengths[:, None] - 1 - pos[None, :]) < window
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                               window=None, k_scale=None, v_scale=None,
                               scale=None):
    """Block-table decode oracle: gather each row's physical blocks into a
    contiguous cache, then run :func:`decode_attention_ref` per row.

    q: (B,H,D); k_pool/v_pool: (NB,bs,Hkv,D); block_tables: (B,MB) int32;
    lengths: (B,).  ``k_scale``/``v_scale`` ((NB,bs) float32) dequantize
    int8 pools before the gather.  Returns (B,H,D)."""
    from repro.models.attention import gather_blocks
    if k_scale is not None:
        k_pool = k_pool.astype(jnp.float32) * k_scale[..., None, None]
        v_pool = v_pool.astype(jnp.float32) * v_scale[..., None, None]
    k = jax.vmap(lambda t: gather_blocks(k_pool, t, axis=0))(block_tables)
    v = jax.vmap(lambda t: gather_blocks(v_pool, t, axis=0))(block_tables)
    return jax.vmap(
        lambda qb, kb, vb, n: decode_attention_ref(
            qb[None], kb[None], vb[None], n, window=window, scale=scale)[0]
    )(q.astype(jnp.float32), k, v, lengths).astype(q.dtype)


def greedy_sample_ref(logits):
    """Fused greedy epilogue oracle: (tokens, logprobs) per row.

    tokens: first-occurrence argmax; logprobs: log_softmax at the token."""
    tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    lp = jnp.take_along_axis(logp, tokens[:, None], axis=-1)[:, 0]
    return tokens, lp


def topk_mask_ref(logits, k: int):
    """Top-k mask oracle: entries below the k-th largest per row become
    NEG_INF; ties at the threshold all survive (like the kernel)."""
    thresh = jnp.sort(logits.astype(jnp.float32), axis=-1)[:, -k]
    return jnp.where(logits >= thresh[:, None],
                     logits.astype(jnp.float32), NEG_INF)


def rwkv6_scan_ref(r, k, v, log_w, u):
    """RWKV6 WKV recurrence oracle. Shapes: (B,S,H,D); u: (H,D).
    Returns (y (B,S,H,D), state (B,H,D,D))."""
    from repro.models.linear_scan import naive_decay_attention
    return naive_decay_attention(r, k, v, log_w, u)


def mamba2_scan_ref(r, k, v, log_w):
    """Mamba2 SSD oracle: scalar/head decay, decay applied in output.
    r/k: (B,S,H,N); v: (B,S,H,hd); log_w: (B,S,H,1)."""
    from repro.models.linear_scan import naive_decay_attention
    lw = jnp.broadcast_to(log_w, r.shape)
    return naive_decay_attention(r, k, v, lw, None, decay_in_output=True)
