"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1.0e30


def flash_attention_ref(q, k, v, *, causal: bool = True, window=None,
                        scale=None):
    """q: (B,S,H,D); k/v: (B,S,Hkv,D), H % Hkv == 0. Returns (B,S,H,D)."""
    B, S, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, S, Hkv, G, D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        pos = jnp.arange(S)
        m = pos[None, :] <= pos[:, None]
        if window is not None:
            m &= (pos[:, None] - pos[None, :]) < window
        s = jnp.where(m[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(B, S, H, D).astype(q.dtype)


def decode_attention_ref(q, k, v, length, *, scale=None):
    """One-token GQA decode. q: (B,H,D); k/v: (B,S,Hkv,D); length: int32.

    Attends over cache positions [0, length). Returns (B,H,D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    valid = jnp.arange(S) < length
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(B, H, D).astype(q.dtype)


def paged_decode_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                               scale=None):
    """Block-table decode oracle: gather each row's physical blocks into a
    contiguous cache, then run :func:`decode_attention_ref` per row.

    q: (B,H,D); k_pool/v_pool: (NB,bs,Hkv,D); block_tables: (B,MB) int32;
    lengths: (B,). Returns (B,H,D)."""
    from repro.models.attention import gather_blocks
    k = jax.vmap(lambda t: gather_blocks(k_pool, t, axis=0))(block_tables)
    v = jax.vmap(lambda t: gather_blocks(v_pool, t, axis=0))(block_tables)
    return jax.vmap(
        lambda qb, kb, vb, n: decode_attention_ref(
            qb[None], kb[None], vb[None], n, scale=scale)[0]
    )(q, k, v, lengths)


def rwkv6_scan_ref(r, k, v, log_w, u):
    """RWKV6 WKV recurrence oracle. Shapes: (B,S,H,D); u: (H,D).
    Returns (y (B,S,H,D), state (B,H,D,D))."""
    from repro.models.linear_scan import naive_decay_attention
    return naive_decay_attention(r, k, v, log_w, u)


def mamba2_scan_ref(r, k, v, log_w):
    """Mamba2 SSD oracle: scalar/head decay, decay applied in output.
    r/k: (B,S,H,N); v: (B,S,H,hd); log_w: (B,S,H,1)."""
    from repro.models.linear_scan import naive_decay_attention
    lw = jnp.broadcast_to(log_w, r.shape)
    return naive_decay_attention(r, k, v, lw, None, decay_in_output=True)
