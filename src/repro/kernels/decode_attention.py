"""One-token GQA decode attention Pallas kernel — the memory-bandwidth-bound
rollout hotspot (the phase RollMux offloads to the cheap pool).

The KV cache streams through VMEM in (bk, D) blocks along the sequential nk
grid axis; all G query heads of a KV group are processed together so each KV
block is read from HBM exactly once (arithmetic intensity ~ 2G flops/byte —
bandwidth-bound, which is precisely the paper's motivation for H20-class
hardware). The live cache length arrives via scalar prefetch (SMEM).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1.0e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                scale: float, bk: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, length, *, block_k: int = 512,
                     interpret: bool = True):
    """q: (B,H,D); k/v: (B,S,Hkv,D); length: scalar int32 (live prefix).

    Returns (B,H,D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    bk = min(block_k, S)
    nk = -(-S // bk)
    pad_k = nk * bk - S
    qt = q.reshape(B, Hkv, G, D)
    kt = jnp.moveaxis(k, 2, 1)                        # (B,Hkv,S,D)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    length = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(_dec_kernel, scale=scale, bk=bk, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, len_ref: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, len_ref: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ki, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length, qt, kt, vt)
    return out.reshape(B, H, D)
