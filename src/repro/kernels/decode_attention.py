"""One-token GQA decode attention Pallas kernels — the memory-bandwidth-bound
rollout hotspot (the phase RollMux offloads to the cheap pool).

:func:`decode_attention` (contiguous): the KV cache streams through VMEM in
(bk, D) blocks along the sequential nk grid axis; all G query heads of a KV
group are processed together so each KV block is read from HBM exactly once
(arithmetic intensity ~ 2G flops/byte — bandwidth-bound, which is precisely
the paper's motivation for H20-class hardware). The live cache length
arrives via scalar prefetch (SMEM).

:func:`paged_decode_attention` (block-table): same online-softmax loop, but
K/V live in a shared block pool (``models/kvcache.init_paged_cache``
layout) and each batch row owns a *block table* of physical block ids.  The
table is scalar-prefetched and consumed inside the BlockSpec ``index_map``,
so the kernel DMAs exactly the row's own physical blocks straight out of
the pool — no gather materialization, which is the entire point of paged
serving: the contiguous view never has to exist in HBM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1.0e30


def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_s, l_s, acc_s, *,
                scale: float, bk: int, nk: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[0], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, length, *, block_k: int = 512,
                     interpret: bool = True):
    """q: (B,H,D); k/v: (B,S,Hkv,D); length: scalar int32 (live prefix).

    Returns (B,H,D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    bk = min(block_k, S)
    nk = -(-S // bk)
    pad_k = nk * bk - S
    qt = q.reshape(B, Hkv, G, D)
    kt = jnp.moveaxis(k, 2, 1)                        # (B,Hkv,S,D)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    length = jnp.asarray(length, jnp.int32).reshape(1)

    kernel = functools.partial(_dec_kernel, scale=scale, bk=bk, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ki, len_ref: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, len_ref: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, ki, len_ref: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ki, len_ref: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(length, qt, kt, vt)
    return out.reshape(B, H, D)


def _paged_dec_kernel(tbl_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                      m_s, l_s, acc_s, *, scale: float, bs: int, nb: int):
    b, ki = pl.program_id(0), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bs, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # logical position of this table entry's tokens; masks both the live
    # prefix and any null-block (table id 0) tail entries past the length
    pos = ki * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           interpret: bool = True):
    """Block-table GQA decode attention over a shared paged KV pool.

    q: (B,H,D); k_pool/v_pool: (NB,bs,Hkv,D) — a pool of NB physical blocks
    of bs token positions (entry 0 = null block); block_tables: (B,MB) int32
    physical block ids per batch row (0 where unassigned); lengths: (B,)
    live prefix per row.  Row b attends over logical positions
    ``[0, lengths[b])`` of the sequence ``concat(pool[tables[b]])``.
    Returns (B,H,D) — allclose to ``decode_attention`` on the gathered
    contiguous cache (``kernels/ref.paged_decode_attention_ref``).
    """
    B, H, D = q.shape
    NB, bs, Hkv, _ = k_pool.shape
    MB = block_tables.shape[1]
    G = H // Hkv
    scale = D ** -0.5
    qt = q.reshape(B, Hkv, G, D)
    kt = jnp.moveaxis(k_pool, 2, 1)                   # (NB, Hkv, bs, D)
    vt = jnp.moveaxis(v_pool, 2, 1)
    tbl = jnp.asarray(block_tables, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)

    kernel = functools.partial(_paged_dec_kernel, scale=scale, bs=bs, nb=MB)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                        # block tables, lengths
        grid=(B, Hkv, MB),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, ki, tbl, lens: (b, h, 0, 0)),
            # the paged DMA: this row's ki-th logical block comes from
            # physical pool block tbl[b, ki]
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, ki, tbl, lens: (tbl[b, ki], h, 0, 0)),
            pl.BlockSpec((1, 1, bs, D),
                         lambda b, h, ki, tbl, lens: (tbl[b, ki], h, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ki, tbl, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tbl, lengths, qt, kt, vt)
    return out.reshape(B, H, D)
