"""One-token GQA decode attention Pallas kernels — the memory-bandwidth-bound
rollout hotspot (the phase RollMux offloads to the cheap pool).

:func:`decode_attention` (contiguous): the KV cache streams through VMEM in
(bk, D) blocks along the sequential nk grid axis; all G query heads of a KV
group are processed together so each KV block is read from HBM exactly once
(arithmetic intensity ~ 2G flops/byte — bandwidth-bound, which is precisely
the paper's motivation for H20-class hardware). Live cache lengths arrive
via scalar prefetch (SMEM) — a scalar (uniform batch) or per-row ``(B,)``
vector (the engine's ragged slot pool).

:func:`paged_decode_attention` (block-table): same online-softmax loop, but
K/V live in a shared block pool (``models/kvcache.init_paged_cache``
layout) and each batch row owns a *block table* of physical block ids.  The
table is scalar-prefetched and consumed inside the BlockSpec ``index_map``,
so the kernel DMAs exactly the row's own physical blocks straight out of
the pool — no gather materialization, which is the entire point of paged
serving: the contiguous view never has to exist in HBM.  Optional
``k_scale``/``v_scale`` pools dequantize int8 blocks inside the block loop
(per-position scales, so incremental decode writes stay exact).

Both kernels take a ``window`` operand (sliding-window attention, gemma3's
local layers): the single query sits at position ``length-1`` and attends
``(length-1) - pos < window``.  ``window`` is a traced scalar so the
per-layer value can ride a ``lax.scan`` over layers; ``None`` uses a
sentinel large enough to never mask.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1.0e30
# matches models/stacks.NO_WINDOW: far beyond any max_seq_len, never masks
NO_WINDOW = 2 ** 30


def _dec_kernel(len_ref, win_ref, q_ref, k_ref, v_ref, o_ref,
                m_s, l_s, acc_s, *, scale: float, bk: int, nk: int):
    b, ki = pl.program_id(0), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bk, D)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    length = len_ref[b]
    # query position is length-1: live prefix plus the sliding window
    valid = (pos < length) & (length - 1 - pos < win_ref[0])
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # a fully-masked block while m is still NEG_INF would give
    # exp(NEG_INF - NEG_INF) = 1 per masked lane — zero them explicitly
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q, k, v, length, *, window=None, block_k: int = 512,
                     interpret: bool = True):
    """q: (B,H,D); k/v: (B,S,Hkv,D); length: int32 scalar or (B,) per-row
    live prefix; window: optional sliding-window size (scalar, traced OK).

    Returns (B,H,D)."""
    B, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    scale = D ** -0.5
    bk = min(block_k, S)
    nk = -(-S // bk)
    pad_k = nk * bk - S
    qt = q.reshape(B, Hkv, G, D)
    kt = jnp.moveaxis(k, 2, 1)                        # (B,Hkv,S,D)
    vt = jnp.moveaxis(v, 2, 1)
    if pad_k:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    lengths = jnp.broadcast_to(
        jnp.asarray(length, jnp.int32).reshape(-1), (B,))
    win = jnp.asarray(NO_WINDOW if window is None else window,
                      jnp.int32).reshape(1)

    kernel = functools.partial(_dec_kernel, scale=scale, bk=bk, nk=nk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                        # lengths, window
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D),
                         lambda b, h, ki, lens, w: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, ki, lens, w: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, ki, lens, w: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ki, lens, w: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(lengths, win, qt, kt, vt)
    return out.reshape(B, H, D)


def _paged_dec_kernel(tbl_ref, len_ref, win_ref, q_ref, k_ref, v_ref, *rest,
                      scale: float, bs: int, nb: int, quant: bool):
    if quant:
        ks_ref, vs_ref, o_ref, m_s, l_s, acc_s = rest
    else:
        o_ref, m_s, l_s, acc_s = rest
    b, ki = pl.program_id(0), pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        acc_s[...] = jnp.zeros_like(acc_s)

    q = q_ref[0, 0].astype(jnp.float32)               # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (bs, D)
    v = v_ref[0, 0].astype(jnp.float32)
    if quant:
        # per-position scales: dequantize this physical block in VMEM
        k = k * ks_ref[0][:, None]
        v = v * vs_ref[0][:, None]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # logical position of this table entry's tokens; masks the live prefix,
    # the sliding window, and any null-block (table id 0) tail entries
    pos = ki * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    length = len_ref[b]
    valid = (pos < length) & (length - 1 - pos < win_ref[0])
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    # zero masked lanes: a fully-masked block (all-null tail past the
    # length, or everything outside the window) with m still NEG_INF
    # would otherwise contribute exp(NEG_INF - NEG_INF) = 1 per lane
    p = jnp.where(valid, jnp.exp(s - m_new[:, None]), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_s[...] = l_s[...] * corr + p.sum(axis=1)
    acc_s[...] = acc_s[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_s[...] = m_new

    @pl.when(ki == nb - 1)
    def _finalize():
        o_ref[0, 0] = (acc_s[...] / jnp.maximum(l_s[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pool, v_pool, block_tables, lengths, *,
                           window=None, k_scale=None, v_scale=None,
                           interpret: bool = True):
    """Block-table GQA decode attention over a shared paged KV pool.

    q: (B,H,D); k_pool/v_pool: (NB,bs,Hkv,D) — a pool of NB physical blocks
    of bs token positions (entry 0 = null block); block_tables: (B,MB) int32
    physical block ids per batch row (0 where unassigned); lengths: (B,)
    live prefix per row.  Row b attends over logical positions
    ``[0, lengths[b])`` of the sequence ``concat(pool[tables[b]])``,
    windowed to the trailing ``window`` positions when given.  With
    ``k_scale``/``v_scale`` ((NB,bs) float32 per-position scales) the pools
    are int8 and dequantized inside the block loop.  Returns (B,H,D) —
    allclose to ``decode_attention`` on the gathered contiguous cache
    (``kernels/ref.paged_decode_attention_ref``).
    """
    B, H, D = q.shape
    NB, bs, Hkv, _ = k_pool.shape
    MB = block_tables.shape[1]
    G = H // Hkv
    scale = D ** -0.5
    qt = q.reshape(B, Hkv, G, D)
    kt = jnp.moveaxis(k_pool, 2, 1)                   # (NB, Hkv, bs, D)
    vt = jnp.moveaxis(v_pool, 2, 1)
    tbl = jnp.asarray(block_tables, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32).reshape(B)
    win = jnp.asarray(NO_WINDOW if window is None else window,
                      jnp.int32).reshape(1)
    quant = k_scale is not None

    kernel = functools.partial(_paged_dec_kernel, scale=scale, bs=bs, nb=MB,
                               quant=quant)
    # the paged DMA: row b's ki-th logical block comes from physical pool
    # block tbl[b, ki]
    pool_spec = pl.BlockSpec((1, 1, bs, D),
                             lambda b, h, ki, tbl, lens, w: (tbl[b, ki],
                                                             h, 0, 0))
    in_specs = [
        pl.BlockSpec((1, 1, G, D),
                     lambda b, h, ki, tbl, lens, w: (b, h, 0, 0)),
        pool_spec,
        pool_spec,
    ]
    operands = [qt, kt, vt]
    if quant:
        scale_spec = pl.BlockSpec(
            (1, bs), lambda b, h, ki, tbl, lens, w: (tbl[b, ki], 0))
        in_specs += [scale_spec, scale_spec]
        operands += [jnp.asarray(k_scale, jnp.float32),
                     jnp.asarray(v_scale, jnp.float32)]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,               # block tables, lengths, window
        grid=(B, Hkv, MB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, D),
                               lambda b, h, ki, tbl, lens, w: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, D), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(tbl, lengths, win, *operands)
    return out.reshape(B, H, D)
