"""Mamba2 (SSD) chunked scan as a Pallas TPU kernel — zamba2's trunk op.

Same chunked decay-linear-attention structure as rwkv6_scan, specialised to
SSD semantics: scalar-per-head decay (log_w broadcast over the state dim),
decay applied in the output read (y_t reads w_t*S_{t-1} + k_t v_t^T), and
the intra-chunk mask includes the diagonal. Oracle: ref.mamba2_scan_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params


def _ssd_kernel(r_ref, k_ref, v_ref, lw_ref, y_ref, st_ref, state_s, *,
                chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_s[...] = jnp.zeros_like(state_s)

    r = r_ref[0, 0].astype(jnp.float32)               # (c, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)               # (c, hd)
    lw = lw_ref[0, 0].astype(jnp.float32)             # (c, N) broadcasted
    state = state_s[...]                              # (N, hd)

    cl = jnp.cumsum(lw, axis=0)                       # (c, N), <= 0
    e = cl                                            # decay-in-output: cl_t

    r_sc = r * jnp.exp(e)
    y = jax.lax.dot_general(r_sc, state, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # intra-chunk with s <= t (diagonal included, no bonus term)
    expo = jnp.exp(jnp.minimum(e[:, None, :] - cl[None, :, :], 0.0))
    A = jnp.einsum("td,sd,tsd->ts", r, k, expo)
    c = chunk
    tri = jax.lax.broadcasted_iota(jnp.int32, (c, c), 0) \
        >= jax.lax.broadcasted_iota(jnp.int32, (c, c), 1)
    A = jnp.where(tri, A, 0.0)
    y = y + jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    clc = cl[-1]
    k_sc = k * jnp.exp(clc[None, :] - cl)
    state = jnp.exp(clc)[:, None] * state + jax.lax.dot_general(
        k_sc, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    state_s[...] = state

    @pl.when(ci == n_chunks - 1)
    def _emit():
        st_ref[0, 0] = state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba2_scan(r, k, v, log_w, *, chunk: int = 64, interpret: bool = True):
    """r/k: (B,S,H,N); v: (B,S,H,hd); log_w: (B,S,H,1) scalar/head decay.

    Returns (y (B,S,H,hd), state (B,H,N,hd) fp32)."""
    B, S, H, N = r.shape
    hd = v.shape[-1]
    c = min(chunk, S)
    n = -(-S // c)
    pad = n * c - S

    def prep(x):
        x = jnp.moveaxis(x, 2, 1)
        return jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0))) if pad else x

    rt, kt, vt = prep(r), prep(k), prep(v)
    lwt = prep(jnp.broadcast_to(log_w, r.shape))
    kernel = functools.partial(_ssd_kernel, chunk=c, n_chunks=n)
    y, state = pl.pallas_call(
        kernel,
        grid=(B, H, n),
        in_specs=[
            pl.BlockSpec((1, 1, c, N), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, c, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, c, N), lambda b, h, ci: (b, h, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, c, hd), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, N, hd), lambda b, h, ci: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, n * c, hd), v.dtype),
            jax.ShapeDtypeStruct((B, H, N, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, hd), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(rt, kt, vt, lwt)
    return jnp.moveaxis(y[:, :, :S], 1, 2), state
