"""Jit'd public wrappers around the Pallas kernels.

``interpret=True`` (default in this CPU container) runs the kernel bodies in
the Pallas interpreter for validation; on real TPUs pass interpret=False.
Model code opts in via ``use_kernels``; the dry-run uses the pure-JAX paths
so roofline numbers come from XLA HLO.
"""
from __future__ import annotations

import jax

from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import (decode_attention,
                                            paged_decode_attention)
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.mamba2_scan import mamba2_scan

ON_TPU = jax.default_backend() == "tpu"
DEFAULT_INTERPRET = not ON_TPU


def flash_attention_op(q, k, v, *, causal=True, window=None,
                       block_q=128, block_k=128):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=DEFAULT_INTERPRET)


def decode_attention_op(q, k, v, length, *, block_k=512):
    return decode_attention(q, k, v, length, block_k=block_k,
                            interpret=DEFAULT_INTERPRET)


def paged_decode_attention_op(q, k_pool, v_pool, block_tables, lengths):
    return paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                                  interpret=DEFAULT_INTERPRET)


def rwkv6_scan_op(r, k, v, log_w, u, *, chunk=64):
    return rwkv6_scan(r, k, v, log_w, u, chunk=chunk,
                      interpret=DEFAULT_INTERPRET)


def mamba2_scan_op(r, k, v, log_w, *, chunk=64):
    return mamba2_scan(r, k, v, log_w, chunk=chunk,
                       interpret=DEFAULT_INTERPRET)
