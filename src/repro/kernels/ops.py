"""Jit'd public wrappers around the Pallas kernels.

Interpret-mode selection is resolved **lazily, per call** — never frozen at
import time.  The old module-level ``ON_TPU``/``DEFAULT_INTERPRET``
constants silently kept whatever backend was active when this module was
first imported, so flipping backends (or the engine's ``--kernel-backend``
flag) after import could run the wrong path.  Resolution order:

1. an explicit :func:`set_interpret` override (process-wide),
2. the ``REPRO_PALLAS_INTERPRET`` env var (``1/true``, ``0/false`` or
   ``auto``),
3. whether JAX's default backend is a TPU *right now*.

``ON_TPU`` and ``DEFAULT_INTERPRET`` remain importable for compatibility
but are computed on attribute access (module ``__getattr__``), so they can
no longer go stale.
"""
from __future__ import annotations

import os
from typing import Optional

import jax

from repro.kernels.flash_attention import flash_attention
from repro.kernels.decode_attention import (decode_attention,
                                            paged_decode_attention)
from repro.kernels.sampling import greedy_sample, topk_mask
from repro.kernels.rwkv6_scan import rwkv6_scan
from repro.kernels.mamba2_scan import mamba2_scan

_ENV_VAR = "REPRO_PALLAS_INTERPRET"
_interpret_override: Optional[bool] = None


def set_interpret(value: Optional[bool]) -> None:
    """Force interpret mode on (True) / off (False); ``None`` restores the
    automatic env/backend resolution."""
    global _interpret_override
    _interpret_override = value


def resolve_interpret() -> bool:
    """Decide interpret mode at call time: override > env var > backend."""
    if _interpret_override is not None:
        return _interpret_override
    env = os.environ.get(_ENV_VAR, "auto").strip().lower()
    if env in ("1", "true", "yes", "on"):
        return True
    if env in ("0", "false", "no", "off"):
        return False
    return jax.default_backend() != "tpu"


def __getattr__(name: str):
    # live values for the legacy import-time constants
    if name == "ON_TPU":
        return jax.default_backend() == "tpu"
    if name == "DEFAULT_INTERPRET":
        return resolve_interpret()
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def flash_attention_op(q, k, v, *, causal=True, window=None,
                       block_q=128, block_k=128):
    return flash_attention(q, k, v, causal=causal, window=window,
                           block_q=block_q, block_k=block_k,
                           interpret=resolve_interpret())


def decode_attention_op(q, k, v, length, *, window=None, block_k=512):
    return decode_attention(q, k, v, length, window=window, block_k=block_k,
                            interpret=resolve_interpret())


def paged_decode_attention_op(q, k_pool, v_pool, block_tables, lengths, *,
                              window=None, k_scale=None, v_scale=None):
    return paged_decode_attention(q, k_pool, v_pool, block_tables, lengths,
                                  window=window, k_scale=k_scale,
                                  v_scale=v_scale,
                                  interpret=resolve_interpret())


def greedy_sample_op(logits, *, block_v=1024):
    return greedy_sample(logits, block_v=block_v,
                         interpret=resolve_interpret())


def topk_mask_op(logits, k, *, block_v=1024):
    return topk_mask(logits, k, block_v=block_v,
                     interpret=resolve_interpret())


def rwkv6_scan_op(r, k, v, log_w, u, *, chunk=64):
    return rwkv6_scan(r, k, v, log_w, u, chunk=chunk,
                      interpret=resolve_interpret())


def mamba2_scan_op(r, k, v, log_w, *, chunk=64):
    return mamba2_scan(r, k, v, log_w, chunk=chunk,
                       interpret=resolve_interpret())
