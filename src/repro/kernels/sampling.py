"""Fused sampling epilogue Pallas kernels (logits -> token, no host hop).

:func:`greedy_sample` fuses the argmax + logprob epilogue of a decode step
into one pass over the vocabulary: the logits stream through VMEM in
``block_v`` chunks while running max / argmax / logsumexp scratch carries
the online reduction, so the (B, V) logits never round-trip through a
separate ``log_softmax`` materialization.  The greedy token's logprob is
``logit[argmax] - logsumexp = -log(sum exp(x - max))`` — free once the
online sum is in hand.

:func:`topk_values` keeps a running top-k scratch per row (k static and
small, the extraction loop is unrolled); :func:`topk_mask` turns that into
threshold-masked logits for ``jax.random.categorical`` — the sampled path's
epilogue.  Ties **at** the k-th value all survive the mask (may keep more
than k candidates); jnp oracles in :mod:`repro.kernels.ref` mirror that
choice.

Pure-jnp oracles: ``ref.greedy_sample_ref`` / ``ref.topk_mask_ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pallas_compat import compiler_params

NEG_INF = -1.0e30


def _greedy_kernel(x_ref, tok_ref, lp_ref, m_s, l_s, idx_s, *,
                   bv: int, nv: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        m_s[...] = jnp.full_like(m_s, NEG_INF)
        l_s[...] = jnp.zeros_like(l_s)
        idx_s[...] = jnp.zeros_like(idx_s)

    x = x_ref[...].astype(jnp.float32)                # (1, bv)
    bm = x.max(axis=1)                                # (1,)
    bi = jnp.argmax(x, axis=1).astype(jnp.int32)      # (1,)
    m_prev = m_s[...]
    m_new = jnp.maximum(m_prev, bm)
    # strict > keeps the first occurrence across blocks, matching
    # jnp.argmax over the full row (within a block argmax already does)
    idx_s[...] = jnp.where(bm > m_prev, vi * bv + bi, idx_s[...])
    l_s[...] = (l_s[...] * jnp.exp(m_prev - m_new)
                + jnp.exp(x - m_new[:, None]).sum(axis=1))
    m_s[...] = m_new

    @pl.when(vi == nv - 1)
    def _finalize():
        tok_ref[...] = idx_s[...]
        # greedy logprob: logit[argmax] - logsumexp = -log(l)
        lp_ref[...] = -jnp.log(jnp.maximum(l_s[...], 1e-30))


@functools.partial(jax.jit, static_argnames=("block_v", "interpret"))
def greedy_sample(logits, *, block_v: int = 1024, interpret: bool = True):
    """logits: (B, V) -> (tokens (B,) int32, logprobs (B,) float32).

    One fused pass: ``tokens = argmax(logits)`` (first occurrence on ties)
    and ``logprobs = log_softmax(logits)[tokens]``."""
    B, V = logits.shape
    bv = min(block_v, V)
    nv = -(-V // bv)
    pad = nv * bv - V
    x = logits
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)), constant_values=NEG_INF)

    kernel = functools.partial(_greedy_kernel, bv=bv, nv=nv)
    out = pl.pallas_call(
        kernel,
        grid=(B, nv),
        in_specs=[pl.BlockSpec((1, bv), lambda b, vi: (b, vi))],
        out_specs=(pl.BlockSpec((1,), lambda b, vi: (b,)),
                   pl.BlockSpec((1,), lambda b, vi: (b,))),
        out_shape=(jax.ShapeDtypeStruct((B,), jnp.int32),
                   jax.ShapeDtypeStruct((B,), jnp.float32)),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.int32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x)
    return out


def _topk_kernel(x_ref, out_ref, top_s, *, k: int, nv: int):
    vi = pl.program_id(1)

    @pl.when(vi == 0)
    def _init():
        top_s[...] = jnp.full_like(top_s, NEG_INF)

    x = x_ref[...].astype(jnp.float32)                # (1, bv)
    merged = jnp.concatenate([top_s[...], x], axis=1)  # (1, k + bv)
    lane = jax.lax.broadcasted_iota(jnp.int32, merged.shape, 1)
    vals = []
    for _ in range(k):          # unrolled: k is static and small
        i = jnp.argmax(merged, axis=1)                # (1,)
        vals.append(merged.max(axis=1))
        # retire only the first occurrence so duplicates stay rankable
        merged = jnp.where(lane == i[:, None], NEG_INF, merged)
    top_s[...] = jnp.stack(vals, axis=1)              # (1, k) descending

    @pl.when(vi == nv - 1)
    def _finalize():
        out_ref[...] = top_s[...]


@functools.partial(jax.jit, static_argnames=("k", "block_v", "interpret"))
def topk_values(logits, k: int, *, block_v: int = 1024,
                interpret: bool = True):
    """logits: (B, V) -> (B, k) largest values per row, descending."""
    B, V = logits.shape
    if not 0 < k <= V:
        raise ValueError(f"k={k} out of range for vocab {V}")
    bv = min(block_v, V)
    nv = -(-V // bv)
    pad = nv * bv - V
    x = logits
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)), constant_values=NEG_INF)

    kernel = functools.partial(_topk_kernel, k=k, nv=nv)
    return pl.pallas_call(
        kernel,
        grid=(B, nv),
        in_specs=[pl.BlockSpec((1, bv), lambda b, vi: (b, vi))],
        out_specs=pl.BlockSpec((1, k), lambda b, vi: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, k), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, k), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x)


def topk_mask(logits, k: int, *, block_v: int = 1024,
              interpret: bool = True):
    """Mask logits below the k-th largest per row to NEG_INF.

    Feed the result to ``jax.random.categorical`` for top-k sampling.
    Rows keep every entry >= the k-th value, so ties at the threshold may
    leave more than k candidates (same as ``ref.topk_mask_ref``)."""
    top = topk_values(logits, k, block_v=block_v, interpret=interpret)
    thresh = top[:, k - 1]
    return jnp.where(logits >= thresh[:, None],
                     logits.astype(jnp.float32), NEG_INF)
