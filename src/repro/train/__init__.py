from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_opt_specs,
                                   adamw_update, warmup_cosine)
from repro.train.checkpoints import (HostStateCache, load_checkpoint,
                                     save_checkpoint)

__all__ = ["AdamWConfig", "adamw_init", "adamw_opt_specs", "adamw_update",
           "warmup_cosine", "HostStateCache", "load_checkpoint",
           "save_checkpoint"]
