"""Checkpointing: disk save/load + the host-DRAM actor cache that backs
RollMux's warm-start context switching (paper §5.1 / C3).

``HostStateCache`` is the "actor cache" of Fig 9: offloaded job states live
here as host numpy arrays; a warm start is a ``device_put`` back, a cold
start re-reads from disk (or re-initializes) — the latency gap is what the
paper's Fig 4 measures.
"""
from __future__ import annotations

import os
import pickle
import time
from typing import Any

import jax
import numpy as np


def _flatten(tree) -> tuple[list[np.ndarray], Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return [np.asarray(x) for x in leaves], treedef


def save_checkpoint(path: str, tree) -> None:
    leaves, treedef = _flatten(tree)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "wb") as f:
        pickle.dump({"leaves": leaves, "treedef": treedef}, f,
                    protocol=pickle.HIGHEST_PROTOCOL)


def load_checkpoint(path: str):
    with open(path, "rb") as f:
        blob = pickle.load(f)
    return jax.tree.unflatten(blob["treedef"], blob["leaves"])


class HostStateCache:
    """Host-memory residency cache with a byte budget (the paper's residency
    constraint). Evicting a resident job = falling back to cold start."""

    def __init__(self, capacity_bytes: int):
        self.capacity = capacity_bytes
        self._store: dict[str, tuple[list[np.ndarray], Any]] = {}
        self.stats = {"warm_hits": 0, "cold_misses": 0, "offloads": 0}

    def used_bytes(self) -> int:
        return sum(sum(a.nbytes for a in leaves)
                   for leaves, _ in self._store.values())

    def can_admit(self, nbytes: int) -> bool:
        return self.used_bytes() + nbytes <= self.capacity

    def offload(self, key: str, tree) -> float:
        """Device -> host. Returns seconds spent."""
        t0 = time.perf_counter()
        self._store[key] = _flatten(jax.device_get(tree))
        self.stats["offloads"] += 1
        return time.perf_counter() - t0

    def restore(self, key: str):
        """Host -> device (warm start). Returns (tree, seconds) or (None, 0)."""
        if key not in self._store:
            self.stats["cold_misses"] += 1
            return None, 0.0
        t0 = time.perf_counter()
        leaves, treedef = self._store[key]
        tree = jax.tree.unflatten(treedef, [jax.device_put(a) for a in leaves])
        self.stats["warm_hits"] += 1
        return tree, time.perf_counter() - t0

    def evict(self, key: str) -> None:
        self._store.pop(key, None)

    def resident(self, key: str) -> bool:
        return key in self._store
