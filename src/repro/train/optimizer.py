"""AdamW in pure JAX (no optax) + LR schedules. Optimizer moments carry the
same logical sharding specs as the parameters (FSDP for the 236B archs)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3.0e-5
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1.0e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    moment_dtype: str = "float32"


def adamw_init(params, cfg: AdamWConfig = AdamWConfig()) -> dict:
    dt = jnp.dtype(cfg.moment_dtype)
    def zeros(p):
        return jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_opt_specs(param_specs) -> dict:
    """Logical specs for the optimizer state, mirroring the params tree."""
    return {"mu": param_specs, "nu": param_specs, "step": ()}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, opt, params, cfg: AdamWConfig,
                 lr_schedule: Callable | None = None):
    """Returns (new_params, new_opt, metrics)."""
    step = opt["step"] + 1
    gnorm = global_norm(grads)
    if cfg.grad_clip > 0:
        scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cfg.lr if lr_schedule is None else lr_schedule(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(m.dtype)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(m.dtype)
        return (p.astype(m.dtype) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt["mu"], opt["nu"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree.unflatten(treedef, [t[0] for t in flat])
    new_m = jax.tree.unflatten(treedef, [t[1] for t in flat])
    new_v = jax.tree.unflatten(treedef, [t[2] for t in flat])
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, {
        "grad_norm": gnorm, "lr": jnp.asarray(lr, jnp.float32)}


def warmup_cosine(base_lr: float, warmup: int, total: int, min_frac=0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * (min_frac + (1 - min_frac) * 0.5 *
                         (1 + jnp.cos(jnp.pi * prog)))
        return jnp.where(step < warmup, warm, cos)
    return sched
