"""Trip-count-aware cost walk over compiled HLO text.

XLA's ``compiled.cost_analysis()`` counts while-loop (lax.scan) bodies ONCE,
which undercounts scan-over-layers models by ~L x microbatches. This walker
parses the post-optimization HLO, builds the computation call graph, and
multiplies while bodies by their trip count (largest integer constant in the
loop condition). Costs:

  * flops        — dot ops: 2 * prod(result) * prod(contracting dims)
  * bytes        — per top-level/fused instruction: result + operands
                   (fusions are NOT expanded: their internals never touch HBM)
  * collectives  — per-kind bytes with loop multipliers (an all-gather inside
                   the layer scan runs L times)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^()]*\)|\S+))\s+"
    r"([a-z][\w\-]*)\((.*)$")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLS = re.compile(r"calls=%?([\w.\-]+)")
_WHILE_ATTRS = re.compile(r"condition=%?([\w.\-]+),?\s*body=%?([\w.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")

FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "iota"}
COLLECTIVES = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_dims(shape_str: str) -> list[tuple[str, list[int]]]:
    return [(dt, [int(d) for d in dims.split(",") if d])
            for dt, dims in _SHAPE_RE.findall(shape_str)]


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_ops: float = 0.0

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k in self.coll:
            self.coll[k] += other.coll[k] * mult
        self.coll_ops += other.coll_ops * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())


def _parse(text: str):
    comps: dict[str, list[dict]] = {}
    entry = None
    cur = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if not line.startswith(" ") and "->" in line and "{" in line:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
                continue
        m = _INSTR.match(line)
        if m and cur is not None:
            name, shape_str, opcode, rest = m.groups()
            comps[cur].append({
                "name": name, "shape": shape_str, "op": opcode, "rest": rest,
            })
    return comps, entry


def _dot_flops(instr, symtab) -> float:
    res_elems = 0
    for _, dims in _shape_dims(instr["shape"]):
        n = 1
        for d in dims:
            n *= d
        res_elems += n
    m = _CONTRACT.search(instr["rest"])
    # first operand = lhs
    ops = _OPERAND.findall(instr["rest"].split(")", 1)[0])
    lhs_shape = symtab.get(ops[0]) if ops else None
    k = 1
    if m and lhs_shape:
        dims = _shape_dims(lhs_shape)
        if dims:
            _, ld = dims[0]
            for ci in (int(x) for x in m.group(1).split(",") if x):
                if ci < len(ld):
                    k *= ld[ci]
    return 2.0 * res_elems * k


_SLICING_OPS = {"dynamic-slice", "slice", "gather"}


def _instr_bytes(ins, symtab, comps) -> float:
    """HBM bytes touched by one top-level instruction.

    Slicing-aware: a (fused) dynamic-slice reads only the slice, and a
    dynamic-update-slice writes only the update region — counting full
    operand shapes would overstate KV-cache decode byte traffic ~100x.
    """
    op = ins["op"]
    if op in _SLICING_OPS:
        b = _shape_bytes(ins["shape"]) * 2          # read slice + write out
        return b
    if op == "dynamic-update-slice":
        ops_ = _OPERAND.findall(ins["rest"])
        upd = _shape_bytes(symtab.get(ops_[1], "")) if len(ops_) > 1 else 0
        return 2 * upd                               # read update + write region
    if op == "fusion":
        m = _CALLS.search(ins["rest"])
        inner = comps.get(m.group(1), []) if m else []
        if inner:
            inner_syms = {i["name"]: i["shape"] for i in inner}
            # consumer map over the fused computation
            consumers: dict[str, list] = {i["name"]: [] for i in inner}
            for ii in inner:
                if ii["op"] == "parameter":
                    continue
                for opnd in _OPERAND.findall(ii["rest"]):
                    if opnd in consumers:
                        consumers[opnd].append(ii)

            def accessed(name, depth=0):
                """Bytes of `name` actually read: slices read their result;
                elementwise converts/bitcasts are lazy — look through them."""
                cons = consumers.get(name, [])
                if not cons or depth > 4:
                    return _shape_bytes(inner_syms.get(name, ""))
                total = 0
                for c in cons:
                    if c["op"] in _SLICING_OPS:
                        total += _shape_bytes(c["shape"])
                    elif c["op"] in ("convert", "bitcast", "copy", "negate"):
                        total += min(accessed(c["name"], depth + 1),
                                     _shape_bytes(inner_syms.get(name, "")))
                    else:
                        return _shape_bytes(inner_syms.get(name, ""))
                return min(total, _shape_bytes(inner_syms.get(name, "")) * 2)

            params = [i for i in inner if i["op"] == "parameter"]
            b = 0.0
            for p in params:
                b += accessed(p["name"])
            root = inner[-1]
            if root["op"] == "dynamic-update-slice":
                ops_ = _OPERAND.findall(root["rest"])
                b += _shape_bytes(inner_syms.get(ops_[1], "")) if len(ops_) > 1 \
                    else _shape_bytes(root["shape"])
            else:
                b += _shape_bytes(ins["shape"])
            return b
    b = _shape_bytes(ins["shape"])
    for opnd in _OPERAND.findall(ins["rest"]):
        if opnd in symtab:
            b += _shape_bytes(symtab[opnd])
    return b


def _trip_count(comp_instrs) -> int:
    best = 1
    for ins in comp_instrs:
        if ins["op"] == "constant":
            m = re.match(r"(\d+)\)", ins["rest"])
            if m:
                best = max(best, int(m.group(1)))
        for c in _CONST_INT.findall(ins["rest"]):
            best = max(best, int(c))
    return best


def analyze_hlo(text: str) -> Costs:
    comps, entry = _parse(text)
    memo: dict[str, Costs] = {}

    def comp_cost(cname: str) -> Costs:
        if cname in memo:
            return memo[cname]
        memo[cname] = Costs()  # break cycles defensively
        total = Costs()
        instrs = comps.get(cname, [])
        symtab = {i["name"]: i["shape"] for i in instrs}
        for ins in instrs:
            op = ins["op"]
            if op in FREE_OPS:
                continue
            c = Costs()
            if op == "while":
                m = _WHILE_ATTRS.search(ins["rest"])
                if m:
                    cond, body = m.group(1), m.group(2)
                    mt = _TRIP_CFG.search(ins["rest"])
                    trips = (int(mt.group(1)) if mt
                             else _trip_count(comps.get(cond, [])))
                    c.add(comp_cost(body), trips)
                    c.add(comp_cost(cond), trips)
            elif op == "conditional":
                branches = _OPERAND.findall(ins["rest"])
                sub = [comp_cost(b) for b in branches if b in comps]
                if sub:
                    best = max(sub, key=lambda s: s.flops + s.bytes)
                    c.add(best)
            elif op == "call":
                m = _CALLS.search(ins["rest"]) or _WHILE_ATTRS.search(ins["rest"])
                tgt = None
                m2 = re.search(r"to_apply=%?([\w.\-]+)", ins["rest"])
                if m2:
                    tgt = m2.group(1)
                if tgt and tgt in comps:
                    c.add(comp_cost(tgt))
            else:
                if op == "dot":
                    c.flops += _dot_flops(ins, symtab)
                if op == "fusion":
                    # a fusion may wrap a dot: account inner dots' flops once
                    m = _CALLS.search(ins["rest"])
                    if m and m.group(1) in comps:
                        inner = comps[m.group(1)]
                        st = {i["name"]: i["shape"] for i in inner}
                        for ii in inner:
                            if ii["op"] == "dot":
                                c.flops += _dot_flops(ii, st)
                if op in COLLECTIVES or (op.endswith("-start")
                                         and op[:-6] in COLLECTIVES):
                    kind = op[:-6] if op.endswith("-start") else op
                    c.coll[kind] += _shape_bytes(ins["shape"]) \
                        * COLLECTIVES[kind]
                    c.coll_ops += 1
                # bytes: slicing-aware per-instruction HBM traffic
                c.bytes += _instr_bytes(ins, symtab, comps)
            total.add(c)
        memo[cname] = total
        return total

    return comp_cost(entry) if entry else Costs()


def top_contributors(text: str, k: int = 25):
    """Per-instruction (bytes x loop-multiplier) attribution — the 'profile'
    the §Perf hypothesis loop reads (no real-TPU timings exist here)."""
    comps, entry = _parse(text)
    if not entry:
        return []
    # propagate loop multipliers down the call graph
    mult: dict[str, float] = {entry: 1.0}
    orderq = [entry]
    while orderq:
        cname = orderq.pop()
        m = mult[cname]
        for ins in comps.get(cname, []):
            if ins["op"] == "while":
                mm = _WHILE_ATTRS.search(ins["rest"])
                if mm:
                    mt = _TRIP_CFG.search(ins["rest"])
                    trips = (int(mt.group(1)) if mt
                             else _trip_count(comps.get(mm.group(1), [])))
                    for sub in mm.groups():
                        if sub in comps:
                            mult[sub] = mult.get(sub, 0.0) + m * trips
                            orderq.append(sub)
    rows = []
    for cname, m in mult.items():
        instrs = comps.get(cname, [])
        symtab = {i["name"]: i["shape"] for i in instrs}
        for ins in instrs:
            if ins["op"] in FREE_OPS or ins["op"] in ("while",):
                continue
            b = _instr_bytes(ins, symtab, comps)
            fl = _dot_flops(ins, symtab) if ins["op"] == "dot" else 0.0
            coll = _shape_bytes(ins["shape"]) if ins["op"] in COLLECTIVES else 0.0
            rows.append({"bytes": b * m, "flops": fl * m, "coll": coll * m,
                         "mult": m, "op": ins["op"], "comp": cname,
                         "name": ins["name"], "shape": ins["shape"][:90]})
    rows.sort(key=lambda r: -r["bytes"])
    return rows[:k]
