"""Roofline-term extraction from compiled XLA artifacts (deliverable g).

Hardware constants (TPU v5e-class target, per task spec):
  197 TFLOP/s bf16 / chip, 819 GB/s HBM / chip, ~50 GB/s/link ICI.

``cost_analysis`` reports the post-SPMD per-device program, so FLOPs and
bytes are per-chip; the collective term uses per-chip collective bytes over
per-chip link bandwidth (equivalent to global_bytes / (chips x link_bw)).
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass

PEAK_FLOPS = 197.0e12
HBM_BW = 819.0e9
ICI_BW = 50.0e9

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLLECTIVE_FACTOR = {
    "all-gather": 1.0,          # every chip receives ~result bytes
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|\w+\[[^\]]*\](?:\{[^}]*\})?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-chip bytes moved by each collective kind in the compiled module."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVE_FACTOR}
    out["_ops"] = 0
    for m in _OP_RE.finditer(hlo_text):
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str) * _COLLECTIVE_FACTOR[kind]
        out["_ops"] += 1
    return out


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float             # per chip
    hlo_bytes: float             # per chip
    collective_bytes_per_chip: float
    collective_ops: int
    model_flops: float           # global useful FLOPs (6ND / 2ND)
    model_flops_per_chip: float
    useful_flop_ratio: float     # model / hlo (per chip)
    bottleneck: str
    step_time_s: float           # max of the three terms
    mfu: float                   # model_flops_per_chip / (step_time * peak)

    def to_dict(self):
        return asdict(self)


def analyze(compiled, *, n_chips: int, model_flops: float) -> RooflineTerms:
    """Roofline terms from the compiled per-device SPMD program.

    FLOPs/bytes/collectives come from the trip-count-aware HLO walk
    (launch.hlo_cost) because XLA's cost_analysis counts lax.scan bodies
    once — a ~L x microbatches undercount for scan-over-layers models.
    """
    from repro.launch.hlo_cost import analyze_hlo
    hlo = compiled.as_text()
    cost = analyze_hlo(hlo)
    flops, byts = cost.flops, cost.bytes
    coll_bytes = cost.collective_bytes

    compute_s = flops / PEAK_FLOPS
    memory_s = byts / HBM_BW
    collective_s = coll_bytes / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    step = max(compute_s, memory_s, collective_s)
    mf_chip = model_flops / n_chips
    return RooflineTerms(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        hlo_flops=flops, hlo_bytes=byts,
        collective_bytes_per_chip=coll_bytes,
        collective_ops=int(cost.coll_ops),
        model_flops=model_flops, model_flops_per_chip=mf_chip,
        useful_flop_ratio=mf_chip / flops if flops else 0.0,
        bottleneck=bottleneck, step_time_s=step,
        mfu=mf_chip / (step * PEAK_FLOPS) if step else 0.0)


def model_flops_for(cfg, shape) -> float:
    """Useful-FLOP estimate: 6·N_active·D for training, 2·N_active·D for
    inference forward (D = tokens processed this step)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def memory_report(compiled) -> dict:
    ma = compiled.memory_analysis()
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        out[k] = getattr(ma, k, None)
    args = out.get("argument_size_in_bytes") or 0
    alias = out.get("alias_size_in_bytes") or 0
    temp = out.get("temp_size_in_bytes") or 0
    outb = out.get("output_size_in_bytes") or 0
    out["resident_bytes"] = args + temp + max(outb - alias, 0)
    return out
