"""Launch layer: production meshes, multi-pod dry-run, roofline analysis,
train/serve drivers. NOTE: import repro.launch.dryrun only as __main__ —
it forces a 512-device view of the host platform."""
from repro.launch.mesh import make_local_mesh, make_production_mesh

__all__ = ["make_local_mesh", "make_production_mesh"]
