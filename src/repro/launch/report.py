"""Render the roofline table from results/dryrun/*.json (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import glob
import json
import os


def fmt_bytes(b):
    if b is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}PB"


def load(mesh: str, d="results/dryrun"):
    out = []
    for f in sorted(glob.glob(os.path.join(d, f"*_{mesh}.json"))):
        out.append(json.load(open(f)))
    return out


def roofline_table(mesh: str = "single") -> str:
    rows = []
    hdr = ("| arch | shape | compute s | memory s | collective s | bottleneck "
           "| HLO GFLOP/chip | HLO bytes/chip | coll bytes/chip | useful ratio "
           "| MFU | resident/chip |")
    sep = "|" + "---|" * 12
    rows.append(hdr)
    rows.append(sep)
    for r in load(mesh):
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped (sub-quadratic rule) | — | — | — | — | — | — |")
            continue
        if r["status"] != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"{r['status']} | — | — | — | — | — | — |")
            continue
        t = r["roofline"]
        mem = r["memory"]["resident_bytes"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3g} | "
            f"{t['memory_s']:.3g} | {t['collective_s']:.3g} | "
            f"**{t['bottleneck']}** | {t['hlo_flops']/1e9:.0f} | "
            f"{fmt_bytes(t['hlo_bytes'])} | "
            f"{fmt_bytes(t['collective_bytes_per_chip'])} | "
            f"{t['useful_flop_ratio']:.2f} | {t['mfu']*100:.1f}% | "
            f"{fmt_bytes(mem)} |")
    return "\n".join(rows)


def pick_hillclimb_candidates():
    """worst roofline fraction (MFU), most collective-bound, most
    representative of the paper's technique."""
    recs = [r for r in load("single") if r["status"] == "ok"]
    by_mfu = sorted(recs, key=lambda r: r["roofline"]["mfu"])
    by_coll = sorted(recs, key=lambda r: -(r["roofline"]["collective_s"] /
                                           max(r["roofline"]["step_time_s"], 1e-12)))
    return {
        "worst_mfu": [(r["arch"], r["shape"], r["roofline"]["mfu"])
                      for r in by_mfu[:6]],
        "most_collective": [(r["arch"], r["shape"],
                             r["roofline"]["collective_s"] /
                             max(r["roofline"]["step_time_s"], 1e-12))
                            for r in by_coll[:6]],
    }


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(roofline_table(mesh))
    print()
    print(json.dumps(pick_hillclimb_candidates(), indent=1))
