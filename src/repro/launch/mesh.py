"""Production mesh builders. Functions, not module constants — importing this
module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16,16) over ("data","model") = 256 chips.
    Multi-pod: (2,16,16) over ("pod","data","model") = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh for CPU smoke tests (axes exist, sizes 1)."""
    return jax.make_mesh((1, 1), ("data", "model"))
