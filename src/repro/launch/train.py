"""End-to-end RL post-training driver (single host, real execution).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --batch 8 --group 4

Runs the full synchronous on-policy loop the paper schedules:
rollout (generation) -> reward (verifiable) -> GRPO advantages ->
training step -> weight sync into the rollout actor.

``--rollout engine`` routes the rollout phase through the
continuous-batching serving engine (``rl.generate_continuous``) instead of
the static-batch ``generate`` scan — the same engine the serving drivers
and benchmarks exercise, so training traffic measures real serving
behaviour (``--kv paged`` serves it from the block-pool KV layout).
Greedy rollouts are token-identical across the two backends; sampled
rollouts draw from a different (equally valid) key stream.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import ArithmeticTask, tokenizer as tok
from repro.models import build_model
from repro.rl import (SamplerConfig, arithmetic_reward, generate,
                      generate_continuous, group_advantages,
                      init_train_state, make_train_step)
from repro.train.optimizer import AdamWConfig, warmup_cosine


def build_train_batch(out, adv, prompt_len):
    tokens = out["tokens"][:, :-1]
    labels = out["tokens"][:, 1:]
    B, T = out["completions"].shape
    zeros = jnp.zeros((B, prompt_len - 1), jnp.float32)
    loss_mask = jnp.concatenate([zeros, out["mask"]], axis=1)
    advm = jnp.broadcast_to(jnp.asarray(adv)[:, None], (B, T))
    advantages = jnp.concatenate([zeros, advm], axis=1)
    return {"tokens": tokens, "labels": labels, "loss_mask": loss_mask,
            "advantages": advantages,
            "behavior_logp": jnp.concatenate([zeros, out["behavior_logp"]], 1)}


def run_training(arch: str = "internlm2-1.8b", *, reduced: bool = True,
                 steps: int = 50, batch: int = 8, group: int = 4,
                 max_new: int = 8, lr: float = 3e-4, seed: int = 0,
                 log_every: int = 5, model=None, rollout: str = "static",
                 temperature: float = 1.0, num_slots: int | None = None,
                 engine_block_size: int = 1, kv: str = "contiguous",
                 kv_block_size: int = 16):
    """One synchronous GRPO loop.  ``rollout`` picks the generation backend:
    ``"static"`` = one fixed-shape ``generate`` scan per step, ``"engine"``
    = the continuous-batching serving engine (``num_slots`` KV slots,
    ``kv`` layout)."""
    if rollout not in ("static", "engine"):
        raise ValueError(f"unknown rollout backend {rollout!r}")
    model = model or build_model(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    opt_cfg = AdamWConfig(lr=lr)
    state = init_train_state(model, key, opt_cfg)
    task = ArithmeticTask(seed=seed)
    sampler = SamplerConfig(max_new_tokens=max_new, temperature=temperature)
    train_step = jax.jit(make_train_step(model, opt_cfg,
                                         lr_schedule=warmup_cosine(lr, 10, steps)))
    history = []
    for step in range(steps):
        b = task.sample_batch(batch)
        prompts = jnp.asarray(np.repeat(b.prompts, group, axis=0))
        key, k1 = jax.random.split(key)
        if rollout == "engine":
            out = generate_continuous(
                model, state["params"], prompts, k1, sampler,
                num_slots=num_slots, block_size=engine_block_size,
                kv_layout=kv, kv_block_size=kv_block_size)
        else:
            out = generate(model, state["params"], prompts, k1, sampler)
        answers = [a for a in b.answers for _ in range(group)]
        rewards = arithmetic_reward(out["completions"], out["mask"], answers)
        adv = group_advantages(rewards, group)
        tb = build_train_batch(out, adv, b.prompts.shape[1])
        state, metrics = train_step(state, tb)
        rec = {"step": step, "reward": float(rewards.mean()),
               "acc": float((rewards >= 1.0).mean()),
               "loss": float(metrics["loss"]),
               "entropy": float(metrics["entropy"])}
        history.append(rec)
        if step % log_every == 0:
            print(f"step {step:4d} reward={rec['reward']:.3f} "
                  f"acc={rec['acc']:.3f} loss={rec['loss']:.4f} "
                  f"entropy={rec['entropy']:.3f}", flush=True)
    return state, history


def _main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--group", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--rollout", choices=("static", "engine"),
                    default="static",
                    help="rollout backend: static generate scan or the "
                         "continuous-batching serving engine")
    ap.add_argument("--slots", type=int, default=None,
                    help="engine KV slots (--rollout engine; default = "
                         "batch * group)")
    ap.add_argument("--kv", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="engine KV layout (--rollout engine)")
    ap.add_argument("--kv-block-size", type=int, default=16)
    args = ap.parse_args()
    t0 = time.time()
    _, hist = run_training(args.arch, reduced=args.reduced, steps=args.steps,
                           batch=args.batch, group=args.group,
                           max_new=args.max_new, lr=args.lr,
                           rollout=args.rollout, num_slots=args.slots,
                           kv=args.kv, kv_block_size=args.kv_block_size)
    print(f"done in {time.time()-t0:.1f}s; "
          f"final reward {hist[-1]['reward']:.3f}")


if __name__ == "__main__":
    _main()
