"""End-to-end RL post-training driver (single host, real execution).

    PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
        --reduced --steps 50 --batch 8 --group 4

Runs the full synchronous on-policy loop the paper schedules:
rollout (generation) -> reward (verifiable) -> GRPO advantages ->
training step -> weight sync into the rollout actor.

``--rollout engine`` routes the rollout phase through the
continuous-batching serving engine (``rl.generate_continuous``) instead of
the static-batch ``generate`` scan — the same engine the serving drivers
and benchmarks exercise, so training traffic measures real serving
behaviour (``--kv paged`` serves it from the block-pool KV layout).
Greedy rollouts are token-identical across the two backends; sampled
rollouts draw from a different (equally valid) key stream.

``--mux`` picks the phase-multiplexing executor (``rl.coexec``), the
paper's answer to the rollout<->train dependency bubble:

* ``off`` (default) — rollout and training back-to-back, the
  standard-disaggregation baseline.
* ``pipeline`` — overlap the rollout of GRPO iteration ``k+1`` with the
  training step of iteration ``k``.  The on-policy staleness guard
  ``--mux-staleness`` bounds how many optimizer steps the rollout weights
  may lag: ``0`` forces full sync (bit-exact to ``off``, no overlap),
  ``1`` (default) overlaps adjacent iterations, correcting the bounded
  off-policy drift with the clipped importance ratio (the per-step lag is
  recorded as ``rollout_staleness`` in the history).
* ``coexec`` — ``--jobs`` independent GRPO jobs time-multiplex the shared
  rollout/train pools round-robin with warm-start context switches from
  the host-DRAM actor cache: while one job trains, another's rollout
  drains through the engine.  Job ``i`` uses ``seed + i``; per-job losses
  are bit-exact to running that job alone.
* ``stream`` — group-level pipelining *inside* the job (``rl.stream``):
  the engine streams each completed GRPO prompt group to a reward permit
  pool (``--reward-workers`` verifiers running off the critical path —
  see ``--reward`` / ``--reward-latency``) while it keeps decoding the
  stragglers, and the trainer consumes rewarded groups as micro-batches
  (``--micro-groups``; default = one bit-exact full-batch step per
  iteration) behind the same staleness guard, extended past 1 with
  clipped importance-ratio diagnostics in the history.

All modes print/return per-step history; the mux modes additionally
report the measured phase timelines (reclaimed dependency bubble) — see
``benchmarks/train_mux.py`` for the tracked numbers.
"""
from __future__ import annotations

import argparse
import time

from repro.models import build_model
from repro.rl.coexec import (GRPOJob, MuxConfig, build_train_batch,
                             run_coexec, run_pipelined, run_sequential)
from repro.rl.rewards import make_reward
from repro.rl.stream import run_streaming

__all__ = ["build_train_batch", "run_training"]


def run_training(arch: str = "internlm2-1.8b", *, reduced: bool = True,
                 steps: int = 50, batch: int = 8, group: int = 4,
                 max_new: int = 8, lr: float = 3e-4, seed: int = 0,
                 log_every: int = 5, model=None, rollout: str = "static",
                 temperature: float = 1.0, num_slots: int | None = None,
                 engine_block_size: int = 1, kv: str = "contiguous",
                 kv_block_size: int = 16, sched: str = "fifo",
                 prefix_share: bool = False,
                 kernel_backend: str = "jnp", kv_dtype: str | None = None,
                 slo_bound: float = 2.0,
                 mux: str = "off", mux_staleness: int = 1, jobs: int = 2,
                 reward: str = "arith", reward_latency: float = 0.0,
                 reward_workers: int = 2, micro_groups: int | None = None,
                 elastic: bool = False,
                 spec=None, carry: bool = False,
                 return_report: bool = False):
    """GRPO post-training through the phase-multiplexed executors.

    ``rollout`` picks the generation backend (``"static"`` scan or the
    continuous-batching serving ``"engine"``); ``mux`` picks the executor
    (see module docstring); ``reward``/``reward_latency`` pick the
    verifier (``rl.rewards.make_reward`` — a nonzero latency wraps it in
    the slow external-verifier stub, the workload ``--mux stream``'s
    reward pool hides off the critical path).  Returns ``(state,
    history)`` — or, for ``mux="coexec"``, ``(states, histories)`` dicts
    keyed by job id — plus the :class:`~repro.rl.coexec.MuxReport` when
    ``return_report``.
    """
    from repro.serve import RolloutSpec

    cfg = MuxConfig(mode=mux, max_staleness=mux_staleness,
                    reward_workers=reward_workers, micro_groups=micro_groups)
    reward_fn = make_reward(reward, latency_s=reward_latency, seed=seed)
    if spec is None:
        spec = RolloutSpec(num_slots=num_slots,
                           block_size=engine_block_size, kv_layout=kv,
                           kv_block_size=kv_block_size, sched=sched,
                           prefix_share=prefix_share,
                           kernel_backend=kernel_backend, kv_dtype=kv_dtype,
                           carry=carry)

    def make_job(jid: str, job_seed: int) -> GRPOJob:
        return GRPOJob(
            jid, model=model or build_model(arch, reduced=reduced),
            seed=job_seed, steps=steps, batch=batch, group=group,
            max_new=max_new, lr=lr, temperature=temperature, rollout=rollout,
            spec=spec, carry=carry or spec.carry, slo_bound=slo_bound,
            reward_fn=reward_fn)

    if cfg.mode == "off":
        state, hist, report = run_sequential(make_job("job0", seed),
                                             log_every=log_every)
    elif cfg.mode == "pipeline":
        state, hist, report = run_pipelined(make_job("job0", seed),
                                            max_staleness=cfg.max_staleness,
                                            log_every=log_every)
    elif cfg.mode == "stream":
        state, hist, report = run_streaming(
            make_job("job0", seed), max_staleness=cfg.max_staleness,
            reward_workers=cfg.reward_workers,
            micro_groups=cfg.micro_groups, elastic=elastic,
            log_every=log_every)
    else:                                   # "coexec"
        if jobs < 1:
            raise ValueError("coexec needs >= 1 jobs")
        group_jobs = [make_job(f"job{i}", seed + i) for i in range(jobs)]
        state, hist, report = run_coexec(group_jobs,
                                         host_cache_gb=cfg.host_cache_gb,
                                         log_every=log_every)
    if return_report:
        return state, hist, report
    return state, hist


def _main():
    ap = argparse.ArgumentParser(
        description="GRPO post-training with phase-multiplexed execution",
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--group", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--rollout", choices=("static", "engine"),
                    default="static",
                    help="rollout backend: static generate scan or the "
                         "continuous-batching serving engine")
    ap.add_argument("--slots", type=int, default=None,
                    help="engine KV slots (--rollout engine; default = "
                         "batch * group)")
    ap.add_argument("--kv", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="engine KV layout (--rollout engine)")
    ap.add_argument("--kv-block-size", type=int, default=16)
    ap.add_argument("--sched", choices=("fifo", "deadline", "slo"),
                    default="fifo",
                    help="engine admission policy (--rollout engine): "
                         "fifo = strict arrival order; deadline = EDF with "
                         "bounded head skipping + per-job token budgets; "
                         "slo = deadlines from the job's slowdown bound "
                         "(--slo-bound), the inter-group SLO contract")
    ap.add_argument("--slo-bound", type=float, default=2.0,
                    help="admitted slowdown bound the slo policy enforces "
                         "(core.InterGroupScheduler.slo_contract exports "
                         "this per job in a planned cluster)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="radix prompt-prefix KV sharing (--kv paged): the "
                         "GRPO group's duplicated prompt prefills once and "
                         "its full blocks are pinned under all members")
    ap.add_argument("--kernel-backend", choices=("jnp", "pallas"),
                    default="jnp",
                    help="engine decode backend (--rollout engine): jnp = "
                         "vmapped model step; pallas = batched "
                         "decode-attention kernels + fused greedy sampling "
                         "(token-identical; recurrent archs fall back)")
    ap.add_argument("--kv-dtype", choices=("auto", "int8"), default=None,
                    help="engine paged KV storage dtype (--kv paged): int8 "
                         "quantizes blocks with per-position scales, "
                         "~halving rollout KV memory per request")
    ap.add_argument("--mux", choices=("off", "pipeline", "coexec", "stream"),
                    default="off",
                    help="phase multiplexing: 'off' runs rollout and "
                         "training back-to-back (baseline); 'pipeline' "
                         "overlaps next-iteration rollout with the current "
                         "training step behind the --mux-staleness guard; "
                         "'coexec' round-robins --jobs jobs over the shared "
                         "rollout/train pools with warm-start switches; "
                         "'stream' pipelines at prompt-group granularity — "
                         "finished groups flow to the --reward-workers "
                         "reward pool and to train micro-batches while the "
                         "engine still decodes the stragglers")
    ap.add_argument("--mux-staleness", type=int, default=1,
                    help="pipeline/stream modes: max optimizer iterations "
                         "the rollout weights may lag (0 = force sync; "
                         "bit-exact to --mux off but with no overlap)")
    ap.add_argument("--carry", action="store_true",
                    help="stream mode, --rollout engine: partial-rollout "
                         "continuation — a mid-rollout weight sync suspends "
                         "live generations, swaps weights and resumes them "
                         "(Engine.reset(carry_live=True)) instead of "
                         "finishing the iteration on stale weights; "
                         "per-token weight versions feed the clip-fraction "
                         "diagnostics")
    ap.add_argument("--jobs", type=int, default=2,
                    help="coexec mode: number of co-executing jobs "
                         "(job i uses seed+i)")
    ap.add_argument("--reward", default="arith",
                    choices=("arith", "length", "format", "composite"),
                    help="verifier (rl.rewards): exact numeric match, "
                         "match + length penalty, regex format check, or "
                         "a weighted composite")
    ap.add_argument("--reward-latency", type=float, default=0.0,
                    help="wrap the verifier in the slow external-verifier "
                         "stub with this mean verdict latency (seconds); "
                         "--mux stream hides it on the reward pool")
    ap.add_argument("--reward-workers", type=int, default=2,
                    help="stream mode: reward permit-pool capacity "
                         "(concurrent verifier calls)")
    ap.add_argument("--micro-groups", type=int, default=None,
                    help="stream mode: rewarded groups per train "
                         "micro-step (default: all groups of an iteration "
                         "in one bit-exact full-batch step)")
    ap.add_argument("--elastic", action="store_true",
                    help="stream mode: close the loop on the reward "
                         "permit pool — each iteration reads the runtime's "
                         "MetricsSnapshot and grows the pool toward "
                         "--reward-workers when verifiers queue, shrinks "
                         "it when the pool idles (held permits are never "
                         "revoked)")
    args = ap.parse_args()
    from repro.serve import RolloutSpec
    spec = RolloutSpec.from_args(args)
    t0 = time.time()
    out = run_training(args.arch, reduced=args.reduced, steps=args.steps,
                       batch=args.batch, group=args.group,
                       max_new=args.max_new, lr=args.lr, seed=args.seed,
                       rollout=args.rollout, spec=spec, carry=args.carry,
                       slo_bound=args.slo_bound,
                       mux=args.mux, mux_staleness=args.mux_staleness,
                       jobs=args.jobs, reward=args.reward,
                       reward_latency=args.reward_latency,
                       reward_workers=args.reward_workers,
                       micro_groups=args.micro_groups, elastic=args.elastic,
                       return_report=True)
    _, hist, report = out
    wall = time.time() - t0
    if args.mux == "coexec":
        finals = {jid: h[-1]["reward"] for jid, h in hist.items() if h}
        print(f"done in {wall:.1f}s; final rewards "
              + ", ".join(f"{j}={r:.3f}" for j, r in sorted(finals.items())))
    else:
        print(f"done in {wall:.1f}s; final reward {hist[-1]['reward']:.3f}")
    s = report.summary()
    reward_part = (f"reward busy {s['total_reward_s']:.2f}s, "
                   if s["total_reward_s"] else "")
    print(f"mux={report.mode}: rollout busy {s['total_rollout_s']:.2f}s, "
          f"train busy {s['total_train_s']:.2f}s, {reward_part}"
          f"overlap {s['overlap_s']:.2f}s "
          f"({s['reclaimed_bubble_frac']:.0%} of the back-to-back bubble "
          f"reclaimed)")


if __name__ == "__main__":
    _main()
