"""Serving drivers: continuous-batching engine (default) + static batch.

The continuous path feeds prompts through ``repro.serve.Engine`` —
policy-driven admission (``--sched fifo|deadline|slo``) into a fixed pool
of KV-cache slots, slot recycle on EOS, decode batched across all live
slots, optional radix prompt-prefix KV sharing (``--prefix-share``, paged
layout).  The static path is the legacy one-batch-end-to-end ``generate``
call, kept as the benchmark baseline.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b \
        --batch 8 --slots 4 --max-new 32              # continuous (default)
    PYTHONPATH=src python -m repro.launch.serve --engine static ...
    PYTHONPATH=src python -m repro.launch.serve --kv paged --prefix-share \
        --group 4                                     # GRPO-shaped sharing
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import tokenizer as tok
from repro.models import build_model
from repro.rl import (SamplerConfig, completions_to_text, generate,
                      generate_continuous)
from repro.serve import RolloutSpec


def _encode_prompts(model, prompts_text):
    plen = max(len(tok.encode(t, bos=True)) for t in prompts_text)
    prompts = jnp.asarray(tok.pad_batch(
        [tok.encode(t, bos=True) for t in prompts_text], plen))
    fr = None
    if model.cfg.frontend == "vision":
        fr = jnp.zeros((prompts.shape[0], model.cfg.num_frontend_tokens,
                        model.cfg.d_model))
    elif model.cfg.frontend == "audio":
        fr = jnp.zeros((prompts.shape[0], model.cfg.max_source_len,
                        model.cfg.d_model))
    return prompts, fr


def serve_batch(arch: str, prompts_text: list[str], *, reduced: bool = True,
                max_new: int = 32, temperature: float = 0.8, seed: int = 0,
                model=None, params=None):
    """Static batch: one prefill + fixed-length decode scan for the whole
    batch (every request pays ``max_new`` steps regardless of EOS)."""
    if model is None:
        model = build_model(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model.init(key)
    prompts, fr = _encode_prompts(model, prompts_text)
    sampler = SamplerConfig(max_new_tokens=max_new, temperature=temperature)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, key, sampler, frontend=fr)
    jax.block_until_ready(out["completions"])
    dt = time.perf_counter() - t0
    n_tok = int(out["mask"].sum())
    return {"texts": completions_to_text(out["completions"], out["mask"]),
            "wall_s": dt, "tokens": n_tok,
            "tok_per_s": n_tok / max(dt, 1e-9)}


def serve_continuous(arch: str, prompts_text: list[str], *,
                     reduced: bool = True, max_new: int = 32,
                     temperature: float = 0.8, seed: int = 0,
                     spec: RolloutSpec | None = None,
                     num_slots: int | None = None, block_size: int = 1,
                     kv: str = "contiguous", kv_block_size: int = 16,
                     num_kv_blocks: int | None = None,
                     sched: str = "fifo", policy=None,
                     prefix_share: bool = False, group: int | None = None,
                     disagg=None, kernel_backend: str = "jnp",
                     kv_dtype: str | None = None, model=None, params=None):
    """Continuous batching: requests stream through the slot-pool engine
    (``kv="paged"`` serves from the shared block-pool KV layout;
    ``sched`` picks the admission policy and ``prefix_share`` enables
    radix prompt-prefix sharing — with ``group``, every ``group``
    consecutive prompts are treated as one shared-prefix group).
    ``disagg`` routes through split prefill/decode pools instead of one
    engine — ``True`` or a dict of ``DisaggConfig`` overrides (see
    ``rl.generate_continuous``); output is identical under greedy.
    ``spec`` supplies the whole engine shape as one
    :class:`~repro.serve.RolloutSpec` instead of the loose kwargs."""
    if spec is None:
        spec = RolloutSpec(num_slots=num_slots, block_size=block_size,
                           kv_layout=kv, kv_block_size=kv_block_size,
                           num_kv_blocks=num_kv_blocks, sched=sched,
                           prefix_share=prefix_share, disagg=disagg,
                           kernel_backend=kernel_backend, kv_dtype=kv_dtype,
                           group=group)
    elif group is not None:
        spec = spec.replace(group=group)
    if model is None:
        model = build_model(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model.init(key)
    prompts, fr = _encode_prompts(model, prompts_text)
    sampler = SamplerConfig(max_new_tokens=max_new, temperature=temperature)
    t0 = time.perf_counter()
    out = generate_continuous(model, params, prompts, key, sampler,
                              frontend=fr, spec=spec, policy=policy)
    dt = time.perf_counter() - t0
    n_tok = int(out["mask"].sum())
    stats = out["engine_stats"]
    report = {"texts": completions_to_text(out["completions"], out["mask"]),
              "wall_s": dt, "tokens": n_tok,
              "tok_per_s": n_tok / max(dt, 1e-9),
              "slot_utilization": stats.slot_utilization,
              "prefills": stats.prefills, "decode_steps": stats.steps,
              "peak_active": stats.peak_active,
              "peak_kv_blocks": stats.peak_kv_blocks,
              "prefix_hits": stats.prefix_hits,
              "blocks_saved": stats.blocks_saved}
    if spec.disagg:
        report["transfers"] = stats.transfers
        report["transfer_time_s"] = stats.transfer_time_s
        report["transferred_blocks"] = stats.transferred_blocks
        report["transfer_overhead_frac"] = stats.transfer_overhead_frac
    return report


def _main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=None,
                    help="KV-cache slots (continuous only; default = batch)")
    ap.add_argument("--block-size", type=int, default=1,
                    help="decode steps fused per scheduler tick")
    ap.add_argument("--kv", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="KV-cache layout (continuous engine only)")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV block (--kv paged)")
    ap.add_argument("--num-kv-blocks", type=int, default=None,
                    help="paged pool size in blocks (default: same memory "
                         "as the contiguous slot pool)")
    ap.add_argument("--sched", choices=("fifo", "deadline", "slo"),
                    default="fifo",
                    help="admission policy: fifo = strict arrival order; "
                         "deadline = EDF with bounded head skipping; slo = "
                         "deadlines derived from a slowdown bound (the "
                         "inter-group SLO contract)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="content-addressed radix-tree KV sharing (--kv "
                         "paged): requests agreeing on a block-aligned "
                         "token prefix share those blocks, exact repeats "
                         "skip prefill entirely (no tag needed)")
    ap.add_argument("--group", type=int, default=None,
                    help="shared-prefix group size for --prefix-share "
                         "(each prompt is duplicated group times, the "
                         "GRPO rollout shape)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: route prompts through a "
                         "dedicated prefill engine, hand the finished KV "
                         "over to the decode engine by block-granular "
                         "transfer handle (output identical under greedy)")
    ap.add_argument("--prefill-slots", type=int, default=None,
                    help="prefill-side slot pool (--disagg; default: "
                         "slots/4, min 1)")
    ap.add_argument("--decode-slots", type=int, default=None,
                    help="decode-side slot pool (--disagg; default: "
                         "slots - prefill slots)")
    ap.add_argument("--prefill-kv-blocks", type=int, default=None,
                    help="prefill-side paged pool size (--disagg --kv "
                         "paged; default: sized to its slot pool)")
    ap.add_argument("--decode-kv-blocks", type=int, default=None,
                    help="decode-side paged pool size (--disagg --kv "
                         "paged; default: --num-kv-blocks)")
    ap.add_argument("--prefill-engines", type=int, default=None,
                    help="parallel prefill engines (--disagg; each gets "
                         "its own full-size pools and radix tree)")
    ap.add_argument("--kv-routing", choices=("kv_aware", "queue"),
                    default=None,
                    help="request steering across --prefill-engines: "
                         "kv_aware sends each request to the engine "
                         "holding its longest registered prefix; queue "
                         "balances on load alone")
    ap.add_argument("--kernel-backend", choices=("jnp", "pallas"),
                    default="jnp",
                    help="decode-step backend (continuous engine only): "
                         "jnp = vmapped model step; pallas = batched "
                         "decode-attention kernels + fused greedy sampling "
                         "(token-identical; recurrent archs fall back)")
    ap.add_argument("--kv-dtype", choices=("auto", "int8"), default=None,
                    help="paged KV storage dtype (--kv paged): int8 "
                         "quantizes blocks with per-position scales, "
                         "~halving KV memory per request")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    spec = RolloutSpec.from_args(args)
    prompts = [f"{i}+{i+1}=" for i in range(args.batch)]
    if args.group:
        prompts = [p for p in prompts for _ in range(args.group)]
    if args.engine == "continuous":
        res = serve_continuous(args.arch, prompts, max_new=args.max_new,
                               spec=spec)
        extra = (f", slot util {res['slot_utilization']:.0%}, "
                 f"{res['decode_steps']} decode steps")
        if args.prefix_share:
            extra += (f", {res['prefix_hits']} prefix hits "
                      f"({res['blocks_saved']} blocks saved)")
        if args.disagg:
            extra += (f", {res['transfers']} KV transfers "
                      f"({res['transfer_overhead_frac']:.1%} overhead)")
    else:
        res = serve_batch(args.arch, prompts, max_new=args.max_new)
        extra = ""
    print(f"[{args.engine}] served {len(prompts)} requests, {res['tokens']} "
          f"tokens in {res['wall_s']:.2f}s ({res['tok_per_s']:.1f} tok/s"
          f"{extra})")
    for p, t in zip(prompts, res["texts"]):
        print(f"  {p!r} -> {t!r}")


if __name__ == "__main__":
    _main()
