"""Serving drivers: continuous-batching engine (default) + static batch.

The continuous path feeds prompts through ``repro.serve.Engine`` —
policy-driven admission (``--sched fifo|deadline|slo``) into a fixed pool
of KV-cache slots, slot recycle on EOS, decode batched across all live
slots, optional radix prompt-prefix KV sharing (``--prefix-share``, paged
layout).  The static path is the legacy one-batch-end-to-end ``generate``
call, kept as the benchmark baseline.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b \
        --batch 8 --slots 4 --max-new 32              # continuous (default)
    PYTHONPATH=src python -m repro.launch.serve --engine static ...
    PYTHONPATH=src python -m repro.launch.serve --kv paged --prefix-share \
        --group 4                                     # GRPO-shaped sharing
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import tokenizer as tok
from repro.models import build_model
from repro.rl import (SamplerConfig, completions_to_text, generate,
                      generate_continuous)
from repro.serve import RolloutSpec


def _encode_prompts(model, prompts_text):
    plen = max(len(tok.encode(t, bos=True)) for t in prompts_text)
    prompts = jnp.asarray(tok.pad_batch(
        [tok.encode(t, bos=True) for t in prompts_text], plen))
    fr = None
    if model.cfg.frontend == "vision":
        fr = jnp.zeros((prompts.shape[0], model.cfg.num_frontend_tokens,
                        model.cfg.d_model))
    elif model.cfg.frontend == "audio":
        fr = jnp.zeros((prompts.shape[0], model.cfg.max_source_len,
                        model.cfg.d_model))
    return prompts, fr


def serve_batch(arch: str, prompts_text: list[str], *, reduced: bool = True,
                max_new: int = 32, temperature: float = 0.8, seed: int = 0,
                model=None, params=None):
    """Static batch: one prefill + fixed-length decode scan for the whole
    batch (every request pays ``max_new`` steps regardless of EOS)."""
    if model is None:
        model = build_model(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model.init(key)
    prompts, fr = _encode_prompts(model, prompts_text)
    sampler = SamplerConfig(max_new_tokens=max_new, temperature=temperature)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, key, sampler, frontend=fr)
    jax.block_until_ready(out["completions"])
    dt = time.perf_counter() - t0
    n_tok = int(out["mask"].sum())
    return {"texts": completions_to_text(out["completions"], out["mask"]),
            "wall_s": dt, "tokens": n_tok,
            "tok_per_s": n_tok / max(dt, 1e-9)}


def serve_continuous(arch: str, prompts_text: list[str], *,
                     reduced: bool = True, max_new: int = 32,
                     temperature: float = 0.8, seed: int = 0,
                     spec: RolloutSpec | None = None,
                     num_slots: int | None = None, block_size: int = 1,
                     kv: str = "contiguous", kv_block_size: int = 16,
                     num_kv_blocks: int | None = None,
                     sched: str = "fifo", policy=None,
                     prefix_share: bool = False, group: int | None = None,
                     disagg=None, kernel_backend: str = "jnp",
                     kv_dtype: str | None = None, model=None, params=None):
    """Continuous batching: requests stream through the slot-pool engine
    (``kv="paged"`` serves from the shared block-pool KV layout;
    ``sched`` picks the admission policy and ``prefix_share`` enables
    radix prompt-prefix sharing — with ``group``, every ``group``
    consecutive prompts are treated as one shared-prefix group).
    ``disagg`` routes through split prefill/decode pools instead of one
    engine — ``True`` or a dict of ``DisaggConfig`` overrides (see
    ``rl.generate_continuous``); output is identical under greedy.
    ``spec`` supplies the whole engine shape as one
    :class:`~repro.serve.RolloutSpec` instead of the loose kwargs."""
    if spec is None:
        spec = RolloutSpec(num_slots=num_slots, block_size=block_size,
                           kv_layout=kv, kv_block_size=kv_block_size,
                           num_kv_blocks=num_kv_blocks, sched=sched,
                           prefix_share=prefix_share, disagg=disagg,
                           kernel_backend=kernel_backend, kv_dtype=kv_dtype,
                           group=group)
    elif group is not None:
        spec = spec.replace(group=group)
    if model is None:
        model = build_model(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model.init(key)
    prompts, fr = _encode_prompts(model, prompts_text)
    sampler = SamplerConfig(max_new_tokens=max_new, temperature=temperature)
    t0 = time.perf_counter()
    out = generate_continuous(model, params, prompts, key, sampler,
                              frontend=fr, spec=spec, policy=policy)
    dt = time.perf_counter() - t0
    n_tok = int(out["mask"].sum())
    stats = out["engine_stats"]
    report = {"texts": completions_to_text(out["completions"], out["mask"]),
              "wall_s": dt, "tokens": n_tok,
              "tok_per_s": n_tok / max(dt, 1e-9),
              "slot_utilization": stats.slot_utilization,
              "prefills": stats.prefills, "decode_steps": stats.steps,
              "peak_active": stats.peak_active,
              "peak_kv_blocks": stats.peak_kv_blocks,
              "prefix_hits": stats.prefix_hits,
              "blocks_saved": stats.blocks_saved}
    if spec.disagg:
        report["transfers"] = stats.transfers
        report["transfer_time_s"] = stats.transfer_time_s
        report["transferred_blocks"] = stats.transferred_blocks
        report["transfer_overhead_frac"] = stats.transfer_overhead_frac
    return report


def serve_elastic(arch: str, prompts_text: list[str], *,
                  reduced: bool = True, max_new: int = 32, seed: int = 0,
                  spec: RolloutSpec | None = None,
                  ladder: tuple = (2, 4, 8), shed: bool = False,
                  deadline_s: float = 2.0, arrival_gap_s: float = 0.05,
                  warmup: bool = True, model=None, params=None):
    """Closed-loop elastic serving: replay a staggered arrival trace
    through ``serve.run_trace`` with an ``ElasticController`` in the loop.

    The engine starts on the smallest rung of ``ladder`` and the
    controller grows/shrinks it between steps by suspend/resume (live KV
    carried, greedy tokens identical to a static run).  ``shed=True``
    stamps every request with an ``arrival + deadline_s`` deadline and
    arms the admission gate: requests that cannot meet their deadline are
    degraded (decode budget clamped) before being shed, and every shed is
    recorded in the report — never silently dropped.  Returns the
    ``run_trace`` report; its ``"elastic"`` section carries
    capacity-seconds, sheds/degrades and the resize history.

    ``warmup`` (default on) pre-compiles every ladder rung's decode shape
    on a throwaway engine before the trace starts — otherwise the first
    step's jit compile lands in ``decode_time_s``, the admission
    predictor reads a wildly inflated time-per-token, and an unloaded
    system sheds like a saturated one."""
    import numpy as np

    from repro.serve import ElasticConfig, ElasticController, Request
    from repro.serve.engine import run_trace

    if model is None:
        model = build_model(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    if params is None:
        params = model.init(key)
    ladder = tuple(sorted({int(x) for x in ladder}))
    if spec is None:
        spec = RolloutSpec()
    spec = spec.replace(num_slots=ladder[0])
    toks = [np.asarray(tok.encode(t, bos=True), np.int32)
            for t in prompts_text]
    plen = max(len(t) for t in toks)
    if warmup:
        for rung in ladder:
            warm = spec.replace(num_slots=rung).build_engine(
                model, params, batch=len(toks),
                max_seq_len=plen + max_new, eos_id=tok.EOS,
                temperature=0.0, rng=key)
            warm.submit(Request(rid=0, prompt=toks[0], max_new_tokens=2))
            while not warm.idle:
                warm.step()
    engine = spec.build_engine(model, params, batch=len(toks),
                               max_seq_len=plen + max_new, eos_id=tok.EOS,
                               temperature=0.0, rng=key)
    reqs = []
    for i, t in enumerate(toks):
        fr = None
        if model.cfg.frontend == "vision":
            fr = jnp.zeros((1, model.cfg.num_frontend_tokens,
                            model.cfg.d_model))
        elif model.cfg.frontend == "audio":
            fr = jnp.zeros((1, model.cfg.max_source_len, model.cfg.d_model))
        arrival = i * arrival_gap_s
        reqs.append(Request(rid=i, prompt=t, max_new_tokens=max_new,
                            arrival_time=arrival, frontend=fr,
                            deadline=arrival + deadline_s if shed else None))
    controller = ElasticController(ElasticConfig(
        ladder=ladder, shed=shed, interval_s=0.05, cooldown_s=0.15))
    report = run_trace(engine, reqs, realtime=False, controller=controller)
    report["texts"] = [
        tok.decode([int(x) for x in o.tokens if int(x) != tok.EOS])
        for o in report["outputs"]]
    return report


def _main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--engine", choices=("continuous", "static", "elastic"),
                    default="continuous",
                    help="continuous = fixed-capacity slot-pool engine; "
                         "static = legacy one-batch generate; elastic = "
                         "continuous engine under the closed-loop capacity "
                         "controller (serve.elastic) replaying a staggered "
                         "arrival trace")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--slots", type=int, default=None,
                    help="KV-cache slots (continuous only; default = batch)")
    ap.add_argument("--block-size", type=int, default=1,
                    help="decode steps fused per scheduler tick")
    ap.add_argument("--kv", choices=("contiguous", "paged"),
                    default="contiguous",
                    help="KV-cache layout (continuous engine only)")
    ap.add_argument("--kv-block-size", type=int, default=16,
                    help="tokens per KV block (--kv paged)")
    ap.add_argument("--num-kv-blocks", type=int, default=None,
                    help="paged pool size in blocks (default: same memory "
                         "as the contiguous slot pool)")
    ap.add_argument("--sched", choices=("fifo", "deadline", "slo"),
                    default="fifo",
                    help="admission policy: fifo = strict arrival order; "
                         "deadline = EDF with bounded head skipping; slo = "
                         "deadlines derived from a slowdown bound (the "
                         "inter-group SLO contract)")
    ap.add_argument("--prefix-share", action="store_true",
                    help="content-addressed radix-tree KV sharing (--kv "
                         "paged): requests agreeing on a block-aligned "
                         "token prefix share those blocks, exact repeats "
                         "skip prefill entirely (no tag needed)")
    ap.add_argument("--group", type=int, default=None,
                    help="shared-prefix group size for --prefix-share "
                         "(each prompt is duplicated group times, the "
                         "GRPO rollout shape)")
    ap.add_argument("--disagg", action="store_true",
                    help="disaggregated serving: route prompts through a "
                         "dedicated prefill engine, hand the finished KV "
                         "over to the decode engine by block-granular "
                         "transfer handle (output identical under greedy)")
    ap.add_argument("--prefill-slots", type=int, default=None,
                    help="prefill-side slot pool (--disagg; default: "
                         "slots/4, min 1)")
    ap.add_argument("--decode-slots", type=int, default=None,
                    help="decode-side slot pool (--disagg; default: "
                         "slots - prefill slots)")
    ap.add_argument("--prefill-kv-blocks", type=int, default=None,
                    help="prefill-side paged pool size (--disagg --kv "
                         "paged; default: sized to its slot pool)")
    ap.add_argument("--decode-kv-blocks", type=int, default=None,
                    help="decode-side paged pool size (--disagg --kv "
                         "paged; default: --num-kv-blocks)")
    ap.add_argument("--prefill-engines", type=int, default=None,
                    help="parallel prefill engines (--disagg; each gets "
                         "its own full-size pools and radix tree)")
    ap.add_argument("--kv-routing", choices=("kv_aware", "queue"),
                    default=None,
                    help="request steering across --prefill-engines: "
                         "kv_aware sends each request to the engine "
                         "holding its longest registered prefix; queue "
                         "balances on load alone")
    ap.add_argument("--kernel-backend", choices=("jnp", "pallas"),
                    default="jnp",
                    help="decode-step backend (continuous engine only): "
                         "jnp = vmapped model step; pallas = batched "
                         "decode-attention kernels + fused greedy sampling "
                         "(token-identical; recurrent archs fall back)")
    ap.add_argument("--kv-dtype", choices=("auto", "int8"), default=None,
                    help="paged KV storage dtype (--kv paged): int8 "
                         "quantizes blocks with per-position scales, "
                         "~halving KV memory per request")
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--ladder", default="2,4,8",
                    help="--engine elastic: comma-separated slot-count "
                         "rungs the controller may resize between (each "
                         "rung compiles its own decode shape once)")
    ap.add_argument("--shed", action="store_true",
                    help="--engine elastic: stamp deadlines on every "
                         "request and arm overload admission control — "
                         "degrade (clamp decode budget) before shedding, "
                         "report every shed")
    ap.add_argument("--deadline-s", type=float, default=2.0,
                    help="--engine elastic --shed: per-request deadline, "
                         "seconds after arrival")
    args = ap.parse_args()
    spec = RolloutSpec.from_args(args)
    prompts = [f"{i}+{i+1}=" for i in range(args.batch)]
    if args.group:
        prompts = [p for p in prompts for _ in range(args.group)]
    if args.engine == "elastic":
        ladder = tuple(int(x) for x in args.ladder.split(","))
        res = serve_elastic(args.arch, prompts, max_new=args.max_new,
                            spec=spec, ladder=ladder, shed=args.shed,
                            deadline_s=args.deadline_s)
        e = res["elastic"]
        print(f"[elastic] served {len(res['texts'])}/{len(prompts)} "
              f"requests, {res['tokens']} tokens in {res['makespan_s']:.2f}s "
              f"({res['tok_per_s']:.1f} tok/s)")
        print(f"  resizes {len(e['resize_log'])} "
              + "".join(f"{a}->{b} " for _, a, b in e["resize_log"])
              + f"| capacity {e['capacity_seconds']:.2f} slot-s "
              f"(static {e['static_capacity_seconds']:.2f}, "
              f"ratio {e['capacity_seconds_ratio']:.2f})")
        print(f"  sheds {e['sheds']}, degrades {e['degrades']}, "
              f"classes {e['class_counts']}")
        for o, t in zip(res["outputs"], res["texts"]):
            print(f"  rid={o.rid} [{o.finish_reason}] -> {t!r}")
        return
    if args.engine == "continuous":
        res = serve_continuous(args.arch, prompts, max_new=args.max_new,
                               spec=spec)
        extra = (f", slot util {res['slot_utilization']:.0%}, "
                 f"{res['decode_steps']} decode steps")
        if args.prefix_share:
            extra += (f", {res['prefix_hits']} prefix hits "
                      f"({res['blocks_saved']} blocks saved)")
        if args.disagg:
            extra += (f", {res['transfers']} KV transfers "
                      f"({res['transfer_overhead_frac']:.1%} overhead)")
    else:
        res = serve_batch(args.arch, prompts, max_new=args.max_new)
        extra = ""
    print(f"[{args.engine}] served {len(prompts)} requests, {res['tokens']} "
          f"tokens in {res['wall_s']:.2f}s ({res['tok_per_s']:.1f} tok/s"
          f"{extra})")
    for p, t in zip(prompts, res["texts"]):
        print(f"  {p!r} -> {t!r}")


if __name__ == "__main__":
    _main()
