"""Batched serving driver: prefill + decode with a KV cache (single host).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --reduced \
        --batch 4 --prompt-len 16 --max-new 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import tokenizer as tok
from repro.models import build_model
from repro.rl import SamplerConfig, completions_to_text, generate


def serve_batch(arch: str, prompts_text: list[str], *, reduced: bool = True,
                max_new: int = 32, temperature: float = 0.8, seed: int = 0):
    model = build_model(arch, reduced=reduced)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    plen = max(len(tok.encode(t, bos=True)) for t in prompts_text)
    prompts = jnp.asarray(tok.pad_batch(
        [tok.encode(t, bos=True) for t in prompts_text], plen))
    fr = None
    if model.cfg.frontend == "vision":
        fr = jnp.zeros((prompts.shape[0], model.cfg.num_frontend_tokens,
                        model.cfg.d_model))
    elif model.cfg.frontend == "audio":
        fr = jnp.zeros((prompts.shape[0], model.cfg.max_source_len,
                        model.cfg.d_model))
    sampler = SamplerConfig(max_new_tokens=max_new, temperature=temperature)
    t0 = time.perf_counter()
    out = generate(model, params, prompts, key, sampler, frontend=fr)
    jax.block_until_ready(out["completions"])
    dt = time.perf_counter() - t0
    n_tok = int(out["mask"].sum())
    return {"texts": completions_to_text(out["completions"], out["mask"]),
            "wall_s": dt, "tokens": n_tok,
            "tok_per_s": n_tok / max(dt, 1e-9)}


def _main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    args = ap.parse_args()
    prompts = [f"{i}+{i+1}=" for i in range(args.batch)]
    res = serve_batch(args.arch, prompts, max_new=args.max_new)
    print(f"served {args.batch} requests, {res['tokens']} tokens in "
          f"{res['wall_s']:.2f}s ({res['tok_per_s']:.1f} tok/s)")
    for p, t in zip(prompts, res["texts"]):
        print(f"  {p!r} -> {t!r}")


if __name__ == "__main__":
    _main()
