"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input shape) on the production meshes, print
memory_analysis / cost_analysis, and extract roofline terms.

The device-count env var is set below BEFORE any jax import — jax locks the
device count on first init. Do not import this module from processes that
need a 1-device view (tests, benches); run it as __main__:

    python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all --mesh both
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import subprocess
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import analyze, memory_report, model_flops_for
from repro.models.model import Model
from repro.models.sharding import (RULE_PROFILES, ShardingRules,
                                   activation_sharding, logical_to_sharding)
from repro.rl.train_step import make_train_step
from repro.train.optimizer import AdamWConfig, adamw_init

RESULTS_DIR = "results/dryrun"


def _tree_shapes(tree):
    return jax.tree.map(lambda x: x.shape, tree)


def batch_sharding(mesh, rules, specs_dict):
    return {k: NamedSharding(mesh, rules.resolve(log, sds.shape, mesh))
            for k, (log, sds) in specs_dict.items()}


def lower_case(arch: str, shape_name: str, *, multi_pod: bool,
               rules: ShardingRules | None = None, microbatches: int = 8,
               remat: bool = True, rules_profile: str = "baseline"):
    """Returns (lowered, meta) for one (arch x shape x mesh) case."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": why}
    model = Model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if rules is None:
        rules = ShardingRules().with_overrides(
            **RULE_PROFILES.get(rules_profile, {}))

    params_abs = model.init_abstract()
    logical = model.logical_specs()
    param_sh = logical_to_sharding(logical, _tree_shapes(params_abs), mesh, rules)
    inputs = model.input_specs(shape)

    def in_sh(name, log):
        return NamedSharding(mesh, rules.resolve(log, inputs[name].shape, mesh))

    t0 = time.perf_counter()
    with mesh, activation_sharding(mesh, rules):
        if shape.kind == "train":
            opt_abs = jax.eval_shape(lambda p: adamw_init(p), params_abs)
            opt_sh = {"mu": param_sh, "nu": param_sh,
                      "step": NamedSharding(mesh, P())}
            state_abs = {"params": params_abs, "opt": opt_abs}
            state_sh = {"params": param_sh, "opt": opt_sh}
            mb = microbatches if shape.global_batch % microbatches == 0 else 1
            step_fn = make_train_step(model, AdamWConfig(),
                                      microbatches=mb, remat=remat)
            bsh = {"tokens": in_sh("tokens", ("batch", "seq")),
                   "labels": in_sh("labels", ("batch", "seq")),
                   "loss_mask": in_sh("loss_mask", ("batch", "seq")),
                   "advantages": in_sh("advantages", ("batch", "seq"))}
            if "frontend" in inputs:
                bsh["frontend"] = in_sh("frontend",
                                        ("batch", "frontend", None))
            lowered = jax.jit(
                step_fn, in_shardings=(state_sh, bsh),
                out_shardings=(state_sh, None)).lower(state_abs, inputs)
        elif shape.kind == "prefill":
            cache_abs = jax.eval_shape(
                lambda: model.init_cache(shape.global_batch, shape.seq_len))
            cache_sh = logical_to_sharding(model.cache_logical_specs(),
                                           _tree_shapes(cache_abs), mesh, rules)

            def prefill(params, tokens, cache, frontend=None):
                return model.prefill(params, tokens, cache, frontend=frontend)

            ish = [param_sh, in_sh("tokens", ("batch", "seq")), cache_sh]
            args = [params_abs, inputs["tokens"], cache_abs]
            if "frontend" in inputs:
                ish.append(in_sh("frontend", ("batch", "frontend", None)))
                args.append(inputs["frontend"])
            lowered = jax.jit(prefill, in_shardings=tuple(ish),
                              out_shardings=(None, cache_sh)).lower(*args)
        else:  # decode
            ring = shape.name == "long_500k" and bool(cfg.sliding_window)

            def serve_step(params, token, cache):
                return model.decode_step(params, token, cache, ring=ring)

            cache_abs = inputs["cache"]
            cache_sh = logical_to_sharding(model.cache_logical_specs(),
                                           _tree_shapes(cache_abs), mesh, rules)
            # donate the KV cache (standard for serving): lets XLA update it
            # in place instead of materializing a full modified copy per step
            donate = (2,) if os.environ.get("DRYRUN_DONATE", "0") != "0" else ()
            lowered = jax.jit(
                serve_step,
                in_shardings=(param_sh, in_sh("token", ("batch", None)),
                              cache_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=donate).lower(
                    params_abs, inputs["token"], cache_abs)
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "multi" if multi_pod else "single",
            "n_chips": mesh.size, "lower_s": time.perf_counter() - t0,
            "params_b": cfg.param_count() / 1e9,
            "active_params_b": cfg.active_param_count() / 1e9}
    return lowered, meta


def run_case(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = RESULTS_DIR, **kw) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}_{shape_name}_{'multi' if multi_pod else 'single'}"
    path = os.path.join(out_dir, tag + ".json")
    try:
        lowered, meta = lower_case(arch, shape_name, multi_pod=multi_pod, **kw)
        if lowered is None:
            rec = {"arch": arch, "shape": shape_name,
                   "mesh": "multi" if multi_pod else "single",
                   "status": "skipped", "why": meta["skipped"]}
        else:
            t0 = time.perf_counter()
            compiled = lowered.compile()
            meta["compile_s"] = time.perf_counter() - t0
            mem = memory_report(compiled)
            cfg = get_config(arch)
            shape = SHAPES[shape_name]
            terms = analyze(compiled, n_chips=meta["n_chips"],
                            model_flops=model_flops_for(cfg, shape))
            if os.environ.get("DRYRUN_SAVE_HLO"):
                with open(os.path.join(out_dir, tag + ".hlo"), "w") as f:
                    f.write(compiled.as_text())
            print(f"[{tag}] memory_analysis: {mem}")
            print(f"[{tag}] cost_analysis: flops={terms.hlo_flops:.3e} "
                  f"bytes={terms.hlo_bytes:.3e}")
            rec = {"status": "ok", **meta, "memory": mem,
                   "roofline": terms.to_dict()}
    except Exception as e:  # record failures for triage, then re-raise intent
        rec = {"arch": arch, "shape": shape_name,
               "mesh": "multi" if multi_pod else "single",
               "status": "error", "error": f"{type(e).__name__}: {e}",
               "trace": traceback.format_exc()[-4000:]}
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    print(f"[{tag}] -> {rec['status']}")
    return rec


def _main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--microbatches", type=int, default=8)
    args = ap.parse_args()

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    if args.all:
        # orchestrate via subprocesses: one compile per process (resumable)
        cases = [(a, s, m) for a in list_archs() for s in SHAPES for m in meshes]
        for a, s, m in cases:
            tag = f"{a}_{s}_{'multi' if m else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                ok = json.load(open(path)).get("status")
                print(f"[{tag}] cached ({ok})")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s,
                   "--mesh", "multi" if m else "single", "--out", args.out,
                   "--microbatches", str(args.microbatches)]
            print("::", " ".join(cmd), flush=True)
            try:
                subprocess.run(cmd, timeout=3300)
            except subprocess.TimeoutExpired:
                with open(path, "w") as f:
                    json.dump({"arch": a, "shape": s,
                               "mesh": "multi" if m else "single",
                               "status": "timeout"}, f)
        return
    run_case(args.arch, args.shape, multi_pod=(meshes[0]),
             out_dir=args.out, microbatches=args.microbatches)


if __name__ == "__main__":
    _main()
