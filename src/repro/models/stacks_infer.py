"""Prefill and single-token decode for every architecture family.

``serve_step`` (one token against a seq_len cache) is what the decode input
shapes lower; prefill builds the cache. Decode caches follow kvcache.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import kvcache
from repro.models import mamba2 as mb
from repro.models import moe as moe_lib
from repro.models import rwkv6 as rw
from repro.models.common import (default_mrope_positions, gelu_mlp_apply,
                                 mlp_apply)
from repro.models.stacks import (
    _embed_tokens, _layer_theta_window, _norm, _sinusoid,
    _unembed, encode_source)


def _write_seq(cache_arr, new, start):
    """Write (L,B,S_new,...) into (L,B,S_max,...) at seq offset ``start``."""
    zeros = (0,) * (cache_arr.ndim - 3)
    return jax.lax.dynamic_update_slice(cache_arr, new.astype(cache_arr.dtype),
                                        (0, 0, start, *zeros))


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------
def stack_prefill(p, cfg: ModelConfig, tokens, cache, *, frontend=None):
    """Full-sequence forward that fills ``cache``; returns (last_logits, cache)."""
    B, S = tokens.shape
    x = _embed_tokens(p, cfg, tokens, frontend)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    mrope_pos = (default_mrope_positions(B, S, cfg.num_frontend_tokens)
                 if cfg.mrope else None)
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        theta_l, window_l = _layer_theta_window(cfg)

        def body(x, xs):
            lp, theta, window = xs
            h = _norm(lp["ln1"], x, cfg)
            if cfg.attention == "mla":
                a = attn.mla_apply_full(lp["attn"], cfg, h, positions)
                ckv, krope = attn._mla_kv_compress(lp["attn"], cfg, h, positions)
                kv = (ckv, krope)
            else:
                q, k, v = attn.gqa_project_qkv(lp["attn"], cfg, h, positions,
                                               rope_theta=theta,
                                               mrope_positions=mrope_pos)
                out = attn.multi_head_attention(q, k, v, positions[0],
                                                positions[0], causal=True,
                                                window=window)
                a = jnp.einsum("bshe,hed->bsd", out, lp["attn"]["wo"])
                kv = (k, v)
            x = x + a
            h = _norm(lp["ln2"], x, cfg)
            if "moe" in lp:
                f, _ = moe_lib.moe_apply(lp["moe"], cfg, h)
            else:
                f = mlp_apply(lp["mlp"], h)
            return x + f, kv

        n_moe = cfg.num_layers - cfg.first_dense_layers if cfg.is_moe else 0
        n_dense = cfg.num_layers - n_moe
        kv_parts = []
        if cfg.is_moe and cfg.first_dense_layers:
            x, kv_d = jax.lax.scan(body, x, (p["dense_layers"],
                                             theta_l[:n_dense], window_l[:n_dense]))
            x, kv_m = jax.lax.scan(body, x, (p["layers"],
                                             theta_l[n_dense:], window_l[n_dense:]))
            kv = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), kv_d, kv_m)
        else:
            x, kv = jax.lax.scan(body, x, (p["layers"], theta_l, window_l))
        if cfg.attention == "mla":
            cache["ckv"] = _write_seq(cache["ckv"], kv[0], 0)
            cache["krope"] = _write_seq(cache["krope"], kv[1], 0)
        else:
            cache["k"] = _write_seq(cache["k"], kv[0], 0)
            cache["v"] = _write_seq(cache["v"], kv[1], 0)

    elif fam == "ssm":
        def body(x, lp):
            h = _norm(lp["ln1"], x, cfg)
            o, (ax, wkv) = rw.rwkv6_time_mix_full(lp["mix"], cfg, h)
            x = x + o
            h = _norm(lp["ln2"], x, cfg)
            o, fx = rw.rwkv6_channel_mix(lp["mix"], cfg, h)
            return x + o, (ax, fx, wkv)
        x, (ax, fx, wkv) = jax.lax.scan(body, x, p["layers"])
        cache["att_x"], cache["ffn_x"] = ax.astype(cache["att_x"].dtype), fx.astype(cache["ffn_x"].dtype)
        cache["wkv"] = wkv

    elif fam == "hybrid":
        shared = p["shared_attn"]
        every = cfg.hybrid_attn_every

        def shared_block(x):
            h = _norm(shared["ln1"], x, cfg)
            q, k, v = attn.gqa_project_qkv(shared["attn"], cfg, h, positions)
            out = attn.multi_head_attention(q, k, v, positions[0], positions[0])
            x = x + jnp.einsum("bshe,hed->bsd", out, shared["attn"]["wo"])
            h = _norm(shared["ln2"], x, cfg)
            return x + mlp_apply(shared["mlp"], h), k, v

        def body(x, xs):
            lp, idx = xs
            h = _norm(lp["norm"], x, cfg)
            m, (conv, ssm) = mb.mamba2_apply_full(lp["mamba"], cfg, h)
            x = x + m
            hd = cfg.resolved_head_dim
            dummy = jnp.zeros((B, S, cfg.num_kv_heads, hd), x.dtype)
            x, k, v = jax.lax.cond((idx + 1) % every == 0, shared_block,
                                   lambda y: (y, dummy, dummy), x)
            return x, (conv, ssm, k, v)

        x, (conv, ssm, k, v) = jax.lax.scan(
            body, x, (p["layers"], jnp.arange(cfg.num_layers)))
        cache["conv"], cache["ssm"] = conv.astype(cache["conv"].dtype), ssm
        k_occ, v_occ = k[every - 1::every], v[every - 1::every]
        Sa = cache["attn_k"].shape[2]
        if S > Sa:
            k_occ, v_occ = k_occ[:, :, -Sa:], v_occ[:, :, -Sa:]
        cache["attn_k"] = _write_seq(cache["attn_k"], k_occ, 0)
        cache["attn_v"] = _write_seq(cache["attn_v"], v_occ, 0)

    elif fam == "audio":
        enc = encode_source(p, cfg, frontend)
        # precompute cross K/V per decoder layer
        def cross_kv(cp):
            ek = jnp.einsum("bsd,dhe->bshe", enc, cp["attn"]["wk"])
            ev = jnp.einsum("bsd,dhe->bshe", enc, cp["attn"]["wv"])
            if cfg.qkv_bias:
                ek, ev = ek + cp["attn"]["bk"], ev + cp["attn"]["bv"]
            return ek, ev
        ck, cv = jax.lax.map(cross_kv, p["cross"])
        cache["cross_k"], cache["cross_v"] = ck.astype(cache["cross_k"].dtype), cv.astype(cache["cross_v"].dtype)
        x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)

        def body(x, xs):
            lp, cp, ekl, evl = xs
            h = _norm(lp["ln1"], x, cfg)
            q, k, v = attn.gqa_project_qkv(lp["attn"], cfg, h, positions,
                                           rope_theta=0.0)
            out = attn.multi_head_attention(q, k, v, positions[0], positions[0])
            x = x + jnp.einsum("bshe,hed->bsd", out, lp["attn"]["wo"])
            h = _norm(cp["ln"], x, cfg)
            x = x + attn.gqa_apply_cross(cp["attn"], cfg, h, ekl, evl)
            h = _norm(lp["ln2"], x, cfg)
            return x + gelu_mlp_apply(lp["mlp"], h), (k, v)

        x, (k, v) = jax.lax.scan(body, x, (p["layers"], p["cross"], ck, cv))
        cache["k"] = _write_seq(cache["k"], k, 0)
        cache["v"] = _write_seq(cache["v"], v, 0)
    else:
        raise ValueError(fam)

    cache["index"] = jnp.asarray(S, jnp.int32)
    logits = _unembed(p, cfg, x[:, -1:])
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Decode (one token)
# ---------------------------------------------------------------------------
def stack_decode_step(p, cfg: ModelConfig, token, cache, *, ring: bool = False):
    """token: (B,1) int32. Returns (logits (B,V), cache')."""
    index = cache["index"]
    x = jnp.take(p["embed"], token, axis=0)
    if cfg.family == "dense" and cfg.local_global_ratio:
        x = x * (cfg.d_model ** 0.5)
    fam = cfg.family
    B = token.shape[0]
    mrope_pos = None
    if cfg.mrope:  # single-position ids consistent with default_mrope_positions
        F = cfg.num_frontend_tokens
        side = max(int(F ** 0.5), 1)
        is_img = index < F
        h = jnp.where(is_img, index // side, index)
        w = jnp.where(is_img, index % side, index)
        tt = jnp.where(is_img, 0, index - F + 1)
        mrope_pos = jnp.broadcast_to(
            jnp.stack([tt, h, w])[:, None, None], (3, B, 1)).astype(jnp.int32)

    if fam in ("dense", "vlm", "moe"):
        theta_l, window_l = _layer_theta_window(cfg, ring=ring)
        if cfg.attention == "mla":
            def body(x, xs):
                lp, ckv_l, krope_l = xs
                h = _norm(lp["ln1"], x, cfg)
                a, ckv_l, krope_l = attn.mla_decode_step(
                    lp["attn"], cfg, h, ckv_l, krope_l, index)
                x = x + a
                h = _norm(lp["ln2"], x, cfg)
                if "moe" in lp:
                    f, _ = moe_lib.moe_apply(lp["moe"], cfg, h)
                else:
                    f = mlp_apply(lp["mlp"], h)
                return x + f, (ckv_l, krope_l)
            kv_names = ("ckv", "krope")
        else:
            def body(x, xs):
                lp, k_l, v_l, theta, window = xs
                h = _norm(lp["ln1"], x, cfg)
                a, k_l, v_l = attn.gqa_decode_step(
                    lp["attn"], cfg, h, k_l, v_l, index, window=window,
                    rope_theta=theta, mrope_positions=mrope_pos, ring=ring)
                x = x + a
                h = _norm(lp["ln2"], x, cfg)
                if "moe" in lp:
                    f, _ = moe_lib.moe_apply(lp["moe"], cfg, h)
                else:
                    f = mlp_apply(lp["mlp"], h)
                return x + f, (k_l, v_l)
            kv_names = ("k", "v")

        n_moe = cfg.num_layers - cfg.first_dense_layers if cfg.is_moe else 0
        n_dense = cfg.num_layers - n_moe
        c0, c1 = (cache[kv_names[0]], cache[kv_names[1]])
        import os as _os
        if _os.environ.get("DRYRUN_UNROLL_DECODE") and not cfg.is_moe \
                and cfg.attention != "mla":
            # §Perf C: unrolled layer loop — each layer's cache update is an
            # independent dynamic-update-slice into the (donated) cache, so
            # XLA updates in place instead of rewriting the scan-carried
            # full stack every iteration.
            theta_l2, window_l2 = theta_l, window_l
            nc0, nc1 = c0, c1
            for li in range(cfg.num_layers):
                lp = jax.tree.map(lambda a: a[li], p["layers"])
                k_l = jax.lax.dynamic_index_in_dim(c0, li, 0, keepdims=False)
                v_l = jax.lax.dynamic_index_in_dim(c1, li, 0, keepdims=False)
                h = _norm(lp["ln1"], x, cfg)
                a, k_l, v_l = attn.gqa_decode_step(
                    lp["attn"], cfg, h, k_l, v_l, index,
                    window=window_l2[li], rope_theta=theta_l2[li],
                    mrope_positions=mrope_pos, ring=ring)
                x = x + a
                h = _norm(lp["ln2"], x, cfg)
                x = x + mlp_apply(lp["mlp"], h)
                nc0 = nc0.at[li].set(k_l.astype(nc0.dtype))
                nc1 = nc1.at[li].set(v_l.astype(nc1.dtype))
            cache[kv_names[0]], cache[kv_names[1]] = nc0, nc1
            cache["index"] = index + 1
            logits = _unembed(p, cfg, x)
            return logits[:, 0], cache
        if cfg.is_moe and cfg.first_dense_layers:
            if cfg.attention == "mla":
                xs_d = (p["dense_layers"], c0[:n_dense], c1[:n_dense])
                xs_m = (p["layers"], c0[n_dense:], c1[n_dense:])
            else:
                xs_d = (p["dense_layers"], c0[:n_dense], c1[:n_dense],
                        theta_l[:n_dense], window_l[:n_dense])
                xs_m = (p["layers"], c0[n_dense:], c1[n_dense:],
                        theta_l[n_dense:], window_l[n_dense:])
            x, kv_d = jax.lax.scan(body, x, xs_d)
            x, kv_m = jax.lax.scan(body, x, xs_m)
            kv = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), kv_d, kv_m)
        else:
            if cfg.attention == "mla":
                xs = (p["layers"], c0, c1)
            else:
                xs = (p["layers"], c0, c1, theta_l, window_l)
            x, kv = jax.lax.scan(body, x, xs)
        cache[kv_names[0]], cache[kv_names[1]] = kv

    elif fam == "ssm":
        def body(x, xs):
            lp, ax, fx, wkv = xs
            h = _norm(lp["ln1"], x, cfg)
            o, ax, wkv = rw.rwkv6_time_mix_step(lp["mix"], cfg, h, ax, wkv)
            x = x + o
            h = _norm(lp["ln2"], x, cfg)
            o, fx = rw.rwkv6_channel_mix(lp["mix"], cfg, h, fx)
            return x + o, (ax, fx, wkv)
        x, (ax, fx, wkv) = jax.lax.scan(
            body, x, (p["layers"], cache["att_x"], cache["ffn_x"], cache["wkv"]))
        cache["att_x"], cache["ffn_x"], cache["wkv"] = (
            ax.astype(cache["att_x"].dtype), fx.astype(cache["ffn_x"].dtype), wkv)

    elif fam == "hybrid":
        shared = p["shared_attn"]
        every = cfg.hybrid_attn_every
        Sa = cache["attn_k"].shape[2]
        attn_ring = ring
        window = jnp.asarray(Sa, jnp.int32) if attn_ring else None

        def body(carry, xs):
            x, ak, av = carry
            lp, idx = xs
            h = _norm(lp["norm"], x, cfg)
            m, conv, ssm = mb.mamba2_decode_step(
                lp["mamba"], cfg, h, lp["_conv"], lp["_ssm"])
            x = x + m
            occ = idx // every

            def do_attn(op):
                x, ak, av = op
                k_l = jax.lax.dynamic_index_in_dim(ak, occ, 0, keepdims=False)
                v_l = jax.lax.dynamic_index_in_dim(av, occ, 0, keepdims=False)
                h = _norm(shared["ln1"], x, cfg)
                a, k_l, v_l = attn.gqa_decode_step(
                    shared["attn"], cfg, h, k_l, v_l, index,
                    window=window, ring=attn_ring)
                x = x + a
                h = _norm(shared["ln2"], x, cfg)
                x = x + mlp_apply(shared["mlp"], h)
                ak = jax.lax.dynamic_update_index_in_dim(ak, k_l, occ, 0)
                av = jax.lax.dynamic_update_index_in_dim(av, v_l, occ, 0)
                return x, ak, av

            x, ak, av = jax.lax.cond((idx + 1) % every == 0, do_attn,
                                     lambda op: op, (x, ak, av))
            return (x, ak, av), (conv, ssm)

        layers_xs = dict(p["layers"])
        layers_xs["_conv"], layers_xs["_ssm"] = cache["conv"], cache["ssm"]
        (x, ak, av), (conv, ssm) = jax.lax.scan(
            body, (x, cache["attn_k"], cache["attn_v"]),
            (layers_xs, jnp.arange(cfg.num_layers)))
        cache["conv"], cache["ssm"] = conv.astype(cache["conv"].dtype), ssm
        cache["attn_k"], cache["attn_v"] = ak, av

    elif fam == "audio":
        x = x + _sinusoid(1, cfg.d_model, offset=index).astype(x.dtype)

        def body(x, xs):
            lp, cp, k_l, v_l, ck_l, cv_l = xs
            h = _norm(lp["ln1"], x, cfg)
            a, k_l, v_l = attn.gqa_decode_step(lp["attn"], cfg, h, k_l, v_l,
                                               index, rope_theta=0.0)
            x = x + a
            h = _norm(cp["ln"], x, cfg)
            x = x + attn.gqa_apply_cross(cp["attn"], cfg, h, ck_l, cv_l)
            h = _norm(lp["ln2"], x, cfg)
            return x + gelu_mlp_apply(lp["mlp"], h), (k_l, v_l)

        x, (k, v) = jax.lax.scan(
            body, x, (p["layers"], p["cross"], cache["k"], cache["v"],
                      cache["cross_k"], cache["cross_v"]))
        cache["k"], cache["v"] = k, v
    else:
        raise ValueError(fam)

    cache["index"] = index + 1
    logits = _unembed(p, cfg, x)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# Decode (one token, whole slot pool, Pallas attention)
# ---------------------------------------------------------------------------
def kernel_supported(cfg: ModelConfig) -> bool:
    """Whether :func:`stack_kernel_decode_step` can serve this config.

    Only GQA transformer stacks qualify: SSM/recurrent families carry no
    sequence-shaped KV for the decode kernel to page, and MLA decodes in
    the compressed-KV space (a different kernel entirely)."""
    return cfg.family in ("dense", "vlm", "moe") and cfg.attention != "mla"


def stack_kernel_decode_step(p, cfg: ModelConfig, token, cache, *,
                             tables=None, interpret: bool = True):
    """Batched one-token decode through the Pallas decode-attention kernels.

    The engine-layout counterpart of :func:`stack_decode_step`: instead of
    vmapping a batch=1 model step over slots, one call consumes the whole
    slot pool with a per-slot ``index`` vector and runs
    ``kernels.decode_attention`` (contiguous slot stripes, ``tables=None``)
    or ``kernels.paged_decode_attention`` (block-table pools) per layer.
    In the paged case the block table is scalar-prefetched into the kernel,
    so no gathered contiguous view of the pool is ever materialized.

    token: ``(N, 1)`` int32.  cache: the serving-cache layout —

    * contiguous: ``k``/``v`` ``(L, N, S, Hkv, hd)`` slot stripes,
      ``index`` ``(N,)``;
    * paged (``tables`` ``(N, MB)`` int32): ``k``/``v`` pools
      ``(L, NB+1, bs, Hkv, hd)`` (block 0 = null), optionally int8 with
      per-position ``k_scale``/``v_scale`` pools ``(L, NB+1, bs)``
      (quantize-on-write, dequantized inside the kernel's block loop).

    Dead slots (table rows all 0 / ``index`` past the stripe) write into
    the null block or fall off the stripe — don't-care positions attention
    masks out, same as the vmapped jnp path.  Returns
    ``(logits (N, V) f32, cache')``.
    """
    if not kernel_supported(cfg):
        raise ValueError(
            f"kernel decode step supports dense/vlm/moe GQA stacks only, "
            f"not family={cfg.family!r} attention={cfg.attention!r}")
    from repro.kernels.decode_attention import (decode_attention,
                                                paged_decode_attention)

    index = cache["index"]                              # (N,)
    N = token.shape[0]
    rows = jnp.arange(N)
    x = jnp.take(p["embed"], token, axis=0)             # (N, 1, d)
    if cfg.family == "dense" and cfg.local_global_ratio:
        x = x * (cfg.d_model ** 0.5)
    pos = index[:, None]                                # (N, 1) rope position
    mrope_pos = None
    if cfg.mrope:   # per-slot single-position ids (cf. stack_decode_step)
        F = cfg.num_frontend_tokens
        side = max(int(F ** 0.5), 1)
        is_img = index < F
        h = jnp.where(is_img, index // side, index)
        w = jnp.where(is_img, index % side, index)
        tt = jnp.where(is_img, 0, index - F + 1)
        mrope_pos = jnp.stack([tt, h, w])[:, :, None].astype(jnp.int32)

    quant = "k" + kvcache.SCALE_SUFFIX in cache
    if tables is not None:
        bs = cache["k"].shape[2]
        MB = tables.shape[1]
        blk = jnp.minimum(index // bs, MB - 1)
        pid = tables[rows, blk]            # 0 (null block) when dead/overrun
        off = index % bs

    def body(x, xs):
        lp, k_l, v_l, ks_l, vs_l, theta, window = xs
        h = _norm(lp["ln1"], x, cfg)
        q, k_new, v_new = attn.gqa_project_qkv(lp["attn"], cfg, h, pos,
                                               rope_theta=theta,
                                               mrope_positions=mrope_pos)
        kr, vr = k_new[:, 0], v_new[:, 0]               # (N, Hkv, hd)
        if tables is None:
            # out-of-stripe writes (dead slots decoding past max_len) drop
            k_l = k_l.at[rows, index].set(kr.astype(k_l.dtype))
            v_l = v_l.at[rows, index].set(vr.astype(v_l.dtype))
            o = decode_attention(q[:, 0], k_l, v_l, index + 1,
                                 window=window, interpret=interpret)
        else:
            if quant:
                kq, ks = kvcache.quantize_kv(kr, 1)
                vq, vs = kvcache.quantize_kv(vr, 1)
                k_l = k_l.at[pid, off].set(kq)
                v_l = v_l.at[pid, off].set(vq)
                ks_l = ks_l.at[pid, off].set(ks)
                vs_l = vs_l.at[pid, off].set(vs)
            else:
                k_l = k_l.at[pid, off].set(kr.astype(k_l.dtype))
                v_l = v_l.at[pid, off].set(vr.astype(v_l.dtype))
            o = paged_decode_attention(q[:, 0], k_l, v_l, tables, index + 1,
                                       window=window, k_scale=ks_l,
                                       v_scale=vs_l, interpret=interpret)
        a = jnp.einsum("bshe,hed->bsd", o[:, None], lp["attn"]["wo"])
        x = x + a.astype(x.dtype)
        h = _norm(lp["ln2"], x, cfg)
        if "moe" in lp:
            f, _ = moe_lib.moe_apply(lp["moe"], cfg, h)
        else:
            f = mlp_apply(lp["mlp"], h)
        return x + f, (k_l, v_l, ks_l, vs_l)

    theta_l, window_l = _layer_theta_window(cfg)
    c0, c1 = cache["k"], cache["v"]
    s0 = cache.get("k" + kvcache.SCALE_SUFFIX)
    s1 = cache.get("v" + kvcache.SCALE_SUFFIX)

    def _sl(t, lo, hi):
        return None if t is None else t[lo:hi]

    n_moe = cfg.num_layers - cfg.first_dense_layers if cfg.is_moe else 0
    n_dense = cfg.num_layers - n_moe
    if cfg.is_moe and cfg.first_dense_layers:
        xs_d = (p["dense_layers"], c0[:n_dense], c1[:n_dense],
                _sl(s0, 0, n_dense), _sl(s1, 0, n_dense),
                theta_l[:n_dense], window_l[:n_dense])
        xs_m = (p["layers"], c0[n_dense:], c1[n_dense:],
                _sl(s0, n_dense, cfg.num_layers),
                _sl(s1, n_dense, cfg.num_layers),
                theta_l[n_dense:], window_l[n_dense:])
        x, kv_d = jax.lax.scan(body, x, xs_d)
        x, kv_m = jax.lax.scan(body, x, xs_m)
        kv = jax.tree.map(lambda a, b: jnp.concatenate([a, b], 0), kv_d, kv_m)
    else:
        xs = (p["layers"], c0, c1, s0, s1, theta_l, window_l)
        x, kv = jax.lax.scan(body, x, xs)
    cache["k"], cache["v"] = kv[0], kv[1]
    if quant:
        cache["k" + kvcache.SCALE_SUFFIX] = kv[2]
        cache["v" + kvcache.SCALE_SUFFIX] = kv[3]
    cache["index"] = index + 1
    logits = _unembed(p, cfg, x)
    return logits[:, 0], cache
