"""Model facade: one object per architecture exposing init / forward /
prefill / decode plus abstract ``input_specs`` for the multi-pod dry-run."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, get_config
from repro.configs.shapes import InputShape
from repro.models import kvcache
from repro.models.stacks import stack_forward, stack_init, stack_specs
from repro.models.stacks_infer import (kernel_supported, stack_decode_step,
                                       stack_kernel_decode_step,
                                       stack_prefill)


@dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---- params ------------------------------------------------------------
    def init(self, key) -> dict:
        return stack_init(key, self.cfg)

    def init_abstract(self) -> dict:
        return jax.eval_shape(lambda: stack_init(jax.random.PRNGKey(0), self.cfg))

    def logical_specs(self) -> dict:
        return stack_specs(self.cfg)

    # ---- compute -----------------------------------------------------------
    def forward(self, params, tokens, *, frontend=None, remat: bool = False):
        return stack_forward(params, self.cfg, tokens, frontend=frontend,
                             remat=remat)

    def init_cache(self, batch: int, max_len: int, *, ring: bool = False):
        return kvcache.init_cache(self.cfg, batch, max_len, ring=ring)

    def init_paged_cache(self, num_slots: int, max_len: int, *,
                         block_size: int, num_blocks: int,
                         kv_dtype: str | None = None) -> dict:
        return kvcache.init_paged_cache(self.cfg, num_slots, max_len,
                                        block_size=block_size,
                                        num_blocks=num_blocks,
                                        kv_dtype=kv_dtype)

    def paged_cache_names(self) -> tuple[str, ...]:
        return kvcache.paged_names(self.cfg)

    def scale_cache_names(self) -> tuple[str, ...]:
        return kvcache.scale_names(self.cfg)

    def cache_logical_specs(self) -> dict:
        return kvcache.cache_specs(self.cfg)

    def prefill(self, params, tokens, cache, *, frontend=None):
        return stack_prefill(params, self.cfg, tokens, cache, frontend=frontend)

    def decode_step(self, params, token, cache, *, ring: bool = False):
        return stack_decode_step(params, self.cfg, token, cache, ring=ring)

    def kernel_supported(self) -> bool:
        """Whether the Pallas batched decode step serves this architecture."""
        return kernel_supported(self.cfg)

    def kernel_decode_step(self, params, token, cache, *, tables=None,
                           interpret: bool = True):
        """Batched one-token decode over a whole slot pool through the
        Pallas decode-attention kernels (``stacks_infer.
        stack_kernel_decode_step``); ``tables`` selects the paged layout."""
        return stack_kernel_decode_step(params, self.cfg, token, cache,
                                        tables=tables, interpret=interpret)

    # ---- abstract inputs for lowering ---------------------------------------
    def input_specs(self, shape: InputShape) -> dict:
        """ShapeDtypeStruct stand-ins for every model input of this shape.

        train  -> {tokens, labels, loss_mask, advantages [, frontend/source]}
        prefill-> {tokens [, frontend/source]}
        decode -> {token, cache}
        """
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        f32 = jnp.float32
        sds = jax.ShapeDtypeStruct
        front = {}
        if cfg.frontend == "vision":
            front["frontend"] = sds((B, cfg.num_frontend_tokens, cfg.d_model), f32)
        elif cfg.frontend == "audio":
            front["frontend"] = sds((B, cfg.max_source_len, cfg.d_model), f32)

        if shape.kind == "train":
            return {
                "tokens": sds((B, S), i32),
                "labels": sds((B, S), i32),
                "loss_mask": sds((B, S), f32),
                "advantages": sds((B, S), f32),
            } | front
        if shape.kind == "prefill":
            return {"tokens": sds((B, S), i32)} | front
        if shape.kind == "decode":
            ring = shape.name == "long_500k" and bool(cfg.sliding_window)
            cache = jax.eval_shape(
                lambda: self.init_cache(B, S, ring=ring))
            return {"token": sds((B, 1), i32), "cache": cache}
        raise ValueError(shape.kind)


def build_model(arch: str | ModelConfig, *, reduced: bool = False) -> Model:
    cfg = get_config(arch) if isinstance(arch, str) else arch
    if reduced:
        import dataclasses
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    return Model(cfg)
