"""Decode caches per architecture family (plain dict pytrees + logical specs).

Cache sequence dims carry the ``cache_seq`` logical axis → sharded over the
``model`` mesh axis (context parallelism for decode); batch over ``data``.

Two serving layouts are built from the same specs:
  * contiguous (:func:`init_cache`) — one ``max_len`` stripe per batch row;
  * paged (:func:`init_paged_cache`) — every leaf whose spec carries the
    ``cache_seq`` axis is re-laid-out as a shared pool of fixed-size blocks
    ``(layers, num_blocks + 1, block_size, ...)`` (block 0 is the null
    block), while seq-less leaves (SSM/conv state, cross-attention KV) stay
    per-slot.  A slot's logical sequence is then the concatenation of the
    blocks its block table names — see ``repro.serve.slots``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _attn_cache(cfg: ModelConfig, L: int, batch: int, S: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (L, batch, S, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _attn_cache_spec():
    ax = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               ring: bool = False) -> dict:
    """Build a zeroed decode cache. ``ring=True`` allocates sliding-window
    ring buffers (long_500k) instead of full-length context.

    DRYRUN_CACHE_F32=1 stores the cache in fp32 — a §Perf experiment: the
    CPU backend emulates bf16 dots by converting operands, and XLA hoists
    those converts into the decode loop carry, maintaining dual f32+bf16
    cache copies (full rewrite per layer). fp32 storage removes the dual
    copy on this backend; on TPU (native bf16 MXU) it is unnecessary.
    """
    import os
    dt = (jnp.float32 if os.environ.get("DRYRUN_CACHE_F32")
          else jnp.dtype(cfg.dtype))
    idx = {"index": jnp.zeros((), jnp.int32)}
    S = min(max_len, cfg.sliding_window) if (ring and cfg.sliding_window) else max_len

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.attention == "mla":
            c = {
                "ckv": jnp.zeros((cfg.num_layers, batch, S, cfg.kv_lora_rank), dt),
                "krope": jnp.zeros((cfg.num_layers, batch, S, cfg.qk_rope_head_dim), dt),
            }
        else:
            c = _attn_cache(cfg, cfg.num_layers, batch, S, dt)
        return c | idx

    if cfg.family == "ssm":     # rwkv6
        H, hd = cfg.num_heads, cfg.resolved_head_dim
        return {
            "att_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            "ffn_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            "wkv": jnp.zeros((cfg.num_layers, batch, H, hd, hd), jnp.float32),
        } | idx

    if cfg.family == "hybrid":  # zamba2
        inner = cfg.ssm_expand * cfg.d_model
        nh = inner // cfg.ssm_head_dim
        conv_dim = inner + 2 * cfg.ssm_state_dim
        n_attn = (cfg.num_layers + cfg.hybrid_attn_every - 1) // cfg.hybrid_attn_every
        Sa = min(S, 4096) if ring else S   # shared-attn window at 500k
        attn = _attn_cache(cfg, n_attn, batch, Sa, dt)
        return {
            "conv": jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_dim), dt),
            "ssm": jnp.zeros((cfg.num_layers, batch, nh, cfg.ssm_state_dim,
                              cfg.ssm_head_dim), jnp.float32),
            "attn_k": attn["k"], "attn_v": attn["v"],
        } | idx

    if cfg.family == "audio":   # whisper enc-dec
        c = _attn_cache(cfg, cfg.num_layers, batch, S, dt)
        hd = cfg.resolved_head_dim
        cross = (cfg.num_layers, batch, cfg.max_source_len, cfg.num_kv_heads, hd)
        return c | {
            "cross_k": jnp.zeros(cross, dt),
            "cross_v": jnp.zeros(cross, dt),
        } | idx

    raise ValueError(f"no cache for family {cfg.family!r}")


def paged_names(cfg: ModelConfig) -> tuple[str, ...]:
    """Cache leaves that get block-paged: those with a ``cache_seq`` axis.

    Families without such leaves (rwkv6: pure recurrent state) page nothing
    — their paged layout degenerates to the contiguous one and a request
    needs zero KV blocks.
    """
    return tuple(sorted(k for k, ax in cache_specs(cfg).items()
                        if ax and "cache_seq" in ax))


SCALE_SUFFIX = "_scale"


def scale_names(cfg: ModelConfig) -> tuple[str, ...]:
    """Companion per-position scale leaves an int8 paged cache carries,
    one per paged leaf (``k`` -> ``k_scale``, ...)."""
    return tuple(n + SCALE_SUFFIX for n in paged_names(cfg))


def quantize_kv(x, pos_ndim: int):
    """Symmetric per-token-position int8 quantization.

    ``x``: float array whose leading ``pos_ndim`` axes identify a token
    position (``(L, NB, bs)`` for a whole pool, ``(bs,)`` for one block's
    positions); the feature axes beyond that share one scale, so a single
    position can be requantized without touching its neighbours — exactly
    what incremental decode writes need.  Returns ``(int8 values, float32
    scales of shape x.shape[:pos_ndim])``; an all-zero position gets scale
    1.0 so dequantization stays the identity on zeros.
    """
    xf = x.astype(jnp.float32)
    red = tuple(range(pos_ndim, x.ndim))
    amax = jnp.max(jnp.abs(xf), axis=red) if red else jnp.abs(xf)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.round(xf / scale.reshape(scale.shape + (1,) * len(red)))
    return jnp.clip(q, -127, 127).astype(jnp.int8), scale


def dequantize_kv(q, scale, dtype=jnp.float32):
    """Inverse of :func:`quantize_kv`: broadcast each position's scale over
    its feature axes."""
    s = scale.reshape(scale.shape + (1,) * (q.ndim - scale.ndim))
    return (q.astype(jnp.float32) * s).astype(dtype)


def init_paged_cache(cfg: ModelConfig, num_slots: int, max_len: int, *,
                     block_size: int, num_blocks: int,
                     kv_dtype: str | None = None) -> dict:
    """Zeroed paged decode cache: ``cache_seq`` leaves become block pools
    ``(L, num_blocks + 1, block_size, ...)`` shared across slots (entry 0 is
    the null block), everything else keeps the per-slot layout. ``index``
    is widened to a per-slot vector, as the serving engine expects.

    ``kv_dtype="int8"`` stores each paged pool as int8 plus a per-position
    ``<name>_scale`` pool ``(L, num_blocks + 1, block_size)`` float32 —
    roughly half the KV bytes of a bf16 pool at a per-position accuracy
    budget of ~1/254 relative error."""
    if kv_dtype not in (None, "auto", "int8"):
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}")
    int8 = kv_dtype == "int8"
    shapes = jax.eval_shape(lambda: init_cache(cfg, num_slots, max_len))
    paged = set(paged_names(cfg))
    if int8 and not paged:
        raise ValueError(
            f"kv_dtype='int8' needs paged KV leaves; family {cfg.family!r} "
            "has none to quantize")
    out = {}
    for name, sd in shapes.items():
        if name == "index":
            out[name] = jnp.zeros((num_slots,), jnp.int32)
        elif name in paged:
            # (L, B, S, *rest) -> (L, num_blocks + 1, block_size, *rest)
            pool = (sd.shape[0], num_blocks + 1, block_size) + sd.shape[3:]
            out[name] = jnp.zeros(pool, jnp.int8 if int8 else sd.dtype)
            if int8:
                out[name + SCALE_SUFFIX] = jnp.ones(pool[:3], jnp.float32)
        else:
            out[name] = jnp.zeros(sd.shape, sd.dtype)
    return out


def cache_specs(cfg: ModelConfig) -> dict:
    idx = {"index": ()}
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.attention == "mla":
            return {"ckv": ("layers", "batch", "cache_seq", "kv_lora"),
                    "krope": ("layers", "batch", "cache_seq", None)} | idx
        return _attn_cache_spec() | idx
    if cfg.family == "ssm":
        return {"att_x": ("layers", "batch", "embed_act"),
                "ffn_x": ("layers", "batch", "embed_act"),
                "wkv": ("layers", "batch", "heads_act", None, None)} | idx
    if cfg.family == "hybrid":
        return {"conv": ("layers", "batch", None, "ssm_inner"),
                "ssm": ("layers", "batch", "heads_act", None, None),
                "attn_k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                "attn_v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim")} | idx
    if cfg.family == "audio":
        return _attn_cache_spec() | {
            "cross_k": ("layers", "batch", "source", "kv_heads", "head_dim"),
            "cross_v": ("layers", "batch", "source", "kv_heads", "head_dim")} | idx
    raise ValueError(cfg.family)
