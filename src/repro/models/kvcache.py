"""Decode caches per architecture family (plain dict pytrees + logical specs).

Cache sequence dims carry the ``cache_seq`` logical axis → sharded over the
``model`` mesh axis (context parallelism for decode); batch over ``data``.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig


def _attn_cache(cfg: ModelConfig, L: int, batch: int, S: int, dtype):
    hd = cfg.resolved_head_dim
    shape = (L, batch, S, cfg.num_kv_heads, hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _attn_cache_spec():
    ax = ("layers", "batch", "cache_seq", "kv_heads", "head_dim")
    return {"k": ax, "v": ax}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, *,
               ring: bool = False) -> dict:
    """Build a zeroed decode cache. ``ring=True`` allocates sliding-window
    ring buffers (long_500k) instead of full-length context.

    DRYRUN_CACHE_F32=1 stores the cache in fp32 — a §Perf experiment: the
    CPU backend emulates bf16 dots by converting operands, and XLA hoists
    those converts into the decode loop carry, maintaining dual f32+bf16
    cache copies (full rewrite per layer). fp32 storage removes the dual
    copy on this backend; on TPU (native bf16 MXU) it is unnecessary.
    """
    import os
    dt = (jnp.float32 if os.environ.get("DRYRUN_CACHE_F32")
          else jnp.dtype(cfg.dtype))
    idx = {"index": jnp.zeros((), jnp.int32)}
    S = min(max_len, cfg.sliding_window) if (ring and cfg.sliding_window) else max_len

    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.attention == "mla":
            c = {
                "ckv": jnp.zeros((cfg.num_layers, batch, S, cfg.kv_lora_rank), dt),
                "krope": jnp.zeros((cfg.num_layers, batch, S, cfg.qk_rope_head_dim), dt),
            }
        else:
            c = _attn_cache(cfg, cfg.num_layers, batch, S, dt)
        return c | idx

    if cfg.family == "ssm":     # rwkv6
        H, hd = cfg.num_heads, cfg.resolved_head_dim
        return {
            "att_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            "ffn_x": jnp.zeros((cfg.num_layers, batch, cfg.d_model), dt),
            "wkv": jnp.zeros((cfg.num_layers, batch, H, hd, hd), jnp.float32),
        } | idx

    if cfg.family == "hybrid":  # zamba2
        inner = cfg.ssm_expand * cfg.d_model
        nh = inner // cfg.ssm_head_dim
        conv_dim = inner + 2 * cfg.ssm_state_dim
        n_attn = (cfg.num_layers + cfg.hybrid_attn_every - 1) // cfg.hybrid_attn_every
        Sa = min(S, 4096) if ring else S   # shared-attn window at 500k
        attn = _attn_cache(cfg, n_attn, batch, Sa, dt)
        return {
            "conv": jnp.zeros((cfg.num_layers, batch, cfg.ssm_conv_width - 1, conv_dim), dt),
            "ssm": jnp.zeros((cfg.num_layers, batch, nh, cfg.ssm_state_dim,
                              cfg.ssm_head_dim), jnp.float32),
            "attn_k": attn["k"], "attn_v": attn["v"],
        } | idx

    if cfg.family == "audio":   # whisper enc-dec
        c = _attn_cache(cfg, cfg.num_layers, batch, S, dt)
        hd = cfg.resolved_head_dim
        cross = (cfg.num_layers, batch, cfg.max_source_len, cfg.num_kv_heads, hd)
        return c | {
            "cross_k": jnp.zeros(cross, dt),
            "cross_v": jnp.zeros(cross, dt),
        } | idx

    raise ValueError(f"no cache for family {cfg.family!r}")


def cache_specs(cfg: ModelConfig) -> dict:
    idx = {"index": ()}
    if cfg.family in ("dense", "vlm", "moe"):
        if cfg.attention == "mla":
            return {"ckv": ("layers", "batch", "cache_seq", "kv_lora"),
                    "krope": ("layers", "batch", "cache_seq", None)} | idx
        return _attn_cache_spec() | idx
    if cfg.family == "ssm":
        return {"att_x": ("layers", "batch", "embed_act"),
                "ffn_x": ("layers", "batch", "embed_act"),
                "wkv": ("layers", "batch", "heads_act", None, None)} | idx
    if cfg.family == "hybrid":
        return {"conv": ("layers", "batch", None, "ssm_inner"),
                "ssm": ("layers", "batch", "heads_act", None, None),
                "attn_k": ("layers", "batch", "cache_seq", "kv_heads", "head_dim"),
                "attn_v": ("layers", "batch", "cache_seq", "kv_heads", "head_dim")} | idx
    if cfg.family == "audio":
        return _attn_cache_spec() | {
            "cross_k": ("layers", "batch", "source", "kv_heads", "head_dim"),
            "cross_v": ("layers", "batch", "source", "kv_heads", "head_dim")} | idx
    raise ValueError(cfg.family)
