"""Mixture-of-Experts layer: top-k routing with capacity-bounded, sort-based
dispatch (no (T, E, C) one-hot — scales to 160-expert DeepSeek-V2).

Expert weights are stacked (E, d, f) and sharded over the ``model`` mesh axis
(expert parallelism); dispatch/combine become all-to-alls under GSPMD.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, mlp_apply, mlp_init, mlp_specs
from repro.models.sharding import constrain


def moe_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, E, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": dense_init(ks[0], d, (E,), jnp.float32),
        "wi_gate": jax.vmap(lambda k: dense_init(k, d, (f,), dt))(
            jax.random.split(ks[1], E)),
        "wi_up": jax.vmap(lambda k: dense_init(k, d, (f,), dt))(
            jax.random.split(ks[2], E)),
        "wo": jax.vmap(lambda k: dense_init(k, f, (d,), dt))(
            jax.random.split(ks[3], E)),
    }
    if cfg.num_shared_experts:
        import dataclasses
        shared_cfg = dataclasses.replace(
            cfg, d_ff=cfg.num_shared_experts * cfg.moe_d_ff)
        p["shared"] = mlp_init(ks[4], shared_cfg)
    return p


def moe_specs(cfg: ModelConfig) -> dict:
    # expert weights: experts over model, embed FSDP over data. (§Perf A2
    # tried replicating the embed dim to kill the per-layer partial-sum
    # all-reduce of expert hiddens — collective only dropped 8% while
    # per-chip MoE FLOPs grew 2.6x because the capacity dim was unsharded:
    # net regression, reverted. The right next lever is sharding the
    # capacity dim over data inside a shard_map dispatch.)
    s = {
        "router": ("embed", None),
        "wi_gate": ("experts", "embed", "ffn"),
        "wi_up": ("experts", "embed", "ffn"),
        "wo": ("experts", "ffn", "embed"),
    }
    if cfg.num_shared_experts:
        s["shared"] = mlp_specs(cfg)
    return s


def moe_apply(p: dict, cfg: ModelConfig, x: jax.Array,
              capacity: Optional[int] = None):
    """x: (B,S,d) -> (out (B,S,d), aux_loss scalar fp32)."""
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    T = B * S
    xf = x.reshape(T, d)

    logits = (xf.astype(jnp.float32) @ p["router"])          # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, k)                     # (T,k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    density = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0) / (T * k)
    mean_prob = probs.mean(0)
    aux = E * jnp.sum(density * mean_prob) * cfg.router_aux_loss

    if capacity is None:
        capacity = max(int(T * k / E * cfg.capacity_factor), 4)
    C = min(capacity, T)

    # sort-based dispatch: position of each (token, slot) within its expert
    flat_e = idx.reshape(-1)                                 # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    se = flat_e[order]
    first = jnp.searchsorted(se, se, side="left")
    pos = jnp.arange(T * k) - first
    keep = pos < C
    dest = jnp.where(keep, se * C + pos, E * C)              # E*C = drop slot
    tok = order // k

    # keep the (T*k, d) dispatch tensors data-sharded (token-parallel) so the
    # reshard into the expert-sharded buffer lowers as a2a/AG, not a masked
    # full-buffer all-reduce (the dominant collective in the MoE baseline)
    gathered = constrain(xf[tok], ("moe_tokens", None))
    buf = jnp.zeros((E * C + 1, d), x.dtype).at[dest].set(gathered)
    eb = buf[:E * C].reshape(E, C, d)
    eb = constrain(eb, ("experts_act", None, None))
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["wi_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", eb, p["wi_up"])
    h = constrain(h, ("experts_act", None, "ffn_act"))
    out_e = jnp.einsum("ecf,efd->ecd", h, p["wo"])
    out_pad = jnp.concatenate(
        [out_e.reshape(E * C, d), jnp.zeros((1, d), x.dtype)], axis=0)

    # combine in the model dtype (bf16): halves dispatch-path bytes; the
    # fp32 router probabilities only weight the combine, stay fp32 in aux
    gb = gates.reshape(-1)[order].astype(x.dtype)
    contrib = constrain(out_pad[dest] * gb[:, None], ("moe_tokens", None))
    y = jnp.zeros((T, d), x.dtype).at[tok].add(contrib)
    if "shared" in p:
        y = y + mlp_apply(p["shared"], xf)
    return y.reshape(B, S, d), aux
