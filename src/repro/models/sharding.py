"""Logical-axis sharding rules (MaxText-style) with divisibility fallbacks.

Every parameter / activation dimension carries a *logical* axis name; a rule
table maps logical axes to mesh axes. A mapping is dropped (dimension left
replicated) when the dimension size is not divisible by the mesh-axis size —
this is what lets one rule table serve 10 heterogeneous architectures
(28-head GQA, 4 kv heads, 51865-token vocabs, ...) on a fixed (data, model)
mesh. The fallbacks are themselves hillclimb levers (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import contextvars
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Default logical->mesh rules. Order matters: first divisible candidate wins.
DEFAULT_RULES: dict[str, tuple[tuple[str, ...], ...]] = {
    # activations
    "batch":      (("pod", "data"), ("data",)),
    "seq":        ((),),
    "cache_seq":  (("model",), ()),      # decode KV/context sharded over model
    "embed_act":  ((),),
    "heads_act":  (("model",), ()),
    "ffn_act":    (("model",), ()),
    "vocab_act":  (("model",), ()),
    "experts_act": (("model",), ()),
    "moe_tokens": (("data",), ()),   # (T*k,) flat dispatch assignments
    "frontend":   ((),),
    # params (FSDP over data on the embed/row dim, tensor over model)
    "embed":      (("data",), ()),
    "heads":      (("model",), ()),
    "kv_heads":   (("model",), ()),
    "head_dim":   ((),),
    "ffn":        (("model",), ()),
    "vocab":      (("model",), ()),
    "experts":    (("model",), ()),
    "experts_embed": ((),),   # replicated: avoid partial-sum ARs per layer
    "experts_ffn": ((),),
    "kv_lora":    ((),),
    "ssm_inner":  (("model",), ()),
    "ssm_state":  ((),),
    "conv":       ((),),
    "layers":     ((),),                  # stacked scan dim — never sharded
    "source":     ((),),                  # enc-dec source positions
}


# Named rule profiles — the §Perf hillclimb levers. Selected via
# ``dryrun --rules <name>``; "baseline" is the paper-faithful default.
RULE_PROFILES: dict[str, dict] = {
    "baseline": {},
    # §Perf C: decode KV cache sharded on head_dim instead of sequence —
    # dynamic-update-slice becomes shard-local (in-place) instead of a
    # full-cache select rewrite under GSPMD.
    "cache_hd": {
        "cache_seq": ((),),
        "head_dim": (("model",), ()),
        "kv_lora": (("model",), ()),
    },
    # §Perf A: pure expert-parallel MoE + fully-sharded gradients: batch
    # stays on data, experts on model, and params FSDP over both axes so
    # gradient reductions become reduce-scatters of shards.
    "fsdp2d": {
        "embed": (("data",), ()),
        "ffn": (("model",), ()),
        "vocab": (("model",), ()),
    },
    # §Perf B: sequence parallelism for long prefill — activations sharded
    # over seq on the model axis. Rescues archs whose head counts don't
    # divide the model axis (qwen2.5's 40 heads -> attention otherwise
    # replicated 16x on the model axis).
    "seqpar": {
        "seq": (("model",), ()),
    },
}


@dataclass(frozen=True)
class ShardingRules:
    rules: dict[str, tuple[tuple[str, ...], ...]] = field(
        default_factory=lambda: dict(DEFAULT_RULES))

    def with_overrides(self, **ov) -> "ShardingRules":
        r = dict(self.rules)
        for k, v in ov.items():
            r[k] = v
        return ShardingRules(r)

    def resolve(self, logical: tuple[str | None, ...],
                shape: tuple[int, ...], mesh: Mesh) -> P:
        """Map logical axes for a concrete shape to a PartitionSpec.

        Drops any candidate whose mesh-axis product does not divide the
        dimension, and never assigns one mesh axis to two dims.
        """
        assert len(logical) == len(shape), (logical, shape)
        used: set[str] = set()
        out: list = []
        for name, size in zip(logical, shape):
            if name is None:
                out.append(None)
                continue
            cands = self.rules.get(name)
            if cands is None:
                raise KeyError(f"no sharding rule for logical axis {name!r}")
            chosen: tuple[str, ...] = ()
            for cand in cands:
                cand = tuple(a for a in cand if a in mesh.shape)
                if not cand or any(a in used for a in cand):
                    continue
                prod = 1
                for a in cand:
                    prod *= mesh.shape[a]
                if size % prod == 0:
                    chosen = cand
                    break
            used.update(chosen)
            out.append(chosen if len(chosen) > 1 else (chosen[0] if chosen else None))
        return P(*out)


def logical_to_sharding(tree_logical, tree_shapes, mesh: Mesh,
                        rules: ShardingRules) -> object:
    """Map a pytree of logical-axis tuples (+ parallel shapes) to NamedShardings."""
    return jax.tree.map(
        lambda log, shp: NamedSharding(mesh, rules.resolve(log, shp, mesh)),
        tree_logical, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def spec_tree_to_pspecs(tree_logical, tree_shapes, mesh: Mesh,
                        rules: ShardingRules) -> object:
    return jax.tree.map(
        lambda log, shp: rules.resolve(log, shp.shape if hasattr(shp, "shape") else shp, mesh),
        tree_logical, tree_shapes,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


# ---------------------------------------------------------------------------
# Activation sharding constraints — active only inside the distributed
# drivers; model code calls ``constrain`` unconditionally and it is a no-op
# in single-device smoke tests.
# ---------------------------------------------------------------------------
_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "activation_sharding", default=None)


@contextmanager
def activation_sharding(mesh: Mesh, rules: ShardingRules):
    tok = _ACTIVE.set((mesh, rules))
    try:
        yield
    finally:
        _ACTIVE.reset(tok)


def constrain(x: jax.Array, logical: tuple[str | None, ...]) -> jax.Array:
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = rules.resolve(logical, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
