"""Chunked linear attention with per-channel decay — shared by Mamba2 (SSD)
and RWKV6 (Finch).

Recurrence (per batch b, head h; state S in R^{Dk x Dv}):

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T ( d_t ∘ S_{t-1} + diag(u_t) k_t v_t^T )

with log w_t <= 0 and
  * Mamba2:  d_t = w_t (decay applies to output too), u_t = 1, w scalar/head;
  * RWKV6:   d_t = 1 (output reads the *un-decayed* previous state),
             u_t = learned bonus, w per-channel data-dependent.

The chunked algorithm only ever exponentiates non-positive numbers
(exp(cl_t - cl_s) with s <= t), so it is numerically safe in fp32 without
the secondary-chunking tricks GPU kernels need. The Pallas kernel in
``repro.kernels.rwkv6_scan`` implements the same math with VMEM-tiled chunks.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def naive_decay_attention(r, k, v, log_w, u=None, decay_in_output=False):
    """O(S·Dk·Dv) reference via lax.scan over time — the oracle.

    r, k, log_w: (B, S, H, Dk); v: (B, S, H, Dv); u: (H, Dk) or None.
    Returns y: (B, S, H, Dv), final_state: (B, H, Dk, Dv).
    """
    B, S, H, Dk = r.shape
    Dv = v.shape[-1]
    f32 = jnp.float32
    r, k, v, log_w = (x.astype(f32) for x in (r, k, v, log_w))

    def step(state, xs):
        rt, kt, vt, lwt = xs                      # (B,H,Dk) ... (B,H,Dv)
        wt = jnp.exp(lwt)
        kv = kt[..., :, None] * vt[..., None, :]  # (B,H,Dk,Dv)
        if decay_in_output:
            read = wt[..., None] * state + kv
        elif u is not None:
            read = state + u[None, :, :, None].astype(f32) * kv
        else:
            read = state + kv
        yt = jnp.einsum("bhk,bhkv->bhv", rt, read)
        state = wt[..., None] * state + kv
        return state, yt

    xs = tuple(jnp.moveaxis(x, 1, 0) for x in (r, k, v, log_w))
    s0 = jnp.zeros((B, H, Dk, Dv), f32)
    state, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).astype(v.dtype), state


@partial(jax.jit, static_argnames=("chunk", "decay_in_output"))
def chunked_decay_attention(r, k, v, log_w, u=None, *, chunk: int = 64,
                            decay_in_output: bool = False,
                            initial_state=None):
    """Chunk-parallel form: O(S·c·Dk + S·Dk·Dv/c) work per step.

    Shapes as in ``naive_decay_attention``; log_w broadcastable over Dk
    (Mamba2 passes (B,S,H,1)). Returns (y, final_state).
    """
    B, S, H, Dk = r.shape
    Dv = v.shape[-1]
    f32 = jnp.float32
    c = min(chunk, S)
    pad = (-S) % c
    if pad:
        r = jnp.pad(r, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_w = jnp.pad(log_w, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = (S + pad) // c

    def to_chunks(x):
        return jnp.moveaxis(
            x.reshape(B, n, c, H, -1), 1, 0).astype(f32)     # (n,B,c,H,·)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, log_w))
    lwc = jnp.broadcast_to(lwc, rc.shape)
    cl = jnp.cumsum(lwc, axis=2)                             # (n,B,c,H,Dk)
    # e_t: decay exponent applied to S_0 when *reading* at position t
    e = cl if decay_in_output else cl - lwc                  # cl_{t-1}

    tri = jnp.tril(jnp.ones((c, c), bool), 0 if decay_in_output else -1)

    def chunk_step(state, xs):
        rcb, kcb, vcb, clb, eb, lwb = xs                     # (B,c,H,·)
        # inter-chunk: read S_0 with decay exp(e_t)
        r_sc = rcb * jnp.exp(eb)
        y_inter = jnp.einsum("bthk,bhkv->bthv", r_sc, state)
        # intra-chunk: A[t,s] = sum_d r_t k_s exp(e_t - cl_s), s < t (or <= t)
        # exponents are <= 0 for the kept (s <= t) entries; clamp so the
        # masked upper triangle can't produce inf (0 * inf = NaN in grads)
        expo = jnp.exp(jnp.minimum(eb[:, :, None] - clb[:, None], 0.0))
        A = jnp.einsum("bthk,bshk,btshk->bhts", rcb, kcb, expo)
        A = jnp.where(tri[None, None], A, 0.0)
        if not decay_in_output:
            rb = rcb * u[None, None].astype(f32) if u is not None else rcb
            diag = jnp.einsum("bthk,bthk->bht", rb, kcb)   # (B,H,c)
            A = A + diag[..., None] * jnp.eye(c, dtype=f32)
        y_intra = jnp.einsum("bhts,bshv->bthv", A, vcb)
        # state update: S_end = diag(exp(cl_c)) S_0 + sum_s exp(cl_c - cl_s) k_s v_s
        clc = clb[:, -1]                                     # (B,H,Dk)
        k_sc = kcb * jnp.exp(clc[:, None] - clb)
        s_delta = jnp.einsum("bshk,bshv->bhkv", k_sc, vcb)
        state = jnp.exp(clc)[..., None] * state + s_delta
        return state, y_inter + y_intra

    s0 = (jnp.zeros((B, H, Dk, Dv), f32) if initial_state is None
          else initial_state.astype(f32))
    state, yc = jax.lax.scan(chunk_step, s0, (rc, kc, vc, cl, e, lwc))
    y = jnp.moveaxis(yc, 0, 1).reshape(B, n * c, H, Dv)[:, :S]
    return y.astype(v.dtype), state


def decay_attention_decode_step(state, r, k, v, log_w, u=None,
                                decay_in_output=False):
    """Single-token decode. state: (B,H,Dk,Dv); r/k/log_w: (B,H,Dk); v: (B,H,Dv)."""
    f32 = jnp.float32
    rt, kt, vt = r.astype(f32), k.astype(f32), v.astype(f32)
    wt = jnp.exp(jnp.broadcast_to(log_w.astype(f32), rt.shape))
    kv = kt[..., :, None] * vt[..., None, :]
    if decay_in_output:
        read = wt[..., None] * state + kv
    elif u is not None:
        read = state + u[None, :, :, None].astype(f32) * kv
    else:
        read = state + kv
    y = jnp.einsum("bhk,bhkv->bhv", rt, read)
    new_state = wt[..., None] * state + kv
    return y.astype(v.dtype), new_state
