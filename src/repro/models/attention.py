"""Attention variants: GQA (w/ sliding window, M-RoPE, bias), MLA (DeepSeek-V2).

Two compute paths:
  * direct   — materialized scores, used for short sequences / smoke tests;
  * blockwise — pure-JAX flash attention (online softmax over KV blocks inside
    a scan over Q blocks) bounding activation memory for 32k+ prefill. The
    Pallas kernel in ``repro.kernels.flash_attention`` is the TPU-tiled
    version of the same algorithm.

Paged decode: when the KV cache lives in a shared block pool (see
``repro.models.kvcache.init_paged_cache``), :func:`gather_blocks`
materializes a slot's contiguous sequence view from its block table.
Because the gather is a pure permutation-copy, running the contiguous
decode steps below on that view is value-identical to the slot-stripe
layout — the contiguous path stays the reference the paged engine and the
block-table Pallas kernel (``repro.kernels.decode_attention``) are checked
against.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import apply_mrope, apply_rope, dense_init, rms_norm

NEG_INF = -1.0e30
DIRECT_MAX_KV = 4096  # direct path threshold


def gather_blocks(pool, table, axis: int = 0):
    """Materialize a contiguous sequence view from a paged KV pool.

    ``pool`` carries a (num_blocks, block_size) axis pair starting at
    ``axis``; ``table`` is a 1-D int32 vector of physical block ids (0 = the
    all-garbage null block — callers mask positions past the live length, so
    its contents are never observable).  Returns ``pool`` with the two block
    axes merged into one sequence axis of ``len(table) * block_size``.
    """
    g = jnp.take(pool, table, axis=axis)
    shape = g.shape[:axis] + (g.shape[axis] * g.shape[axis + 1],) \
        + g.shape[axis + 2:]
    return g.reshape(shape)


# ---------------------------------------------------------------------------
# Core softmax attention (shared by GQA / MLA / cross-attention)
# ---------------------------------------------------------------------------
def _direct_attention(q, k, v, q_pos, k_pos, *, causal, window, scale):
    """q: (B,Sq,Hkv,G,D) k/v: (B,Sk,Hkv,Dk/Dv) -> (B,Sq,Hkv,G,Dv)."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k).astype(jnp.float32) * scale
    if causal:
        m = k_pos[None, :] <= q_pos[:, None]
        if window is not None:
            m &= (q_pos[:, None] - k_pos[None, :]) < window
        s = jnp.where(m[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w.astype(v.dtype), v)


def _blockwise_attention(q, k, v, q_pos, k_pos, *, causal, window, scale,
                         block_q=1024, block_k=1024):
    """Flash-style online-softmax attention; same signature as direct path."""
    B, Sq, Hkv, G, D = q.shape
    Sk, Dv = k.shape[1], v.shape[-1]
    bq, bk = min(block_q, Sq), min(block_k, Sk)
    nq, nk = -(-Sq // bq), -(-Sk // bk)
    # pad to block multiples
    q = jnp.pad(q, ((0, 0), (0, nq * bq - Sq), (0, 0), (0, 0), (0, 0)))
    qp = jnp.pad(q_pos, (0, nq * bq - Sq), constant_values=-1)
    k_ = jnp.pad(k, ((0, 0), (0, nk * bk - Sk), (0, 0), (0, 0)))
    v_ = jnp.pad(v, ((0, 0), (0, nk * bk - Sk), (0, 0), (0, 0)))
    kp = jnp.pad(k_pos, (0, nk * bk - Sk), constant_values=2**30)
    qb = q.reshape(B, nq, bq, Hkv, G, D)
    qpb = qp.reshape(nq, bq)
    kb = k_.reshape(B, nk, bk, Hkv, -1)
    vb = v_.reshape(B, nk, bk, Hkv, Dv)
    kpb = kp.reshape(nk, bk)

    def q_step(_, qi):
        qblk, qpos = qb[:, qi], qpb[qi]

        def kv_step(carry, ki):
            m_prev, l_prev, acc = carry
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qblk, kb[:, ki]
                           ).astype(jnp.float32) * scale
            msk = kpb[ki][None, :] <= qpos[:, None]
            if window is not None:
                msk &= (qpos[:, None] - kpb[ki][None, :]) < window
            if not causal:
                msk = (kpb[ki] < Sk)[None, :] & jnp.ones_like(msk)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + p.sum(axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bhgqk,bkhd->bhgqd", p, vb[:, ki].astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((B, Hkv, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, Hkv, G, bq), jnp.float32)
        a0 = jnp.zeros((B, Hkv, G, bq, Dv), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(v.dtype)

    _, ob = jax.lax.scan(q_step, None, jnp.arange(nq))      # (nq,B,Hkv,G,bq,Dv)
    out = jnp.moveaxis(ob, 0, 3).reshape(B, Hkv, G, nq * bq, Dv)
    return jnp.moveaxis(out, 3, 1)[:, :Sq]                  # (B,Sq,Hkv,G,Dv)


def multi_head_attention(q, k, v, q_pos, k_pos, *, causal=True, window=None,
                         scale=None, force_blockwise: Optional[bool] = None):
    """q: (B,Sq,H,D), k/v: (B,Sk,Hkv,·) with H % Hkv == 0. Returns (B,Sq,H,Dv)."""
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    G = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Sq, Hkv, G, D)
    use_blockwise = (k.shape[1] > DIRECT_MAX_KV if force_blockwise is None
                     else force_blockwise)
    fn = _blockwise_attention if use_blockwise else _direct_attention
    out = fn(qg, k, v, q_pos, k_pos, causal=causal, window=window, scale=scale)
    return out.reshape(B, Sq, H, -1)


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------
def gqa_init(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    dt = jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    d, H, Hkv = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, (H, hd), dt),
        "wk": dense_init(ks[1], d, (Hkv, hd), dt),
        "wv": dense_init(ks[2], d, (Hkv, hd), dt),
        "wo": dense_init(ks[3], H * hd, (d,), dt).reshape(H, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dt)
        p["bk"] = jnp.zeros((Hkv, hd), dt)
        p["bv"] = jnp.zeros((Hkv, hd), dt)
    return p


def gqa_specs(cfg: ModelConfig) -> dict:
    s = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        s |= {"bq": ("heads", "head_dim"), "bk": ("kv_heads", "head_dim"),
              "bv": ("kv_heads", "head_dim")}
    return s


def gqa_project_qkv(p, cfg: ModelConfig, x, positions, *,
                    rope_theta: Optional[float] = None,
                    mrope_positions: Optional[jax.Array] = None):
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dhe->bshe", x, p["wk"])
    v = jnp.einsum("bsd,dhe->bshe", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    theta = cfg.rope_theta if rope_theta is None else rope_theta
    rope_off = not isinstance(theta, jax.Array) and theta <= 0
    if mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, theta)
        k = apply_mrope(k, mrope_positions, theta)
    elif positions is not None and not rope_off:
        q = apply_rope(q, positions, theta)
        k = apply_rope(k, positions, theta)
    return q, k, v


def gqa_apply_full(p, cfg: ModelConfig, x, positions, *, window=None,
                   rope_theta=None, mrope_positions=None, causal=True):
    """Full-sequence self-attention. x: (B,S,d) -> (B,S,d)."""
    q, k, v = gqa_project_qkv(p, cfg, x, positions, rope_theta=rope_theta,
                              mrope_positions=mrope_positions)
    pos = positions if positions is not None else jnp.arange(x.shape[1])
    qpos = pos[0] if pos.ndim == 2 else pos
    out = multi_head_attention(q, k, v, qpos, qpos, causal=causal, window=window)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def gqa_apply_cross(p, cfg: ModelConfig, x, enc_k, enc_v):
    """Cross-attention against precomputed encoder K/V: (B,Ssrc,Hkv,hd)."""
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    Ssrc = enc_k.shape[1]
    qpos = jnp.arange(x.shape[1])
    kpos = jnp.arange(Ssrc)
    out = multi_head_attention(q, enc_k, enc_v, qpos, kpos, causal=False)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def gqa_decode_step(p, cfg: ModelConfig, x, k_cache, v_cache, index, *,
                    window=None, rope_theta=None, mrope_positions=None,
                    ring: bool = False):
    """One-token decode. x: (B,1,d); k/v_cache: (B,S,Hkv,hd); index: scalar.

    Returns (out (B,1,d), k_cache', v_cache'). ``ring=True`` treats the cache
    as a ring buffer of size window (long_500k sliding-window decode).
    """
    B, _, _ = x.shape
    pos = jnp.full((B, 1), index, jnp.int32)
    q, k_new, v_new = gqa_project_qkv(p, cfg, x, pos, rope_theta=rope_theta,
                                      mrope_positions=mrope_positions)
    S = k_cache.shape[1]
    slot = (index % S) if ring else index
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k_new.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v_new.astype(v_cache.dtype), slot, axis=1)
    H = q.shape[2]
    Hkv = k_cache.shape[2]
    G = H // Hkv
    qg = q.reshape(B, Hkv, G, -1)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache).astype(jnp.float32)
    s = s * (q.shape[-1] ** -0.5)
    kpos = jnp.arange(S)
    if ring:
        # entry at slot p holds absolute position: reconstruct validity
        abs_pos = jnp.where(kpos <= slot, index - slot + kpos,
                            index - slot - S + kpos)
        valid = (abs_pos >= 0) & (abs_pos <= index)
        if window is not None:
            valid &= (index - abs_pos) < window
    else:
        valid = kpos <= index
        if window is not None:
            valid &= (index - kpos) < window
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", w.astype(v_cache.dtype), v_cache)
    out = out.reshape(B, 1, H, -1)
    return (jnp.einsum("bshe,hed->bsd", out, p["wo"]).astype(x.dtype),
            k_cache, v_cache)


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2): low-rank KV compression, absorbed decode
# ---------------------------------------------------------------------------
def mla_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d, H = cfg.d_model, cfg.num_heads
    hd, vd = cfg.resolved_head_dim, cfg.resolved_v_head_dim
    r, qr, rp = cfg.kv_lora_rank, cfg.q_lora_rank, cfg.qk_rope_head_dim
    ks = jax.random.split(key, 6)
    return {
        "wq_a": dense_init(ks[0], d, (qr,), dt),
        "q_norm": jnp.zeros((qr,), dt),
        "wq_b": dense_init(ks[1], qr, (H, hd + rp), dt),
        "wkv_a": dense_init(ks[2], d, (r + rp,), dt),
        "kv_norm": jnp.zeros((r,), dt),
        "wkv_b_k": dense_init(ks[3], r, (H, hd), dt),
        "wkv_b_v": dense_init(ks[4], r, (H, vd), dt),
        "wo": dense_init(ks[5], H * vd, (d,), dt).reshape(H, vd, d),
    }


def mla_specs(cfg: ModelConfig) -> dict:
    return {
        "wq_a": ("embed", "kv_lora"),
        "q_norm": ("kv_lora",),
        "wq_b": ("kv_lora", "heads", "head_dim"),
        "wkv_a": ("embed", "kv_lora"),
        "kv_norm": ("kv_lora",),
        "wkv_b_k": ("kv_lora", "heads", "head_dim"),
        "wkv_b_v": ("kv_lora", "heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }


def _mla_q(p, cfg: ModelConfig, x, positions):
    hd, rp = cfg.resolved_head_dim, cfg.qk_rope_head_dim
    q = rms_norm(x @ p["wq_a"], p["q_norm"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhe->bshe", q, p["wq_b"])
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_kv_compress(p, cfg: ModelConfig, x, positions):
    r = cfg.kv_lora_rank
    kv = x @ p["wkv_a"]
    c_kv, k_rope = kv[..., :r], kv[..., r:]
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope   # (B,S,r), (B,S,rp)


def mla_apply_full(p, cfg: ModelConfig, x, positions):
    """Training/prefill path: expand compressed KV to per-head K/V."""
    hd = cfg.resolved_head_dim
    rp = cfg.qk_rope_head_dim
    q_nope, q_rope = _mla_q(p, cfg, x, positions)
    c_kv, k_rope = _mla_kv_compress(p, cfg, x, positions)
    c_n = rms_norm(c_kv, p["kv_norm"], cfg.norm_eps)
    k_nope = jnp.einsum("bsr,rhe->bshe", c_n, p["wkv_b_k"])
    v = jnp.einsum("bsr,rhe->bshe", c_n, p["wkv_b_v"])
    H = k_nope.shape[2]
    k = jnp.concatenate([k_nope, jnp.broadcast_to(
        k_rope[:, :, None, :], (*k_nope.shape[:3], rp))], axis=-1)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    pos = positions[0] if positions.ndim == 2 else positions
    out = multi_head_attention(q, k, v, pos, pos, causal=True,
                               scale=(hd + rp) ** -0.5)
    return jnp.einsum("bshe,hed->bsd", out, p["wo"])


def mla_decode_step(p, cfg: ModelConfig, x, ckv_cache, krope_cache, index):
    """Absorbed one-token decode: attention runs in the kv_lora space.

    ckv_cache: (B,S,r) raw compressed KV; krope_cache: (B,S,rp).
    """
    B = x.shape[0]
    hd, rp = cfg.resolved_head_dim, cfg.qk_rope_head_dim
    pos = jnp.full((B, 1), index, jnp.int32)
    q_nope, q_rope = _mla_q(p, cfg, x, pos)                  # (B,1,H,·)
    c_new, kr_new = _mla_kv_compress(p, cfg, x, pos)
    ckv_cache = jax.lax.dynamic_update_slice_in_dim(
        ckv_cache, c_new.astype(ckv_cache.dtype), index, axis=1)
    krope_cache = jax.lax.dynamic_update_slice_in_dim(
        krope_cache, kr_new.astype(krope_cache.dtype), index, axis=1)
    c_n = rms_norm(ckv_cache, p["kv_norm"], cfg.norm_eps)    # (B,S,r)
    # absorb wkv_b_k into q: q_c (B,H,r)
    q_c = jnp.einsum("bhe,rhe->bhr", q_nope[:, 0], p["wkv_b_k"])
    s = jnp.einsum("bhr,bsr->bhs", q_c, c_n).astype(jnp.float32)
    s += jnp.einsum("bhe,bse->bhs", q_rope[:, 0], krope_cache).astype(jnp.float32)
    s *= (hd + rp) ** -0.5
    valid = jnp.arange(ckv_cache.shape[1]) <= index
    s = jnp.where(valid[None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o_c = jnp.einsum("bhs,bsr->bhr", w.astype(c_n.dtype), c_n)
    o = jnp.einsum("bhr,rhe->bhe", o_c, p["wkv_b_v"])        # (B,H,vd)
    return (jnp.einsum("bhe,hed->bd", o, p["wo"]).astype(x.dtype)[:, None],
            ckv_cache, krope_cache)
