"""Layer-stack assembly for every architecture family.

All stacks scan over layers with stacked params (small HLO, fast compile);
non-uniform structure is handled inside the scan body:
  * gemma3   — per-layer (theta, window) arrays select local vs global attn;
  * zamba2   — a single *shared* attention block applied every k-th layer
               via lax.cond (weights reused, as in the paper);
  * deepseek — leading dense layer(s) scanned separately from MoE layers.
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba2 as mb
from repro.models import moe as moe_lib
from repro.models import rwkv6 as rw
from repro.models.common import (
    dense_init, embed_init, gelu_mlp_apply, gelu_mlp_init, gelu_mlp_specs,
    layer_norm, mlp_apply, mlp_init, mlp_specs, rms_norm,
    default_mrope_positions)
from repro.models.sharding import constrain

NO_WINDOW = jnp.int32(2 ** 30)


# ---------------------------------------------------------------------------
# norms (whisper = LayerNorm w/ bias, everyone else = RMSNorm)
# ---------------------------------------------------------------------------
def _norm_init(cfg: ModelConfig):
    dt = jnp.dtype(cfg.dtype)
    if cfg.family == "audio":
        return {"w": jnp.ones((cfg.d_model,), dt), "b": jnp.zeros((cfg.d_model,), dt)}
    return jnp.zeros((cfg.d_model,), dt)


def _norm_spec(cfg: ModelConfig):
    if cfg.family == "audio":
        return {"w": ("embed",), "b": ("embed",)}
    return ("embed",)


def _norm(p, x, cfg: ModelConfig):
    if cfg.family == "audio":
        return layer_norm(x, p["w"], p["b"], cfg.norm_eps)
    return rms_norm(x, p, cfg.norm_eps)


def _sinusoid(seq: int, d: int, offset=0) -> jax.Array:
    pos = jnp.arange(seq) + offset
    inv = jnp.exp(-jnp.arange(0, d, 2) / d * math.log(10000.0))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[:, :d]


def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _lift_specs(spec, n_extra_logical="layers"):
    """Prepend the 'layers' logical axis to every leaf of a specs tree."""
    return jax.tree.map(
        lambda t: (n_extra_logical, *t), spec,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


# ---------------------------------------------------------------------------
# per-layer init / specs
# ---------------------------------------------------------------------------
def _dense_layer_init(key, cfg: ModelConfig, *, use_moe: bool):
    k1, k2 = jax.random.split(key)
    p = {"ln1": _norm_init(cfg), "ln2": _norm_init(cfg)}
    p["attn"] = (attn.mla_init(k1, cfg) if cfg.attention == "mla"
                 else attn.gqa_init(k1, cfg))
    if use_moe:
        p["moe"] = moe_lib.moe_init(k2, cfg)
    elif cfg.family == "audio":
        p["mlp"] = gelu_mlp_init(k2, cfg)
    else:
        p["mlp"] = mlp_init(k2, cfg)
    return p


def _dense_layer_specs(cfg: ModelConfig, *, use_moe: bool):
    s = {"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg)}
    s["attn"] = (attn.mla_specs(cfg) if cfg.attention == "mla"
                 else attn.gqa_specs(cfg))
    if use_moe:
        s["moe"] = moe_lib.moe_specs(cfg)
    elif cfg.family == "audio":
        s["mlp"] = gelu_mlp_specs(cfg)
    else:
        s["mlp"] = mlp_specs(cfg)
    return s


def _layer_theta_window(cfg: ModelConfig, *, ring: bool = False):
    """Per-layer (rope_theta, window) arrays — gemma3's 5:1 local:global."""
    L = cfg.num_layers
    if cfg.local_global_ratio and cfg.sliding_window:
        r = cfg.local_global_ratio
        is_global = (jnp.arange(L) % (r + 1)) == r
        theta = jnp.where(is_global, cfg.rope_theta, 1.0e4)
        if ring:  # long_500k carve: global layers also windowed
            window = jnp.full((L,), cfg.sliding_window, jnp.int32)
        else:
            window = jnp.where(is_global, NO_WINDOW, cfg.sliding_window)
    else:
        theta = jnp.full((L,), cfg.rope_theta, jnp.float32)
        w = cfg.sliding_window if cfg.sliding_window else 2 ** 30
        window = jnp.full((L,), w, jnp.int32)
    return theta.astype(jnp.float32), window


# ---------------------------------------------------------------------------
# top-level init / specs
# ---------------------------------------------------------------------------
def stack_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: dict = {"embed": embed_init(keys[0], cfg.vocab_size, cfg.d_model, dt),
               "final_norm": _norm_init(cfg)}
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(keys[1], cfg.d_model, (cfg.vocab_size,), dt)

    fam = cfg.family
    if fam in ("dense", "vlm", "moe", "audio"):
        n_moe = cfg.num_layers - cfg.first_dense_layers if cfg.is_moe else 0
        n_dense = cfg.num_layers - n_moe
        if n_dense:
            p["dense_layers"] = _stacked(
                lambda k: _dense_layer_init(k, cfg, use_moe=False), keys[2], n_dense)
        if n_moe:
            p["layers"] = _stacked(
                lambda k: _dense_layer_init(k, cfg, use_moe=True), keys[3], n_moe)
        elif not cfg.is_moe:
            p["layers"] = p.pop("dense_layers")
        if fam == "audio":
            p["encoder"] = {
                "layers": _stacked(
                    lambda k: _dense_layer_init(k, cfg, use_moe=False),
                    keys[4], cfg.encoder_layers),
                "final_norm": _norm_init(cfg),
            }
            p["cross"] = _stacked(
                lambda k: {"ln": _norm_init(cfg),
                           "attn": attn.gqa_init(k, cfg)},
                keys[5], cfg.num_layers)
    elif fam == "ssm":
        p["layers"] = _stacked(
            lambda k: {"ln1": _norm_init(cfg), "ln2": _norm_init(cfg),
                       "mix": rw.rwkv6_init(k, cfg)}, keys[2], cfg.num_layers)
    elif fam == "hybrid":
        p["layers"] = _stacked(
            lambda k: {"norm": _norm_init(cfg), "mamba": mb.mamba2_init(k, cfg)},
            keys[2], cfg.num_layers)
        p["shared_attn"] = {
            "ln1": _norm_init(cfg), "attn": attn.gqa_init(keys[3], cfg),
            "ln2": _norm_init(cfg), "mlp": mlp_init(keys[4], cfg),
        }
    else:
        raise ValueError(fam)
    return p


def stack_specs(cfg: ModelConfig) -> dict:
    s: dict = {"embed": ("vocab", "embed"), "final_norm": _norm_spec(cfg)}
    if not cfg.tie_embeddings:
        s["lm_head"] = ("embed", "vocab")
    fam = cfg.family
    if fam in ("dense", "vlm", "moe", "audio"):
        dense_spec = _lift_specs(_dense_layer_specs(cfg, use_moe=False))
        if cfg.is_moe:
            if cfg.first_dense_layers:
                s["dense_layers"] = dense_spec
            s["layers"] = _lift_specs(_dense_layer_specs(cfg, use_moe=True))
        else:
            s["layers"] = dense_spec
        if fam == "audio":
            s["encoder"] = {"layers": dense_spec, "final_norm": _norm_spec(cfg)}
            s["cross"] = _lift_specs({"ln": _norm_spec(cfg),
                                      "attn": attn.gqa_specs(cfg)})
    elif fam == "ssm":
        s["layers"] = _lift_specs({"ln1": _norm_spec(cfg), "ln2": _norm_spec(cfg),
                                   "mix": rw.rwkv6_specs(cfg)})
    elif fam == "hybrid":
        s["layers"] = _lift_specs({"norm": _norm_spec(cfg),
                                   "mamba": mb.mamba2_specs(cfg)})
        s["shared_attn"] = {"ln1": _norm_spec(cfg), "attn": attn.gqa_specs(cfg),
                            "ln2": _norm_spec(cfg), "mlp": mlp_specs(cfg)}
    return s


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------
def _embed_tokens(p, cfg: ModelConfig, tokens, frontend=None):
    x = jnp.take(p["embed"], tokens, axis=0)
    if cfg.family == "dense" and cfg.local_global_ratio:  # gemma3
        x = x * math.sqrt(cfg.d_model)
    if frontend is not None and cfg.family == "vlm":
        F = frontend.shape[1]
        pad = jnp.zeros((x.shape[0], x.shape[1] - F, x.shape[2]), x.dtype)
        fe = jnp.concatenate([frontend.astype(x.dtype), pad], axis=1)
        sel = (jnp.arange(x.shape[1]) < F)[None, :, None]
        x = jnp.where(sel, fe, x)
    return constrain(x, ("batch", "seq", "embed_act"))


def _unembed(p, cfg: ModelConfig, x):
    x = _norm(p["final_norm"], x, cfg)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, p["embed"])
    else:
        logits = x @ p["lm_head"]
    return constrain(logits.astype(jnp.float32), ("batch", "seq", "vocab_act"))


def _maybe_ckpt(fn, remat: bool):
    return jax.checkpoint(fn) if remat else fn


# ---------------------------------------------------------------------------
# forward (training / full-sequence)
# ---------------------------------------------------------------------------
def stack_forward(p, cfg: ModelConfig, tokens, *, frontend=None,
                  remat: bool = False):
    """tokens: (B,S) int32 -> (logits (B,S,V) fp32, aux scalar)."""
    B, S = tokens.shape
    x = _embed_tokens(p, cfg, tokens, frontend)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S)).astype(jnp.int32)
    mrope_pos = (default_mrope_positions(B, S, cfg.num_frontend_tokens)
                 if cfg.mrope else None)
    fam = cfg.family

    if fam in ("dense", "vlm", "moe"):
        theta_l, window_l = _layer_theta_window(cfg)

        def body(carry, xs):
            x, aux = carry
            lp, theta, window = xs
            h = _norm(lp["ln1"], x, cfg)
            if cfg.attention == "mla":
                a = attn.mla_apply_full(lp["attn"], cfg, h, positions)
            else:
                a = attn.gqa_apply_full(lp["attn"], cfg, h, positions,
                                        window=window, rope_theta=theta,
                                        mrope_positions=mrope_pos)
            x = x + a
            h = _norm(lp["ln2"], x, cfg)
            if "moe" in lp:
                f, al = moe_lib.moe_apply(lp["moe"], cfg, h)
                aux = aux + al
            else:
                f = mlp_apply(lp["mlp"], h)
            return (x + f, aux), None

        body = _maybe_ckpt(body, remat)
        aux = jnp.zeros((), jnp.float32)
        n_moe = cfg.num_layers - cfg.first_dense_layers if cfg.is_moe else 0
        n_dense = cfg.num_layers - n_moe
        if cfg.is_moe and cfg.first_dense_layers:
            (x, aux), _ = jax.lax.scan(
                body, (x, aux),
                (p["dense_layers"], theta_l[:n_dense], window_l[:n_dense]))
            (x, aux), _ = jax.lax.scan(
                body, (x, aux),
                (p["layers"], theta_l[n_dense:], window_l[n_dense:]))
        else:
            (x, aux), _ = jax.lax.scan(body, (x, aux),
                                       (p["layers"], theta_l, window_l))
        return _unembed(p, cfg, x), aux

    if fam == "ssm":
        def body(x, lp):
            h = _norm(lp["ln1"], x, cfg)
            o, _ = rw.rwkv6_time_mix_full(lp["mix"], cfg, h)
            x = x + o
            h = _norm(lp["ln2"], x, cfg)
            o, _ = rw.rwkv6_channel_mix(lp["mix"], cfg, h)
            return x + o, None
        x, _ = jax.lax.scan(_maybe_ckpt(body, remat), x, p["layers"])
        return _unembed(p, cfg, x), jnp.zeros((), jnp.float32)

    if fam == "hybrid":
        shared = p["shared_attn"]
        every = cfg.hybrid_attn_every

        def shared_block(x):
            h = _norm(shared["ln1"], x, cfg)
            a = attn.gqa_apply_full(shared["attn"], cfg, h, positions)
            x = x + a
            h = _norm(shared["ln2"], x, cfg)
            return x + mlp_apply(shared["mlp"], h)

        def body(x, xs):
            lp, idx = xs
            h = _norm(lp["norm"], x, cfg)
            m, _ = mb.mamba2_apply_full(lp["mamba"], cfg, h)
            x = x + m
            return jax.lax.cond((idx + 1) % every == 0, shared_block,
                                lambda y: y, x), None

        x, _ = jax.lax.scan(_maybe_ckpt(body, remat), x,
                            (p["layers"], jnp.arange(cfg.num_layers)))
        return _unembed(p, cfg, x), jnp.zeros((), jnp.float32)

    if fam == "audio":
        enc = encode_source(p, cfg, frontend)
        x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)

        def body(x, xs):
            lp, cp = xs
            h = _norm(lp["ln1"], x, cfg)
            x = x + attn.gqa_apply_full(lp["attn"], cfg, h, positions)
            h = _norm(cp["ln"], x, cfg)
            ek = jnp.einsum("bsd,dhe->bshe", enc, cp["attn"]["wk"])
            ev = jnp.einsum("bsd,dhe->bshe", enc, cp["attn"]["wv"])
            if cfg.qkv_bias:
                ek, ev = ek + cp["attn"]["bk"], ev + cp["attn"]["bv"]
            x = x + attn.gqa_apply_cross(cp["attn"], cfg, h, ek, ev)
            h = _norm(lp["ln2"], x, cfg)
            return x + gelu_mlp_apply(lp["mlp"], h), None

        x, _ = jax.lax.scan(_maybe_ckpt(body, remat), x,
                            (p["layers"], p["cross"]))
        return _unembed(p, cfg, x), jnp.zeros((), jnp.float32)

    raise ValueError(fam)


def encode_source(p, cfg: ModelConfig, frontend):
    """Whisper encoder over stubbed frame embeddings (B, Ssrc, d)."""
    enc = p["encoder"]
    x = frontend.astype(jnp.dtype(cfg.dtype))
    x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)
    pos = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2]).astype(jnp.int32)

    def body(x, lp):
        h = _norm(lp["ln1"], x, cfg)
        x = x + attn.gqa_apply_full(lp["attn"], cfg, h, pos, causal=False,
                                    rope_theta=0.0)
        h = _norm(lp["ln2"], x, cfg)
        return x + gelu_mlp_apply(lp["mlp"], h), None

    x, _ = jax.lax.scan(body, x, enc["layers"])
    return _norm(enc["final_norm"], x, cfg)
