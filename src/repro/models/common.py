"""Shared model building blocks: norms, MLPs, embeddings, RoPE/M-RoPE, masks.

Every ``*_init`` has a parallel ``*_specs`` returning the same tree with
logical-axis tuples as leaves (consumed by models.sharding).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Axes = tuple  # tree leaves in specs trees are tuples of logical axis names


def truncated_normal_init(key, shape, scale: float, dtype) -> jax.Array:
    # fan-in scaled truncated normal, MaxText-style
    stddev = scale / math.sqrt(max(shape[0], 1))
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * stddev).astype(dtype)


def dense_init(key, in_dim: int, out_shape: tuple[int, ...], dtype) -> jax.Array:
    return truncated_normal_init(key, (in_dim, *out_shape), 1.0, dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def group_norm_heads(x: jax.Array, w: jax.Array, eps: float) -> jax.Array:
    """Per-head group norm over the last dim; x: (..., H, D), w: (H, D)."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    x = (x - mu) * jax.lax.rsqrt(var + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "wi_gate": dense_init(k1, cfg.d_model, (d_ff,), dt),
        "wi_up": dense_init(k2, cfg.d_model, (d_ff,), dt),
        "wo": dense_init(k3, d_ff, (cfg.d_model,), dt),
    }


def mlp_specs(cfg: ModelConfig) -> dict:
    return {
        "wi_gate": ("embed", "ffn"),
        "wi_up": ("embed", "ffn"),
        "wo": ("ffn", "embed"),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    g = jax.nn.silu(x @ p["wi_gate"])
    u = x @ p["wi_up"]
    return (g * u) @ p["wo"]


def gelu_mlp_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    return {
        "wi": dense_init(k1, cfg.d_model, (cfg.d_ff,), dt),
        "bi": jnp.zeros((cfg.d_ff,), dt),
        "wo": dense_init(k2, cfg.d_ff, (cfg.d_model,), dt),
        "bo": jnp.zeros((cfg.d_model,), dt),
    }


def gelu_mlp_specs(cfg: ModelConfig) -> dict:
    return {"wi": ("embed", "ffn"), "bi": ("ffn",),
            "wo": ("ffn", "embed"), "bo": ("embed",)}


def gelu_mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    return (jax.nn.gelu(x @ p["wi"] + p["bi"], approximate=True)) @ p["wo"] + p["bo"]


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32. theta may be traced."""
    if not isinstance(theta, jax.Array) and theta <= 0:
        return x  # learned-positions model (whisper): no rotary
    freqs = rope_freqs(x.shape[-1], theta)                 # (D/2,)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (B, S, D/2)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# M-RoPE: rotary channels split into (temporal, height, width) sections —
# proportions follow qwen2-vl's (16, 24, 24) of head_dim/2 = 64.
def mrope_sections(half: int) -> tuple[int, int, int]:
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(x: jax.Array, positions3: jax.Array, theta: float,
                sections: tuple[int, ...] | None = None) -> jax.Array:
    """x: (B, S, H, D); positions3: (3, B, S) — (t, h, w) position ids."""
    half = x.shape[-1] // 2
    sections = sections or mrope_sections(half)
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(x.shape[-1], theta)                  # (half,)
    # pick which of t/h/w drives each rotary channel
    sec_id = jnp.repeat(jnp.arange(len(sections)), jnp.array(sections),
                        total_repeat_length=half)           # (half,)
    pos = positions3.astype(jnp.float32)                    # (3, B, S)
    pos_per_chan = jnp.take(pos, sec_id, axis=0)            # (half, B, S)
    ang = jnp.moveaxis(pos_per_chan, 0, -1) * freqs         # (B, S, half)
    sin, cos = jnp.sin(ang)[:, :, None, :], jnp.cos(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def default_mrope_positions(batch: int, seq: int, num_frontend: int) -> jax.Array:
    """Stub M-RoPE ids: image patches get a sqrt grid, text gets linear t."""
    side = max(int(math.sqrt(max(num_frontend, 1))), 1)
    t = jnp.arange(seq)
    is_img = t < num_frontend
    h = jnp.where(is_img, (t // side), t)
    w = jnp.where(is_img, (t % side), t)
    tt = jnp.where(is_img, 0, t - num_frontend + 1)
    pos = jnp.stack([tt, h, w])                             # (3, S)
    return jnp.broadcast_to(pos[:, None, :], (3, batch, seq)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Masks (built lazily from iota; never materialized at (S, S) for big S —
# blockwise attention receives span bounds instead)
# ---------------------------------------------------------------------------
def causal_mask(q_pos: jax.Array, k_pos: jax.Array,
                window: Optional[int] = None) -> jax.Array:
    """Boolean mask (…, Q, K): k <= q and (q - k) < window when sliding."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= (q_pos[:, None] - k_pos[None, :]) < window
    return m
