"""RWKV6 (Finch) block: data-dependent token-shift + per-channel decay WKV.

Token-mix state for decode is one vector per layer (+ the wkv matrix state);
channel-mix keeps its own shift vector. [arXiv:2404.05892]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, group_norm_heads
from repro.models.linear_scan import (
    chunked_decay_attention, decay_attention_decode_step)

MIX_RANK = 32
DECAY_RANK = 64
N_MIX = 5  # r, k, v, w, g


def _dims(cfg: ModelConfig):
    H = cfg.num_heads
    hd = cfg.resolved_head_dim
    assert H * hd == cfg.d_model
    return H, hd


def rwkv6_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    H, hd = _dims(cfg)
    ks = jax.random.split(key, 10)
    return {
        # token mix
        "mu_x": jnp.zeros((d,), dt),
        "mu": jnp.zeros((N_MIX, d), dt),
        "tm_w1": dense_init(ks[0], d, (N_MIX * MIX_RANK,), dt),
        "tm_w2": (jax.random.normal(ks[1], (N_MIX, MIX_RANK, d), jnp.float32)
                  * 0.02).astype(dt),
        "w0": jnp.full((d,), -6.0, jnp.float32),
        "w1": dense_init(ks[2], d, (DECAY_RANK,), dt),
        "w2": (jax.random.normal(ks[3], (DECAY_RANK, d), jnp.float32)
               * 0.02).astype(dt),
        "wr": dense_init(ks[4], d, (d,), dt),
        "wk": dense_init(ks[5], d, (d,), dt),
        "wv": dense_init(ks[6], d, (d,), dt),
        "wg": dense_init(ks[7], d, (d,), dt),
        "wo": dense_init(ks[8], d, (d,), dt),
        "u": jnp.zeros((H, hd), jnp.float32),
        "ln_x": jnp.ones((H, hd), dt),
        # channel mix
        "cm_mu_k": jnp.zeros((d,), dt),
        "cm_mu_r": jnp.zeros((d,), dt),
        "cm_wk": dense_init(ks[9], d, (cfg.d_ff,), dt),
        "cm_wv": dense_init(jax.random.fold_in(key, 99), cfg.d_ff, (d,), dt),
        "cm_wr": dense_init(jax.random.fold_in(key, 98), d, (d,), dt),
    }


def rwkv6_specs(cfg: ModelConfig) -> dict:
    return {
        "mu_x": ("embed",), "mu": (None, "embed"),
        "tm_w1": ("embed", None), "tm_w2": (None, None, "embed"),
        "w0": ("embed",), "w1": ("embed", None), "w2": (None, "embed"),
        "wr": ("embed", "ssm_inner"), "wk": ("embed", "ssm_inner"),
        "wv": ("embed", "ssm_inner"), "wg": ("embed", "ssm_inner"),
        "wo": ("ssm_inner", "embed"),
        "u": ("heads", "head_dim"), "ln_x": ("heads", "head_dim"),
        "cm_mu_k": ("embed",), "cm_mu_r": ("embed",),
        "cm_wk": ("embed", "ffn"), "cm_wv": ("ffn", "embed"),
        "cm_wr": ("embed", "ssm_inner"),
    }


def _shift(x, last=None):
    """xx[t] = x[t-1]; first position comes from ``last`` (decode state)."""
    first = (jnp.zeros_like(x[:, :1]) if last is None else last[:, None])
    return jnp.concatenate([first, x[:, :-1]], axis=1)


def _token_mix_inputs(p, cfg, x, xx):
    dx = xx - x
    base = x + dx * p["mu_x"]
    z = jnp.tanh(base @ p["tm_w1"]).reshape(*x.shape[:2], N_MIX, MIX_RANK)
    mixes = jnp.einsum("bsfr,frd->bsfd", z, p["tm_w2"]) + p["mu"]
    xi = x[:, :, None, :] + dx[:, :, None, :] * mixes        # (B,S,5,d)
    x_r, x_k, x_v, x_w, x_g = (xi[:, :, i] for i in range(N_MIX))
    H, hd = _dims(cfg)
    B_, S = x.shape[:2]
    r = (x_r @ p["wr"]).reshape(B_, S, H, hd)
    k = (x_k @ p["wk"]).reshape(B_, S, H, hd)
    v = (x_v @ p["wv"]).reshape(B_, S, H, hd)
    g = jax.nn.silu(x_g @ p["wg"])
    lw = p["w0"] + (jnp.tanh(x_w @ p["w1"]) @ p["w2"]).astype(jnp.float32)
    log_w = -jnp.exp(lw).reshape(B_, S, H, hd)               # <= 0
    return r, k, v, g, log_w


def _token_mix_out(p, cfg, y, g, x_shape, dtype):
    H, hd = _dims(cfg)
    y = group_norm_heads(y, p["ln_x"], 64e-5).reshape(*x_shape[:2], cfg.d_model)
    return (y.astype(dtype) * g) @ p["wo"]


def rwkv6_time_mix_full(p, cfg: ModelConfig, x, *, initial=None):
    """initial: (last_x (B,d), wkv_state (B,H,hd,hd)) or None."""
    last_x = None if initial is None else initial[0]
    xx = _shift(x, last_x)
    r, k, v, g, log_w = _token_mix_inputs(p, cfg, x, xx)
    st0 = None if initial is None else initial[1]
    y, state = chunked_decay_attention(r, k, v, log_w, p["u"],
                                       initial_state=st0)
    out = _token_mix_out(p, cfg, y, g, x.shape, x.dtype)
    return out, (x[:, -1], state)


def rwkv6_time_mix_step(p, cfg: ModelConfig, x, last_x, state):
    """x: (B,1,d). Returns (out, new_last_x, new_state)."""
    xx = last_x[:, None]
    r, k, v, g, log_w = _token_mix_inputs(p, cfg, x, xx)
    y, state = decay_attention_decode_step(
        state, r[:, 0], k[:, 0], v[:, 0], log_w[:, 0], p["u"])
    out = _token_mix_out(p, cfg, y[:, None], g, x.shape, x.dtype)
    return out, x[:, 0], state


def rwkv6_channel_mix(p, cfg: ModelConfig, x, last_x=None):
    """Works for full-seq (last_x None or (B,d)) and single step alike."""
    xx = _shift(x, last_x)
    xk = x + (xx - x) * p["cm_mu_k"]
    xr = x + (xx - x) * p["cm_mu_r"]
    kk = jnp.square(jax.nn.relu(xk @ p["cm_wk"]))
    return jax.nn.sigmoid(xr @ p["cm_wr"]) * (kk @ p["cm_wv"]), x[:, -1]


def rwkv6_init_state(cfg: ModelConfig, batch: int):
    H, hd = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    return {
        "att_x": jnp.zeros((batch, cfg.d_model), dt),
        "ffn_x": jnp.zeros((batch, cfg.d_model), dt),
        "wkv": jnp.zeros((batch, H, hd, hd), jnp.float32),
    }
