"""Mamba2 (SSD) block — used by zamba2 trunk; decode keeps O(1) state."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rms_norm
from repro.models.linear_scan import (
    chunked_decay_attention, decay_attention_decode_step)
from repro.models.sharding import constrain


def _dims(cfg: ModelConfig):
    inner = cfg.ssm_expand * cfg.d_model
    nh = inner // cfg.ssm_head_dim
    return inner, nh, cfg.ssm_state_dim


def mamba2_init(key, cfg: ModelConfig) -> dict:
    dt = jnp.dtype(cfg.dtype)
    inner, nh, N = _dims(cfg)
    conv_dim = inner + 2 * N
    ks = jax.random.split(key, 3)
    return {
        "in_proj": dense_init(ks[0], cfg.d_model, (2 * inner + 2 * N + nh,), dt),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_dim),
                                     jnp.float32) * 0.02).astype(dt),
        "conv_b": jnp.zeros((conv_dim,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm_w": jnp.zeros((inner,), dt),
        "out_proj": dense_init(ks[2], inner, (cfg.d_model,), dt),
    }


def mamba2_specs(cfg: ModelConfig) -> dict:
    return {
        "in_proj": ("embed", "ssm_inner"),
        "conv_w": ("conv", "ssm_inner"),
        "conv_b": ("ssm_inner",),
        "A_log": (None,),
        "D": (None,),
        "dt_bias": (None,),
        "norm_w": ("ssm_inner",),
        "out_proj": ("ssm_inner", "embed"),
    }


def _split_proj(p, cfg, proj):
    inner, nh, N = _dims(cfg)
    z = proj[..., :inner]
    xBC = proj[..., inner:2 * inner + 2 * N]
    dt = proj[..., 2 * inner + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv1d, width W. xBC: (B,S,C); conv_state: (B,W-1,C)."""
    W = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[-1]), xBC.dtype)
    else:
        pad = conv_state.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(xp[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(W))
    new_state = xp[:, -(W - 1):] if W > 1 else pad
    return jax.nn.silu(out + conv_b), new_state


def _ssm_inputs(p, cfg, x_conv, dt_raw):
    inner, nh, N = _dims(cfg)
    B_, S = x_conv.shape[0], x_conv.shape[1]
    x_in = x_conv[..., :inner].reshape(B_, S, nh, cfg.ssm_head_dim)
    Bmat = x_conv[..., inner:inner + N][:, :, None, :]           # (B,S,1,N)
    Cmat = x_conv[..., inner + N:][:, :, None, :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    log_w = (-jnp.exp(p["A_log"]) * dt)[..., None]               # (B,S,nh,1)
    r = jnp.broadcast_to(Cmat, (B_, S, nh, N))
    k = jnp.broadcast_to(Bmat, (B_, S, nh, N))
    v = x_in * dt[..., None]
    return x_in, r, k, v, log_w


def mamba2_apply_full(p, cfg: ModelConfig, x, *, initial_state=None):
    """x: (B,S,d) -> (B,S,d). Returns (out, (conv_state, ssm_state))."""
    inner, nh, N = _dims(cfg)
    proj = x @ p["in_proj"]
    proj = constrain(proj, ("batch", "seq", "ffn_act"))
    z, xBC, dt_raw = _split_proj(p, cfg, proj)
    conv_in_state = None if initial_state is None else initial_state[0]
    x_conv, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_in_state)
    x_in, r, k, v, log_w = _ssm_inputs(p, cfg, x_conv, dt_raw)
    ssm_in_state = None if initial_state is None else initial_state[1]
    y, ssm_state = chunked_decay_attention(
        r, k, v, log_w, decay_in_output=True, initial_state=ssm_in_state)
    y = y + p["D"][None, None, :, None] * x_in.astype(jnp.float32)
    y = y.reshape(*x.shape[:2], inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], (conv_state, ssm_state)


def mamba2_decode_step(p, cfg: ModelConfig, x, conv_state, ssm_state):
    """x: (B,1,d); conv_state: (B,W-1,C); ssm_state: (B,nh,N,hd) fp32."""
    inner, nh, N = _dims(cfg)
    proj = x @ p["in_proj"]
    z, xBC, dt_raw = _split_proj(p, cfg, proj)
    x_conv, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    x_in, r, k, v, log_w = _ssm_inputs(p, cfg, x_conv, dt_raw)
    y, ssm_state = decay_attention_decode_step(
        ssm_state, r[:, 0], k[:, 0], v[:, 0], log_w[:, 0],
        decay_in_output=True)
    y = y[:, None] + p["D"][None, None, :, None] * x_in.astype(jnp.float32)
    y = y.reshape(x.shape[0], 1, inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], conv_state, ssm_state


def mamba2_init_state(cfg: ModelConfig, batch: int):
    inner, nh, N = _dims(cfg)
    conv_dim = inner + 2 * N
    return (jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), jnp.dtype(cfg.dtype)),
            jnp.zeros((batch, nh, N, cfg.ssm_head_dim), jnp.float32))
