"""Synthetic verifiable-reward (RLVR) task pipeline: integer arithmetic.

Each prompt is ``"a+b="`` (or -, *); the verifiable answer is the decimal
result. This is the in-framework stand-in for DeepMath/Math-Orz-style RLVR
datasets; rewards are computed by exact-match verification in rl/rewards.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator

import numpy as np

from repro.data import tokenizer as tok


@dataclass(frozen=True)
class TaskConfig:
    max_operand: int = 99
    ops: tuple[str, ...] = ("+", "-")
    prompt_len: int = 16
    max_answer_len: int = 8


@dataclass
class Batch:
    prompts: np.ndarray       # (B, prompt_len) int32, left-padded
    answers: list[str]        # verifiable ground truth
    prompt_text: list[str]


class ArithmeticTask:
    def __init__(self, cfg: TaskConfig = TaskConfig(), seed: int = 0):
        self.cfg = cfg
        self.rng = np.random.default_rng(seed)

    def sample_batch(self, batch_size: int) -> Batch:
        cfg = self.cfg
        a = self.rng.integers(0, cfg.max_operand + 1, batch_size)
        b = self.rng.integers(0, cfg.max_operand + 1, batch_size)
        op = self.rng.choice(list(cfg.ops), batch_size)
        texts, answers = [], []
        for ai, bi, oi in zip(a, b, op):
            texts.append(f"{ai}{oi}{bi}=")
            answers.append(str(ai + bi if oi == "+" else
                               ai - bi if oi == "-" else ai * bi))
        prompts = tok.pad_batch([tok.encode(t, bos=True) for t in texts],
                                cfg.prompt_len, left=True)
        return Batch(prompts, answers, texts)

    def iterate(self, batch_size: int) -> Iterator[Batch]:
        while True:
            yield self.sample_batch(batch_size)
