from repro.data.pipeline import ArithmeticTask, Batch, TaskConfig
from repro.data import tokenizer

__all__ = ["ArithmeticTask", "Batch", "TaskConfig", "tokenizer"]
