"""Byte-level tokenizer (ids 0..255 = bytes; specials above)."""
from __future__ import annotations

import numpy as np

PAD = 256
BOS = 257
EOS = 258
VOCAB = 260  # padded to a small multiple


def encode(text: str, *, bos: bool = False, eos: bool = False) -> list[int]:
    ids = list(text.encode("utf-8"))
    if bos:
        ids = [BOS] + ids
    if eos:
        ids = ids + [EOS]
    return ids


def decode(ids) -> str:
    bs = bytes(int(i) for i in ids if 0 <= int(i) < 256)
    return bs.decode("utf-8", errors="replace")


def pad_batch(seqs: list[list[int]], length: int, *, left: bool = True) -> np.ndarray:
    out = np.full((len(seqs), length), PAD, np.int32)
    for i, s in enumerate(seqs):
        s = s[-length:] if left else s[:length]
        if left:
            out[i, length - len(s):] = s
        else:
            out[i, :len(s)] = s
    return out
