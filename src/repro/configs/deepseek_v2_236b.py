"""DeepSeek-V2-236B — MLA (kv_lora=512) + MoE 2 shared + 160 routed top-6
[arXiv:2405.04434].

Assigned spec lists d_ff=1536 = routed-expert width; the single leading dense
layer uses the published 12288 hidden width.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,          # MLA: kv heads == heads post up-projection
    head_dim=128,              # qk_nope_head_dim
    v_head_dim=128,
    d_ff=12288,                # dense (first layer) hidden width
    moe_d_ff=1536,             # routed expert width (assigned d_ff)
    vocab_size=102400,
    attention="mla",
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_head_dim=64,
    rope_theta=1.0e4,
    num_experts=160,
    num_experts_per_tok=6,
    num_shared_experts=2,
    first_dense_layers=1,
    subquadratic=False,
))
