"""Paper Table 3 / Table 6 job profiles for the scheduler experiments.

These are *workload profiles* (phase durations, memory footprints, GPU
counts), not model-zoo configs — they feed the RollMux scheduler and the
discrete-event simulator exactly as the paper's profiler output would.

Durations are the paper's own published characteristics:
  * Table 2 memory footprints (GB per 8-GPU node),
  * Table 3 micro-benchmark job types (A-E),
  * Table 6 simulation profiles (BL/RH/TH x S/M/L, Unif bounds).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class JobProfile:
    name: str
    model: str
    turns: str              # "single" | "multi"
    t_roll: float           # rollout phase duration (s), worst-case estimate
    t_train: float          # training phase duration (s), worst-case estimate
    mem_roll_gb: float      # host-memory footprint of rollout actor (8-GPU node)
    mem_train_gb: float     # host-memory footprint of training actor
    n_roll_gpus: int
    n_train_gpus: int
    max_new_tokens: int = 8192


# Paper Table 2 (GB per 8-GPU node)
MEM_FOOTPRINT_GB = {
    "3B":  {"rollout": 113.4, "train": 156.2},
    "7B":  {"rollout": 275.7, "train": 240.0},
    "8B":  {"rollout": 290.0, "train": 260.0},   # interpolated
    "14B": {"rollout": 445.4, "train": 456.1},
    "32B": {"rollout": 490.3, "train": 520.4},
}

# Paper Table 3 micro-benchmark job types. Phase durations follow Fig 2's
# 50-900s range with the stated skews (Type-D: T_roll ~ 2.5 T_train,
# Type-E: T_roll ~ 6 T_train).
TYPE_A = JobProfile("Type-A", "Qwen2.5-7B",  "single", 170.0, 185.0,
                    MEM_FOOTPRINT_GB["7B"]["rollout"], MEM_FOOTPRINT_GB["7B"]["train"], 8, 8)
TYPE_B = JobProfile("Type-B", "Qwen2.5-14B", "single", 250.0, 265.0,
                    MEM_FOOTPRINT_GB["14B"]["rollout"], MEM_FOOTPRINT_GB["14B"]["train"], 8, 8)
TYPE_C = JobProfile("Type-C", "Qwen2.5-32B", "single", 320.0, 500.0,
                    MEM_FOOTPRINT_GB["32B"]["rollout"], MEM_FOOTPRINT_GB["32B"]["train"], 16, 16)
TYPE_D = JobProfile("Type-D", "Qwen3-8B",    "multi",  500.0, 200.0,
                    MEM_FOOTPRINT_GB["8B"]["rollout"], MEM_FOOTPRINT_GB["8B"]["train"], 8, 8)
TYPE_E = JobProfile("Type-E", "Qwen3-14B",   "multi",  900.0, 150.0,
                    MEM_FOOTPRINT_GB["14B"]["rollout"], MEM_FOOTPRINT_GB["14B"]["train"], 8, 8,
                    max_new_tokens=16384)

PAPER_JOB_TYPES = {j.name: j for j in (TYPE_A, TYPE_B, TYPE_C, TYPE_D, TYPE_E)}

# Paper Table 6: simulation profiles — (lo, hi) of Unif for (t_roll, t_train).
SIM_PROFILES: dict[str, dict[str, tuple[tuple[float, float], tuple[float, float]]]] = {
    "BL": {"S": ((50, 100), (50, 100)),
           "M": ((100, 200), (100, 200)),
           "L": ((200, 300), (200, 300))},
    "RH": {"S": ((100, 200), (25, 50)),
           "M": ((200, 400), (50, 100)),
           "L": ((400, 600), (100, 200))},
    "TH": {"S": ((25, 50), (100, 200)),
           "M": ((50, 100), (200, 400)),
           "L": ((100, 200), (400, 600))},
}
